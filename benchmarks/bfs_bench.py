"""Shared benchmark helpers: timed BFS runs + CSV emission.

CSV schema (required): name,us_per_call,derived
``derived`` carries the benchmark-specific figure of merit (TEPS, ratio,
words, ...).  Multi-device benchmarks run in *subprocesses* so this
process keeps the default single device."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional

import numpy as np

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def run_worker(payload: Dict, n_devices: int = 16, timeout: int = 2400) -> Dict:
    """Run benchmarks/worker.py in a subprocess with forced device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = _SRC
    worker = os.path.join(os.path.dirname(__file__), "worker.py")
    r = subprocess.run([sys.executable, worker], input=json.dumps(payload),
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    if r.returncode != 0:
        raise RuntimeError(f"worker failed: {r.stderr[-2000:]}")
    return json.loads(r.stdout.splitlines()[-1])
