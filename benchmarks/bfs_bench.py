"""Shared benchmark helpers: timed BFS runs + CSV emission.

CSV schema (required): name,us_per_call,derived
``derived`` carries the benchmark-specific figure of merit (TEPS, ratio,
words, ...).  Multi-device benchmarks run in *subprocesses* so this
process keeps the default single device."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional

import numpy as np

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def run_worker(payload: Dict, n_devices: int = 16, timeout: int = 2400) -> Dict:
    """Run benchmarks/worker.py in a subprocess with forced device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = _SRC
    worker = os.path.join(os.path.dirname(__file__), "worker.py")
    r = subprocess.run([sys.executable, worker], input=json.dumps(payload),
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    if r.returncode != 0:
        raise RuntimeError(f"worker failed: {r.stderr[-2000:]}")
    return json.loads(r.stdout.splitlines()[-1])


_PHASES = ("wire_transpose", "wire_expand", "wire_fold", "wire_rotate",
           "wire_updates")

# label -> worker payload: the "1ds" decomposition runs twice, once per
# frontier codec, so the sweep measures the compressed-vs-raw-vs-dense
# exchange crossover on the SAME graph
_DECOMP_VARIANTS = (
    ("1d", {"decomposition": "1d"}),
    ("1ds-raw", {"decomposition": "1ds", "frontier_codec": "none"}),
    ("1ds-packed", {"decomposition": "1ds", "frontier_codec": "packed"}),
    ("2d", {"decomposition": "2d"}),
)


def sweep_decompositions(scale: int, grid, n_devices: int = 16,
                         roots: int = 4, out_json: Optional[str] = None,
                         **payload_kw) -> List[Dict]:
    """Run the same R-MAT graph through every decomposition variant on
    the same device count (1d/1ds use p = pr*pc strips; "1ds" runs both
    raw-id and packed-codec exchanges) and emit one CSV row per variant
    with TEPS + per-phase wire counters — the measured side of the
    paper's Eq. 2 comparison.  ``out_json`` additionally dumps the rows
    plus the compressed-vs-raw-vs-dense expand-words crossover artifact
    (``expand_words_artifact``) for CI."""
    out = []
    for label, extra in _DECOMP_VARIANTS:
        res = run_worker({"scale": scale, "grid": list(grid),
                          "roots": roots, **extra, **payload_kw},
                         n_devices=n_devices)
        res["variant"] = label
        ctr = res["counters"] or {}
        phases = ";".join(f"{k}={ctr.get(k, 0.0):.3e}" for k in _PHASES)
        emit(f"bfs_s{scale}_{label}_{grid[0]}x{grid[1]}",
             res["hmean_s"] * 1e6,
             f"teps={res['teps']:.3e};"
             f"compile_s={res.get('compile_s', 0.0):.3f};{phases}")
        out.append(res)
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"rows": out,
                       "expand_words": expand_words_artifact(out)}, f,
                      indent=2)
    return out


def _variant_key(row) -> str:
    if row.get("variant"):
        return row["variant"]
    if row["decomposition"] == "1ds":
        return ("1ds-raw" if row.get("frontier_codec") == "none"
                else "1ds-packed")
    return row["decomposition"]


def expand_words_artifact(rows) -> Dict:
    """The compressed-vs-raw-vs-dense 1D expand comparison from a
    ``sweep_decompositions`` run: per-level measured wire words for the
    "1d" bitmap allgather and BOTH "1ds" id exchanges (raw 64-bit-word
    ids vs the packed fixed-width codec) on the same graph, the
    per-level closed forms, and each variant's crossover level — the
    first level where that sparse exchange stops beating the bitmap
    (None if it wins every level)."""
    if _SRC not in sys.path:           # CLI runs without PYTHONPATH=src
        sys.path.insert(0, _SRC)
    from repro.core import comm_model
    by = {_variant_key(r): r for r in rows}
    d1 = by.get("1d")
    ref = by.get("1ds-packed") or by.get("1ds-raw")
    if not (d1 and ref):
        return {}
    n_pad, p = ref["n_pad"], ref["p"]
    bits = comm_model.codec_bits(n_pad // p)
    dense_level = comm_model.expand_1d_level_words(n_pad, p)

    def sparse_block(row, padded_model):
        if not row:
            return None
        sparse = row.get("levels_wire_expand") or []
        cap = row.get("cap_x") or 0
        return {
            "cap_x": cap,
            # live words shipped per level (the modeled alltoallv
            # volume); the static padded buckets cost the padded model
            # a level whenever the sparse path runs
            "padded_level_words_model": padded_model(cap),
            "levels_wire_expand": sparse,
            "levels_n_f": row.get("levels_n_f"),
            "wire_expand_total": (row["counters"] or {}).get("wire_expand"),
            "crossover_level": next(
                (i for i, w in enumerate(sparse) if w >= dense_level), None),
        }

    raw = sparse_block(by.get("1ds-raw"),
                       lambda c: comm_model.sparse_expand_padded_words(c, p))
    packed = sparse_block(
        by.get("1ds-packed"),
        lambda c: comm_model.compressed_expand_padded_words(c, p, bits))
    out = {
        "n_pad": n_pad, "p": p, "codec_bits": bits,
        "dense_level_words_model": dense_level,
        "dense_levels_wire_expand": d1.get("levels_wire_expand"),
        "wire_expand_total_1d": (d1["counters"] or {}).get("wire_expand"),
        "topdown_1d_words_model": comm_model.topdown_1d_words(ref["m"], p),
        "raw": raw, "packed": packed,
    }
    if raw and packed and raw["wire_expand_total"]:
        out["packed_over_raw_total"] = (packed["wire_expand_total"]
                                        / raw["wire_expand_total"])
    return out


def sweep_expand_chunks(scale: int, grid, n_devices: int = 16,
                        roots: int = 2, chunks=(1, 2),
                        out_json: Optional[str] = None,
                        **payload_kw) -> Dict:
    """The software-pipelined-expand overlap sweep: run the same R-MAT
    graph through 1d / 1ds-packed / 2d at every ``expand_chunks`` value,
    recording per-chunking fast-path latency (``traverse_min_s``) AND
    the modeled-vs-measured wire words — the artifact that pins the
    tentpole invariant: chunking overlaps latency, it never changes the
    bytes on the wire (``chunked_expand_1d_level_words`` equals the
    dense form; the 2d R/G ring doubles only the latency-cheap
    ``wire_rotate``).  One CSV row per (variant, chunking)."""
    if _SRC not in sys.path:
        sys.path.insert(0, _SRC)
    from repro.core import comm_model
    variants = (("1d", {"decomposition": "1d"}),
                ("1ds-packed", {"decomposition": "1ds",
                                "frontier_codec": "packed"}),
                ("2d", {"decomposition": "2d"}))
    rows = []
    for label, extra in variants:
        for ec in chunks:
            base = {"scale": scale, "grid": list(grid), "roots": roots,
                    "expand_chunks": int(ec), **extra, **payload_kw}
            # fast run for the latency figure, instrumented run for the
            # measured wire counters (the fast path compiles them out)
            fast = run_worker({**base, "instrument": False},
                              n_devices=n_devices)
            inst = run_worker({**base, "instrument": True},
                              n_devices=n_devices)
            n_pad, p = inst["n_pad"], inst["p"]
            ctr = inst["counters"] or {}
            levels = len(inst.get("levels_mode") or [])
            row = {"variant": label, "expand_chunks": int(ec),
                   "traverse_min_s": min(fast["times"]),
                   "traverse_hmean_s": fast["hmean_s"],
                   "teps_best": fast["m_input"] / min(fast["times"]),
                   "hlo_collectives": fast["hlo_collectives"],
                   "levels": levels,
                   "wire_expand_measured": ctr.get("wire_expand"),
                   "wire_rotate_measured": ctr.get("wire_rotate")}
            if label == "1d":
                # every 1d level (top-down chunked or bottom-up dense)
                # ships exactly the dense bitmap volume
                row["wire_expand_model"] = levels * \
                    comm_model.chunked_expand_1d_level_words(n_pad, p, ec)
            elif label == "1ds-packed":
                # per level: the chunked compressed form when the sparse
                # exchange ran, the dense bitmap otherwise (bottom-up /
                # overflow fallback) — every measured level must match
                # one of the two candidates
                bits = comm_model.codec_bits((n_pad // p) // int(ec))
                dense_lvl = comm_model.chunked_expand_1d_level_words(
                    n_pad, p, ec)
                ok = True
                for n_f, w in zip(inst.get("levels_n_f") or [],
                                  inst.get("levels_wire_expand") or []):
                    sparse_w = comm_model.compressed_expand_1d_words(
                        n_f, p, bits, int(ec))
                    ok &= any(abs(w - c) <= 1e-5 * max(c, 1.0)
                              for c in (sparse_w, dense_lvl))
                row["wire_model_consistent"] = bool(ok)
            emit(f"bfs_chunks_s{scale}_{label}_c{ec}",
                 row["traverse_min_s"] * 1e6,
                 f"teps_best={row['teps_best']:.3e};"
                 f"wire_expand={row['wire_expand_measured']:.3e}")
            rows.append(row)
    art = {"config": {"scale": scale, "grid": list(grid),
                      "n_devices": n_devices, "roots": roots,
                      "chunks": [int(c) for c in chunks]},
           "rows": rows, "wire_expand_unchanged": {},
           "best_chunking": {}}
    for label, _ in variants:
        rs = [r for r in rows if r["variant"] == label]
        ws = [r["wire_expand_measured"] for r in rs]
        # the headline invariant: chunking leaves the expand wire words
        # unchanged (bit-for-bit for 1d/2d; 1ds may legitimately differ
        # when per-sub-range overflow flips a level to the dense
        # fallback, so the artifact records the outcome rather than
        # asserting it)
        art["wire_expand_unchanged"][label] = bool(
            all(abs(w - ws[0]) <= 1e-5 * max(ws[0], 1.0) for w in ws))
        best = min(rs, key=lambda r: r["traverse_min_s"])
        art["best_chunking"][label] = {
            "expand_chunks": best["expand_chunks"],
            "traverse_min_s": best["traverse_min_s"]}
    if out_json:
        with open(out_json, "w") as f:
            json.dump(art, f, indent=2)
    return art


def sweep_local_formats(scale: int, grid, n_devices: int = 16,
                        roots: int = 2, local_mode: str = "kernel",
                        out_json: Optional[str] = None,
                        **payload_kw) -> List[Dict]:
    """The paper's Fig. 6 grid on identical R-MAT graphs: local pointer
    storage (CSR vs DCSC) crossed with the decomposition (1D strips vs
    2D blocks), one CSV row per combo with traversal time, TEPS, and the
    §5.1 storage-word accounting.  The 1D/CSR cell is the O(n*p)
    col_ptr blow-up the paper charges against 1D; 1D/DCSC is the strip
    compression that answers it (graph/formats.py).  ``out_json`` dumps
    the rows as a machine-readable artifact (CI bench smoke)."""
    rows = []
    for decomp in ("1d", "2d"):
        for storage in ("csr", "dcsc"):
            res = run_worker({"scale": scale, "grid": list(grid),
                              "roots": roots, "decomposition": decomp,
                              "storage": storage, "local_mode": local_mode,
                              **payload_kw}, n_devices=n_devices)
            mem = res[f"mem_{storage}"]
            emit(f"bfs_fmt_s{scale}_{decomp}_{storage}_{local_mode}",
                 res["hmean_s"] * 1e6,
                 f"teps={res['teps']:.3e};pointer_i32={mem['pointer_i32']};"
                 f"total_i32={mem['total_i32']};"
                 f"compile_s={res.get('compile_s', 0.0):.3f}")
            rows.append({"scale": scale, "grid": list(grid),
                         "decomposition": decomp, "storage": storage,
                         "local_mode": local_mode,
                         "us_per_call": res["hmean_s"] * 1e6,
                         "teps": res["teps"], "storage_words": mem,
                         "compile_s": res.get("compile_s"),
                         "ship_s": res.get("ship_s"),
                         "times_s": res.get("times"),
                         "counters": res["counters"]})
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def bench_trajectory(scale: int = 14, grid=(4, 4), n_devices: int = 16,
                     roots: int = 2, degree: int = 4,
                     out_json: str = "BENCH_bfs.json",
                     chunk_sweep=(2, 4)) -> Dict:
    """Extend the bench trajectory: the pinned scale-14 / p=16 R-MAT
    config (the same graph family as the 16-device acceptance tests)
    through every decomposition variant ("1ds" both raw and packed),
    each compiled BOTH ways — ``instrument=False`` (the latency-lean
    fast path the paper's depth/time/TEPS runs use) and
    ``instrument=True`` (full counters).  ``chunk_sweep`` additionally
    times the software-pipelined fast engine per expand_chunks value
    (parents parity asserted in-worker) and records each variant's best
    chunking — the PR 7 acceptance figure.  APPENDS one point to the
    ``{"points": [...]}`` trajectory in ``out_json`` (auto-converting a
    legacy single-point file), so future PRs diff traversal latency and
    the compiled collective schedule against the whole history.
    Returns the new point."""
    point = {"config": {"scale": scale, "degree": degree,
                        "grid": list(grid), "n_devices": n_devices,
                        "roots": roots},
             "decompositions": {}}
    for label, extra in _DECOMP_VARIANTS:
        # ONE worker process builds both engines and interleaves the
        # timing (ABBA), so the comparison is not smeared by
        # process-level drift; ``traverse_s`` is the best-observed
        # per-root latency (forced-host-device runs are noisy)
        res = run_worker({"scale": scale, "grid": list(grid),
                          "roots": roots, "degree": degree, **extra,
                          "compare_instrument": True,
                          "chunk_sweep": [int(c) for c in chunk_sweep]},
                         n_devices=n_devices)
        row = {"frontier_codec": res.get("frontier_codec")}
        for mode in ("fast", "instrumented"):
            b = res[mode]
            row[mode] = {"traverse_s": b["hmean_s"],
                         "traverse_min_s": b["min_s"],
                         "teps": b["teps"],
                         "level_collectives": b["hlo_collectives"],
                         "compile_s": b.get("compile_s"),
                         "times_s": b["times"]}
        row["speedup_fast"] = (row["instrumented"]["traverse_s"]
                               / row["fast"]["traverse_s"])
        best_c, best_t = 1, row["fast"]["traverse_min_s"]
        if res.get("chunked"):
            row["chunked"] = {}
            for ec, b in sorted(res["chunked"].items(), key=lambda kv:
                                int(kv[0])):
                row["chunked"][ec] = {
                    "traverse_s": b["hmean_s"],
                    "traverse_min_s": b["min_s"],
                    "teps_best": b["teps_best"],
                    "level_collectives": b["hlo_collectives"],
                    "baseline_resample_min_s": b["baseline_resample_min_s"],
                    "times_s": b["times"]}
                if b["min_s"] < best_t:
                    best_c, best_t = int(ec), b["min_s"]
            row["best_fast"] = {"expand_chunks": best_c,
                                "traverse_min_s": best_t}
        emit(f"bfs_traj_s{scale}_{label}_fast",
             row["fast"]["traverse_s"] * 1e6,
             f"teps={row['fast']['teps']:.3e};"
             f"collectives={row['fast']['level_collectives']['total']};"
             f"speedup_vs_instrumented={row['speedup_fast']:.3f};"
             f"best_chunking={best_c}")
        point["decompositions"][label] = row
    # the born-sharded build + store numbers at the SAME pinned config:
    # disk -> first-traversal vs rebuild + recompile (PR 8 acceptance)
    point["build_store"] = build_store_lane(
        scale, grid, n_devices=n_devices, decomposition="1d",
        roots=roots, degree=degree)
    if out_json:
        points = []
        if os.path.exists(out_json):
            with open(out_json) as f:
                prev = json.load(f)
            # legacy schema: a bare single point (the PR 5 seed) — keep
            # it as point 0 rather than overwriting history
            points = prev["points"] if "points" in prev else [prev]
        points.append(point)
        with open(out_json, "w") as f:
            json.dump({"points": points}, f, indent=2)
    return point


def build_store_lane(scale: int, grid, n_devices: int = 16,
                     decomposition: str = "1d", roots: int = 4,
                     degree: int = 16, seed: int = 1,
                     store_dir: Optional[str] = None,
                     out_json: Optional[str] = None) -> Dict:
    """The born-sharded build-then-load acceptance lane: one worker
    process builds the graph ON DEVICE (distributed R-MAT generation +
    owner routing, no host edge list), persists graph + AOT executable
    to a shared store, and a SECOND worker process — cold, nothing
    cached — reloads both and traverses.  The artifact pins build TEPS
    and the figure the store exists for: disk -> first-traversal latency
    vs rebuild + recompile on the same mesh."""
    import tempfile
    store = store_dir or tempfile.mkdtemp(prefix="graph_store_")
    base = {"scale": scale, "grid": list(grid), "roots": roots,
            "degree": degree, "seed": seed,
            "decomposition": decomposition, "store_dir": store}
    build = run_worker({**base, "phase": "build"}, n_devices=n_devices)
    load = run_worker({**base, "phase": "load"}, n_devices=n_devices)
    rebuild_s = (build["build_s"] + build["ship_s"] + build["compile_s"]
                 + build["first_traversal_s"])
    art = {
        "config": base, "n_devices": n_devices,
        "build_s": build["build_s"], "build_teps": build["build_teps"],
        "gen_route_s": build["gen_route_s"],
        "format_s": build["format_s"], "save_s": build["save_s"],
        "compile_s": build["compile_s"],
        "route_words_measured": build["route_words_measured"],
        "route_words_expected": build["route_words_expected"],
        "m": build["m"], "m_input": build["m_input"],
        "load_s": load["load_s"], "exec_load_s": load["exec_load_s"],
        "exec_from_store": load["exec_from_store"],
        "ship_s_loaded": load["ship_s"],
        "disk_to_first_traversal_s": load["to_first_traversal_s"],
        "rebuild_to_first_traversal_s": rebuild_s,
        "store_speedup": rebuild_s / load["to_first_traversal_s"],
        "traverse_hmean_s": {"build": build["hmean_s"],
                             "load": load["hmean_s"]},
        "teps": {"build": build["teps"], "load": load["teps"]},
    }
    emit(f"bfs_build_s{scale}_{decomposition}_p{n_devices}",
         build["build_s"] * 1e6,
         f"build_teps={build['build_teps']:.3e};"
         f"save_s={build['save_s']:.3f};compile_s={build['compile_s']:.3f}")
    emit(f"bfs_store_load_s{scale}_{decomposition}_p{n_devices}",
         load["to_first_traversal_s"] * 1e6,
         f"rebuild_s={rebuild_s:.3f};speedup={art['store_speedup']:.2f};"
         f"exec_hit={load['exec_from_store']}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(art, f, indent=2)
    return art


def engine_timing_summary(rows) -> List[Dict]:
    """Compile-vs-traverse split per sweep row (the engine's promise:
    per-root time excludes compilation), as a compact artifact."""
    out = []
    for r in rows:
        times = r.get("times_s") or []
        out.append({
            "name": f"s{r['scale']}_{r['decomposition']}_{r['storage']}_"
                    f"{r['local_mode']}",
            "compile_s": r.get("compile_s"),
            "ship_s": r.get("ship_s"),
            "traverse_s_per_root": times,
            "traverse_hmean_s": (len(times) / sum(1.0 / t for t in times)
                                 if times else None),
            "teps": r.get("teps"),
        })
    return out


def _main():
    """CLI for the CI bench smoke: tiny-scale sweep_local_formats on
    forced host devices, CSV to stdout + JSON artifacts; ``--decomp-out``
    additionally runs the decomposition sweep (1d, 1ds raw, 1ds packed,
    2d) and writes the compressed-vs-raw-vs-dense expand-words
    crossover artifact."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--grid", default="2x2")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--roots", type=int, default=2)
    ap.add_argument("--local-mode", default="kernel")
    ap.add_argument("--out", default=None)
    ap.add_argument("--timings-out", default=None,
                    help="write the compile-vs-traverse split per combo "
                         "(engine path) as a JSON artifact")
    ap.add_argument("--decomp-out", default=None,
                    help="also run the 1d/1ds(raw+packed)/2d "
                         "sweep_decompositions and write the "
                         "compressed-vs-raw-vs-dense expand-words "
                         "crossover artifact to this path")
    ap.add_argument("--expand-chunks", default="1,2",
                    help="comma-separated expand_chunks values for the "
                         "--overlap-out sweep (each must divide the "
                         "per-strip packed word count)")
    ap.add_argument("--overlap-out", default=None,
                    help="run sweep_expand_chunks (software-pipelined "
                         "expand: per-chunking fast latency + modeled "
                         "vs measured wire words) and write the overlap "
                         "artifact to this path")
    ap.add_argument("--bench-out", default=None,
                    help="run bench_trajectory (instrumented-vs-fast on "
                         "the pinned scale-14/p=16 R-MAT config) and "
                         "append one point to this BENCH_bfs.json-style "
                         "trajectory file")
    ap.add_argument("--bench-scale", type=int, default=14,
                    help="override the pinned bench_trajectory scale")
    ap.add_argument("--bench-devices", type=int, default=16,
                    help="override the pinned bench_trajectory devices "
                         "(grid is sqrt x sqrt)")
    ap.add_argument("--build-out", default=None,
                    help="run build_store_lane (device-side distributed "
                         "build -> persist -> cold reload -> traverse) "
                         "and write the build_s/load_s/compile_s "
                         "artifact to this path")
    ap.add_argument("--build-scale", type=int, default=16,
                    help="R-MAT scale for the --build-out lane")
    ap.add_argument("--build-devices", type=int, default=16,
                    help="forced device count for the --build-out lane")
    ap.add_argument("--build-decomp", default="1d",
                    help="decomposition for the --build-out lane")
    a = ap.parse_args()
    pr, pc = map(int, a.grid.split("x"))
    print("name,us_per_call,derived")
    rows = sweep_local_formats(a.scale, (pr, pc), n_devices=a.devices,
                               roots=a.roots, local_mode=a.local_mode,
                               out_json=a.out, validate=True)
    if a.timings_out:
        with open(a.timings_out, "w") as f:
            json.dump(engine_timing_summary(rows), f, indent=2)
    if a.decomp_out:
        sweep_decompositions(a.scale, (pr, pc), n_devices=a.devices,
                             roots=a.roots, out_json=a.decomp_out,
                             validate=True)
    if a.overlap_out:
        chunks = [int(c) for c in a.expand_chunks.split(",") if c]
        sweep_expand_chunks(a.scale, (pr, pc), n_devices=a.devices,
                            roots=a.roots, chunks=chunks,
                            out_json=a.overlap_out)
    if a.bench_out:
        side = int(round(a.bench_devices ** 0.5))
        if side * side != a.bench_devices:
            # the artifact records n_devices as the mesh size — a
            # silently floored grid would pin numbers from a smaller
            # mesh than the config claims
            raise SystemExit(f"--bench-devices {a.bench_devices} is not "
                             f"a square (the trajectory grid is NxN)")
        bench_trajectory(scale=a.bench_scale, grid=(side, side),
                         n_devices=a.bench_devices, roots=a.roots,
                         out_json=a.bench_out)
    if a.build_out:
        g1 = (a.build_devices, 1) if a.build_decomp in ("1d", "1ds") \
            else (int(round(a.build_devices ** 0.5)),) * 2
        build_store_lane(a.build_scale, g1, n_devices=a.build_devices,
                         decomposition=a.build_decomp, roots=a.roots,
                         out_json=a.build_out)


if __name__ == "__main__":
    _main()
