"""Shared benchmark helpers: timed BFS runs + CSV emission.

CSV schema (required): name,us_per_call,derived
``derived`` carries the benchmark-specific figure of merit (TEPS, ratio,
words, ...).  Multi-device benchmarks run in *subprocesses* so this
process keeps the default single device."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional

import numpy as np

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def run_worker(payload: Dict, n_devices: int = 16, timeout: int = 2400) -> Dict:
    """Run benchmarks/worker.py in a subprocess with forced device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = _SRC
    worker = os.path.join(os.path.dirname(__file__), "worker.py")
    r = subprocess.run([sys.executable, worker], input=json.dumps(payload),
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    if r.returncode != 0:
        raise RuntimeError(f"worker failed: {r.stderr[-2000:]}")
    return json.loads(r.stdout.splitlines()[-1])


_PHASES = ("wire_transpose", "wire_expand", "wire_fold", "wire_rotate",
           "wire_updates")


def sweep_decompositions(scale: int, grid, n_devices: int = 16,
                         roots: int = 4, out_json: Optional[str] = None,
                         **payload_kw) -> List[Dict]:
    """Run the same R-MAT graph through all three decompositions on the
    same device count (1d/1ds use p = pr*pc strips) and emit one CSV row
    per decomposition with TEPS + per-phase wire counters — the measured
    side of the paper's Eq. 2 comparison.  ``out_json`` additionally
    dumps the rows plus the dense-vs-sparse expand-words crossover
    artifact (``expand_words_artifact``) for CI."""
    out = []
    for decomp in ("1d", "1ds", "2d"):
        res = run_worker({"scale": scale, "grid": list(grid),
                          "roots": roots, "decomposition": decomp,
                          **payload_kw}, n_devices=n_devices)
        ctr = res["counters"] or {}
        phases = ";".join(f"{k}={ctr.get(k, 0.0):.3e}" for k in _PHASES)
        emit(f"bfs_s{scale}_{decomp}_{grid[0]}x{grid[1]}",
             res["hmean_s"] * 1e6,
             f"teps={res['teps']:.3e};"
             f"compile_s={res.get('compile_s', 0.0):.3f};{phases}")
        out.append(res)
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"rows": out,
                       "expand_words": expand_words_artifact(out)}, f,
                      indent=2)
    return out


def expand_words_artifact(rows) -> Dict:
    """The dense-vs-sparse 1D expand comparison from a
    ``sweep_decompositions`` run: per-level measured wire words for the
    "1d" bitmap allgather and the "1ds" id exchange on the same graph,
    the per-level dense closed form, and the crossover level — the first
    level where the sparse exchange stops beating the bitmap (None if it
    wins every level)."""
    if _SRC not in sys.path:           # CLI runs without PYTHONPATH=src
        sys.path.insert(0, _SRC)
    from repro.core import comm_model
    by = {r["decomposition"]: r for r in rows}
    d1, ds = by.get("1d"), by.get("1ds")
    if not (d1 and ds):
        return {}
    n_pad, p = ds["n_pad"], ds["p"]
    dense_level = comm_model.expand_1d_level_words(n_pad, p)
    sparse = ds.get("levels_wire_expand") or []
    crossover = next((i for i, w in enumerate(sparse) if w >= dense_level),
                     None)
    return {
        "n_pad": n_pad, "p": p, "cap_x": ds.get("cap_x"),
        "dense_level_words_model": dense_level,
        # live ids shipped per level (the modeled alltoallv volume); the
        # static padded buckets cost sparse_padded_level_words_model a
        # level whenever the sparse path runs
        "sparse_padded_level_words_model":
            comm_model.sparse_expand_padded_words(ds.get("cap_x") or 0, p),
        "dense_levels_wire_expand": d1.get("levels_wire_expand"),
        "sparse_levels_wire_expand": sparse,
        "sparse_levels_n_f": ds.get("levels_n_f"),
        "wire_expand_total_1d": (d1["counters"] or {}).get("wire_expand"),
        "wire_expand_total_1ds": (ds["counters"] or {}).get("wire_expand"),
        "topdown_1d_words_model": comm_model.topdown_1d_words(ds["m"], p),
        "crossover_level": crossover,
    }


def sweep_local_formats(scale: int, grid, n_devices: int = 16,
                        roots: int = 2, local_mode: str = "kernel",
                        out_json: Optional[str] = None,
                        **payload_kw) -> List[Dict]:
    """The paper's Fig. 6 grid on identical R-MAT graphs: local pointer
    storage (CSR vs DCSC) crossed with the decomposition (1D strips vs
    2D blocks), one CSV row per combo with traversal time, TEPS, and the
    §5.1 storage-word accounting.  The 1D/CSR cell is the O(n*p)
    col_ptr blow-up the paper charges against 1D; 1D/DCSC is the strip
    compression that answers it (graph/formats.py).  ``out_json`` dumps
    the rows as a machine-readable artifact (CI bench smoke)."""
    rows = []
    for decomp in ("1d", "2d"):
        for storage in ("csr", "dcsc"):
            res = run_worker({"scale": scale, "grid": list(grid),
                              "roots": roots, "decomposition": decomp,
                              "storage": storage, "local_mode": local_mode,
                              **payload_kw}, n_devices=n_devices)
            mem = res[f"mem_{storage}"]
            emit(f"bfs_fmt_s{scale}_{decomp}_{storage}_{local_mode}",
                 res["hmean_s"] * 1e6,
                 f"teps={res['teps']:.3e};pointer_i32={mem['pointer_i32']};"
                 f"total_i32={mem['total_i32']};"
                 f"compile_s={res.get('compile_s', 0.0):.3f}")
            rows.append({"scale": scale, "grid": list(grid),
                         "decomposition": decomp, "storage": storage,
                         "local_mode": local_mode,
                         "us_per_call": res["hmean_s"] * 1e6,
                         "teps": res["teps"], "storage_words": mem,
                         "compile_s": res.get("compile_s"),
                         "ship_s": res.get("ship_s"),
                         "times_s": res.get("times"),
                         "counters": res["counters"]})
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def bench_trajectory(scale: int = 14, grid=(4, 4), n_devices: int = 16,
                     roots: int = 2, degree: int = 4,
                     out_json: str = "BENCH_bfs.json") -> Dict:
    """Seed/extend the bench trajectory: the pinned scale-14 / p=16
    R-MAT config (the same graph family as the 16-device acceptance
    tests) through all three decompositions, each compiled BOTH ways —
    ``instrument=False`` (the latency-lean fast path the paper's
    depth/time/TEPS runs use) and ``instrument=True`` (full counters).
    Writes ``{traverse_s, TEPS, level_collectives}`` per decomposition
    so future PRs diff traversal latency and the compiled collective
    schedule against a pinned artifact."""
    out = {"config": {"scale": scale, "degree": degree, "grid": list(grid),
                      "n_devices": n_devices, "roots": roots},
           "decompositions": {}}
    for decomp in ("1d", "1ds", "2d"):
        # ONE worker process builds both engines and interleaves the
        # timing (ABBA), so the comparison is not smeared by
        # process-level drift; ``traverse_s`` is the best-observed
        # per-root latency (forced-host-device runs are noisy)
        res = run_worker({"scale": scale, "grid": list(grid),
                          "roots": roots, "degree": degree,
                          "decomposition": decomp,
                          "compare_instrument": True},
                         n_devices=n_devices)
        row = {}
        for label in ("fast", "instrumented"):
            b = res[label]
            row[label] = {"traverse_s": b["hmean_s"],
                          "traverse_min_s": b["min_s"],
                          "teps": b["teps"],
                          "level_collectives": b["hlo_collectives"],
                          "compile_s": b.get("compile_s"),
                          "times_s": b["times"]}
        row["speedup_fast"] = (row["instrumented"]["traverse_s"]
                               / row["fast"]["traverse_s"])
        emit(f"bfs_traj_s{scale}_{decomp}_fast",
             row["fast"]["traverse_s"] * 1e6,
             f"teps={row['fast']['teps']:.3e};"
             f"collectives={row['fast']['level_collectives']['total']};"
             f"speedup_vs_instrumented={row['speedup_fast']:.3f}")
        out["decompositions"][decomp] = row
    if out_json:
        with open(out_json, "w") as f:
            json.dump(out, f, indent=2)
    return out


def engine_timing_summary(rows) -> List[Dict]:
    """Compile-vs-traverse split per sweep row (the engine's promise:
    per-root time excludes compilation), as a compact artifact."""
    out = []
    for r in rows:
        times = r.get("times_s") or []
        out.append({
            "name": f"s{r['scale']}_{r['decomposition']}_{r['storage']}_"
                    f"{r['local_mode']}",
            "compile_s": r.get("compile_s"),
            "ship_s": r.get("ship_s"),
            "traverse_s_per_root": times,
            "traverse_hmean_s": (len(times) / sum(1.0 / t for t in times)
                                 if times else None),
            "teps": r.get("teps"),
        })
    return out


def _main():
    """CLI for the CI bench smoke: tiny-scale sweep_local_formats on
    forced host devices, CSV to stdout + JSON artifacts; ``--decomp-out``
    additionally runs the three-way decomposition sweep and writes the
    dense-vs-sparse expand-words crossover artifact."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--grid", default="2x2")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--roots", type=int, default=2)
    ap.add_argument("--local-mode", default="kernel")
    ap.add_argument("--out", default=None)
    ap.add_argument("--timings-out", default=None,
                    help="write the compile-vs-traverse split per combo "
                         "(engine path) as a JSON artifact")
    ap.add_argument("--decomp-out", default=None,
                    help="also run the 1d/1ds/2d sweep_decompositions "
                         "and write the dense-vs-sparse expand-words "
                         "artifact to this path")
    ap.add_argument("--bench-out", default=None,
                    help="run bench_trajectory (instrumented-vs-fast on "
                         "the pinned scale-14/p=16 R-MAT config) and "
                         "write BENCH_bfs.json-style rows to this path")
    ap.add_argument("--bench-scale", type=int, default=14,
                    help="override the pinned bench_trajectory scale")
    ap.add_argument("--bench-devices", type=int, default=16,
                    help="override the pinned bench_trajectory devices "
                         "(grid is sqrt x sqrt)")
    a = ap.parse_args()
    pr, pc = map(int, a.grid.split("x"))
    print("name,us_per_call,derived")
    rows = sweep_local_formats(a.scale, (pr, pc), n_devices=a.devices,
                               roots=a.roots, local_mode=a.local_mode,
                               out_json=a.out, validate=True)
    if a.timings_out:
        with open(a.timings_out, "w") as f:
            json.dump(engine_timing_summary(rows), f, indent=2)
    if a.decomp_out:
        sweep_decompositions(a.scale, (pr, pc), n_devices=a.devices,
                             roots=a.roots, out_json=a.decomp_out,
                             validate=True)
    if a.bench_out:
        side = int(round(a.bench_devices ** 0.5))
        if side * side != a.bench_devices:
            # the artifact records n_devices as the mesh size — a
            # silently floored grid would pin numbers from a smaller
            # mesh than the config claims
            raise SystemExit(f"--bench-devices {a.bench_devices} is not "
                             f"a square (the trajectory grid is NxN)")
        bench_trajectory(scale=a.bench_scale, grid=(side, side),
                         n_devices=a.bench_devices, roots=a.roots,
                         out_json=a.bench_out)


if __name__ == "__main__":
    _main()
