"""Shared benchmark helpers: timed BFS runs + CSV emission.

CSV schema (required): name,us_per_call,derived
``derived`` carries the benchmark-specific figure of merit (TEPS, ratio,
words, ...).  Multi-device benchmarks run in *subprocesses* so this
process keeps the default single device."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional

import numpy as np

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def run_worker(payload: Dict, n_devices: int = 16, timeout: int = 2400) -> Dict:
    """Run benchmarks/worker.py in a subprocess with forced device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = _SRC
    worker = os.path.join(os.path.dirname(__file__), "worker.py")
    r = subprocess.run([sys.executable, worker], input=json.dumps(payload),
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    if r.returncode != 0:
        raise RuntimeError(f"worker failed: {r.stderr[-2000:]}")
    return json.loads(r.stdout.splitlines()[-1])


_PHASES = ("wire_transpose", "wire_expand", "wire_fold", "wire_rotate",
           "wire_updates")


def sweep_decompositions(scale: int, grid, n_devices: int = 16,
                         roots: int = 4, **payload_kw) -> List[Dict]:
    """Run the same R-MAT graph through both decompositions on the same
    device count (1D uses p = pr*pc strips) and emit one CSV row per
    decomposition with TEPS + per-phase wire counters — the measured
    side of the paper's Eq. 2 comparison."""
    out = []
    for decomp in ("1d", "2d"):
        res = run_worker({"scale": scale, "grid": list(grid),
                          "roots": roots, "decomposition": decomp,
                          **payload_kw}, n_devices=n_devices)
        ctr = res["counters"] or {}
        phases = ";".join(f"{k}={ctr.get(k, 0.0):.3e}" for k in _PHASES)
        emit(f"bfs_s{scale}_{decomp}_{grid[0]}x{grid[1]}",
             res["hmean_s"] * 1e6, f"teps={res['teps']:.3e};{phases}")
        out.append(res)
    return out
