"""Benchmark subprocess worker: runs BFS configurations on a forced
multi-device host platform and reports timings + counters as JSON.

Uses the plan/compile/run session API (repro.core.engine): the graph is
shipped and the search program compiled exactly once (``compile_s`` /
``ship_s`` in the output), then every root is pure traversal time — the
paper's §7 methodology without hand-rolled device_put/warmup loops."""
import json
import sys
import time

import numpy as np


def _monitor_from(payload):
    """Opt-in straggler detection over per-root wall times: payload
    ``straggler`` truthy enables it (a dict passes window/factor/
    min_samples through).  Events land in the timing summary."""
    opts = payload.get("straggler")
    if not opts:
        return None
    from repro.runtime.straggler import StragglerMonitor
    return StragglerMonitor(**(opts if isinstance(opts, dict) else {}))


def _monitor_block(monitor):
    if monitor is None:
        return {}
    return {"straggler_events": [
        {"step": s, "dt_s": dt, "p95_s": p95}
        for s, dt, p95 in monitor.events],
        "straggler_deadline_s": monitor.deadline}


def _build_store_phase(payload):
    from repro.ckpt.graph_store import GraphStore, plan_bfs_from_store
    from repro.configs.base import BFSConfig
    from repro.core.engine import plan_bfs
    from repro.graph.dist_build import BuildSpec, dist_build
    from repro.launch.mesh import make_local_mesh, make_local_mesh_1d

    pr, pc = payload["grid"]
    decomp = payload.get("decomposition", "1d")
    spec = BuildSpec(scale=payload["scale"],
                     edge_factor=payload.get("degree", 16),
                     seed=payload.get("seed", 1))
    cfg = BFSConfig(decomposition=decomp,
                    instrument=payload.get("instrument", False))
    store = GraphStore(payload["store_dir"])
    name = payload.get("name", f"s{spec.scale}-{decomp}")
    mesh = make_local_mesh_1d(pr * pc) if decomp in ("1d", "1ds") \
        else make_local_mesh(pr, pc)

    if payload["phase"] == "build":
        g, info = dist_build(spec, decomp, mesh, (pr, pc))
        t1 = time.perf_counter()
        store.save_graph(name, g, spec=spec)
        save_s = time.perf_counter() - t1
        plan = plan_bfs(g, cfg, mesh)
        eng = plan.compile(store=store)       # compiles + persists exec
        extra = {"build_s": info["build_s"], "save_s": save_s,
                 "gen_route_s": info["gen_route_s"],
                 "format_s": info["format_s"],
                 "build_teps": info["build_teps"],
                 "route_words_measured": info["route_words_measured"],
                 "route_words_expected": info["route_words_expected"],
                 "m": info["m"], "m_input": info["m_input"]}
    else:
        t2 = time.perf_counter()
        plan = plan_bfs_from_store(store, name, cfg, mesh,
                                   expect_spec=spec)
        load_s = time.perf_counter() - t2
        eng = plan.compile(store=store)       # exec from disk on hit
        g = plan.graph
        extra = {"load_s": load_s, "exec_load_s": eng.exec_load_s,
                 "exec_from_store": eng.exec_from_store,
                 "m": int(g.m), "m_input": int(g.m_input)}

    # born-sharded graphs have no host edge list: pick high-degree roots
    # from the (small) degree vector instead of random_source(edges)
    deg = np.asarray(g.deg_A).ravel()         # layout A ravel == global id
    roots = np.argsort(deg)[::-1][: payload.get("roots", 4)]
    t3 = time.perf_counter()
    out0 = eng.search(int(roots[0]))
    out0[0].block_until_ready()
    first_s = time.perf_counter() - t3        # includes dispatch warmup
    monitor = _monitor_from(payload)
    times = []
    for step, r in enumerate(roots):
        ta = time.perf_counter()
        out = eng.search(int(r))
        out[0].block_until_ready()
        times.append(time.perf_counter() - ta)
        if monitor is not None:
            monitor.observe(step, times[-1])
    hmean = len(times) / sum(1.0 / t for t in times)
    print(json.dumps({
        **extra, **_monitor_block(monitor),
        "phase": payload["phase"], "decomposition": decomp,
        "n_pad": g.part.n, "p": g.part.p,
        "compile_s": eng.compile_s, "ship_s": eng.ship_s,
        "first_traversal_s": first_s, "times": times, "hmean_s": hmean,
        "teps": extra["m_input"] / hmean,
        "to_first_traversal_s": (extra.get("build_s", 0.0)
                                 + extra.get("load_s", 0.0)
                                 + eng.ship_s + eng.compile_s
                                 + eng.exec_load_s + first_s),
    }))


def main():
    payload = json.loads(sys.stdin.read())
    from repro.configs.base import BFSConfig
    from repro.core.engine import plan_bfs
    from repro.core.ref import validate_parents
    from repro.graph.formats import build_blocked, build_blocked_1d
    from repro.graph.rmat import rmat_graph, scale_free_standin, random_source
    from repro.launch.mesh import make_local_mesh, make_local_mesh_1d

    if payload.get("phase") in ("build", "load"):
        # born-sharded build / store lanes: phase "build" generates the
        # graph ON DEVICE (no host edge list), persists graph +
        # executable to the shared store dir, and reports build TEPS;
        # phase "load" (a fresh process, so nothing is warm) measures
        # the disk -> first-traversal latency the store exists for.
        _build_store_phase(payload)
        return

    if payload.get("graph") == "twitter_standin":
        edges = scale_free_standin(payload["n"], payload["m"], seed=7)
    else:
        edges = rmat_graph(payload["scale"], payload.get("degree", 16),
                           seed=payload.get("seed", 1))
    pr, pc = payload["grid"]
    decomp = payload.get("decomposition", "2d")
    cfg = BFSConfig(decomposition=decomp,
                    storage=payload.get("storage", "dcsc"),
                    fold_mode=payload.get("fold_mode", "reduce"),
                    direction_optimizing=payload.get("diropt", True),
                    instrument=payload.get("instrument", True),
                    frontier_codec=payload.get("frontier_codec",
                                               BFSConfig.frontier_codec),
                    expand_chunks=payload.get("expand_chunks", 1))
    rng = np.random.default_rng(0)
    roots = [random_source(edges, rng) for _ in range(payload.get("roots", 4))]

    # 1d/1ds runs reuse the same grid spec as p = pr*pc strips so sweeps
    # pair up on identical graphs
    local_mode = payload.get("local_mode", "dense")
    if decomp in ("1d", "1ds"):
        # the uncompressed strip col_ptr is only materialized for the
        # kernel/csr comparison cell (O(n*p) host words by design)
        need_col_ptr = (local_mode == "kernel"
                        and cfg.storage == "csr")
        g = build_blocked_1d(edges, pr * pc, align=32, cap_pad=32,
                             with_col_ptr=need_col_ptr)
        mesh = make_local_mesh_1d(pr * pc)
    else:
        g = build_blocked(edges, pr, pc, align=32, cap_pad=32)
        mesh = make_local_mesh(pr, pc)
    plan = plan_bfs(g, cfg, mesh, local_mode=local_mode,
                    cap_f=payload.get("cap_f", 0),
                    cap_x=payload.get("cap_x", 0))
    eng = plan.compile()                  # ship once + jit once
    # one untimed warmup execution: AOT compile never runs the program,
    # so first-dispatch/allocation overhead must not land on root 0
    eng.search(int(roots[0]))[0].block_until_ready()

    if payload.get("compare_instrument"):
        # fair instrumented-vs-fast comparison: both engines in ONE
        # process, timing interleaved ABBA over reps so machine drift
        # cancels; report best-observed latency alongside the hmean
        # (forced-host-device runs are noisy — min is the stable
        # figure, and the artifact keeps the raw times).
        import dataclasses
        plan_f = plan_bfs(g, dataclasses.replace(cfg, instrument=False),
                          mesh, local_mode=local_mode,
                          cap_f=payload.get("cap_f", 0),
                          cap_x=payload.get("cap_x", 0))
        eng_f = plan_f.compile()
        eng_f.search(int(roots[0]))[0].block_until_ready()
        for r in roots:                   # parents parity sanity
            a = eng.to_result(eng.search(int(r)))
            b = eng_f.to_result(eng_f.search(int(r)))
            assert (a.parents == b.parents).all(), int(r)

        def timed(engine):
            ts = []
            for r in roots:
                t0 = time.perf_counter()
                out = engine.search(int(r))
                out[0].block_until_ready()
                ts.append(time.perf_counter() - t0)
            return ts

        t_i, t_f = [], []
        for _ in range(int(payload.get("reps", 3))):
            t_i += timed(eng)
            t_f += timed(eng_f)
            t_f += timed(eng_f)
            t_i += timed(eng)

        def block(engine, ts):
            hm = len(ts) / sum(1.0 / t for t in ts)
            return {"times": ts, "hmean_s": hm, "min_s": min(ts),
                    "teps": edges.m_input / hm,
                    "teps_best": edges.m_input / min(ts),
                    "compile_s": engine.compile_s,
                    "hlo_collectives": engine.collective_counts()}

        # "chunk_sweep": additionally compile the software-pipelined
        # fast engine per expand_chunks value, assert bit-identical
        # parents against the unpipelined fast engine, and ABBA-time it
        # against a resample of that baseline so chunked-vs-unchunked
        # latency is compared under the same machine drift
        chunked = {}
        for ec in payload.get("chunk_sweep", []):
            ec = int(ec)
            plan_c = plan_bfs(g, dataclasses.replace(cfg, instrument=False,
                                                     expand_chunks=ec),
                              mesh, local_mode=local_mode,
                              cap_f=payload.get("cap_f", 0),
                              cap_x=payload.get("cap_x", 0))
            eng_c = plan_c.compile()
            eng_c.search(int(roots[0]))[0].block_until_ready()
            for r in roots:
                a = eng_f.to_result(eng_f.search(int(r)))
                b = eng_c.to_result(eng_c.search(int(r)))
                assert (a.parents == b.parents).all(), (ec, int(r))
            t_c, t_b = [], []
            for _ in range(int(payload.get("reps", 3))):
                t_b += timed(eng_f)
                t_c += timed(eng_c)
                t_c += timed(eng_c)
                t_b += timed(eng_f)
            chunked[str(ec)] = {**block(eng_c, t_c),
                                "baseline_resample_min_s": min(t_b)}

        print(json.dumps({
            "m_input": edges.m_input, "m": edges.m, "n": edges.n,
            "n_pad": g.part.n, "p": g.part.p, "decomposition": decomp,
            "frontier_codec": cfg.frontier_codec,
            "expand_chunks": cfg.expand_chunks,
            "instrumented": block(eng, t_i), "fast": block(eng_f, t_f),
            **({"chunked": chunked} if chunked else {}),
        }))
        return

    monitor = _monitor_from(payload)
    times, counters = [], None
    for step, r in enumerate(roots):
        # time the device search only (block on parents), converting to
        # host results outside the timed region — same methodology as
        # the pre-engine hand-rolled loop
        t0 = time.perf_counter()
        out = eng.search(int(r))
        out[0].block_until_ready()
        times.append(time.perf_counter() - t0)
        if monitor is not None:
            monitor.observe(step, times[-1])
        res = eng.to_result(out)
        counters = res.counters
        if payload.get("validate"):
            ok, msg = validate_parents(edges.n, edges.src, edges.dst, int(r),
                                       res.parents)
            assert ok, msg
    hmean = len(times) / sum(1.0 / t for t in times)
    # both graph formats share the storage_words(mode) accounting API
    mem = {"mem_csr": g.storage_words("csr"),
           "mem_dcsc": g.storage_words("dcsc")}
    # per-level frontier sizes / modes / measured expand words from the
    # last root's search (the dense-vs-sparse expand crossover artifact)
    used = res.level_stats[:, 3] > 0
    levels = {"levels_n_f": res.level_stats[used, 0].tolist(),
              "levels_mode": res.level_stats[used, 2].tolist(),
              "levels_wire_expand": res.level_stats[used, 4].tolist()}
    print(json.dumps({
        "hmean_s": hmean, "times": times, "m_input": edges.m_input,
        "m": edges.m, "n": edges.n, "n_pad": g.part.n, "p": g.part.p,
        "cap_x": plan.statics.cap_x,
        "counters": counters, "decomposition": decomp,
        "instrument": cfg.instrument,
        "frontier_codec": cfg.frontier_codec,
        "expand_chunks": cfg.expand_chunks,
        # static collective schedule of the compiled search: the while
        # body appears once, so this is ~the per-level schedule plus
        # constant startup — the figure the fast path exists to shrink
        "hlo_collectives": eng.collective_counts(),
        "compile_s": eng.compile_s, "ship_s": eng.ship_s,
        "teps": edges.m_input / hmean, **levels, **mem,
        **_monitor_block(monitor),
    }))


if __name__ == "__main__":
    main()
