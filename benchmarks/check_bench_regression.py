"""Append-only bench-regression guard over BENCH_bfs.json.

``BENCH_bfs.json`` accumulates one ``points`` entry per landed perf PR
(benchmarks/bfs_bench.py --bench-out appends, never rewrites).  This
guard compares the NEWEST point against the PREVIOUS one and fails when
any decomposition+mode's best traversal time (``traverse_min_s``)
regresses by more than the threshold (default 25% — wide enough for
forced-host-device timing noise, tight enough to catch a real
schedule regression).

Variant names drift across points as the registry grows (point 0's
"1ds" became "1ds-raw"/"1ds-packed" when the codec split landed), so
only the (decomposition, mode) pairs present in BOTH points are
compared — a renamed or newly added variant is not a regression.

Run as:  python benchmarks/check_bench_regression.py [BENCH_bfs.json]
Exit status 1 on regression; prints one line per comparison.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Tuple


def _mins(point: dict) -> Dict[Tuple[str, str], float]:
    """{(decomposition, mode): traverse_min_s} of one bench point."""
    out = {}
    for name, variant in point.get("decompositions", {}).items():
        for mode in ("fast", "instrumented"):
            t = variant.get(mode, {}).get("traverse_min_s")
            if t is not None and t > 0:
                out[(name, mode)] = float(t)
    return out


def check_points(data: dict, threshold: float = 0.25) -> List[str]:
    """Regression messages comparing the newest point to the previous
    one; empty when clean (or when fewer than 2 points exist — a fresh
    trajectory has nothing to regress against)."""
    points = data.get("points", [])
    if len(points) < 2:
        return []
    prev, new = _mins(points[-2]), _mins(points[-1])
    msgs = []
    for key in sorted(set(prev) & set(new)):
        ratio = new[key] / prev[key]
        status = "REGRESSED" if ratio > 1.0 + threshold else "ok"
        print(f"{key[0]}/{key[1]}: {prev[key]:.6f}s -> {new[key]:.6f}s "
              f"({ratio:.3f}x) {status}")
        if ratio > 1.0 + threshold:
            msgs.append(
                f"{key[0]}/{key[1]} regressed {ratio:.3f}x "
                f"({prev[key]:.6f}s -> {new[key]:.6f}s, "
                f"threshold {1.0 + threshold:.2f}x)")
    return msgs


def main(argv) -> int:
    path = argv[1] if len(argv) > 1 else "BENCH_bfs.json"
    with open(path) as f:
        data = json.load(f)
    msgs = check_points(data)
    if msgs:
        for m in msgs:
            print("FAIL:", m, file=sys.stderr)
        return 1
    print(f"bench guard clean over {len(data.get('points', []))} points")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
