"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (schema required by the
deliverables).  Container reality: one CPU core, so wall-clock reflects
*algorithmic work* (the paper's direction-optimization wins show up
directly); cross-node *scaling* curves are derived from the paper's own
§6 alpha-beta model fed with our measured communication counters, and are
labeled ``modeled``.  Multi-device runs execute in subprocesses with a
forced host-device count so this process keeps 1 device.

  fig3  weak-scaling, top-down vs direction-optimizing      (measured)
  fig4  strong scaling across grid sizes                    (meas+model)
  fig5  platform comparison (Cray XE6/XK7/XC30 vs TPU v5e)  (modeled)
  fig6  DCSC vs CSR storage + search rate                   (measured)
  fig7  in-node multithreading analogue (rank granularity)  (modeled)
  fig8  process-grid skewness sweep                         (measured)
  tab1  communication-volume accounting vs closed forms     (measured)
  fig9  Twitter-standin real-graph validation               (measured)
"""
import sys
import time

import numpy as np

from benchmarks.bfs_bench import emit, run_worker


def fig3_weak_scaling():
    """TD vs DO as the graph grows.  NOTE: wall-us on this container times
    the *dense-vectorized* local step (frontier-independent work — the
    work-proportional path is the Pallas kernel, interpret-only on CPU),
    so the paper's Fig-3 quantity is the measured USEFUL-WORK speedup
    from the counters: edges actually needing examination."""
    for scale in (12, 13, 14):
        res = {}
        for diropt in (False, True):
            r = run_worker({"scale": scale, "grid": [4, 4],
                            "diropt": diropt, "roots": 4, "validate": scale <= 13})
            res[diropt] = r
            name = f"fig3_weak_s{scale}_{'diropt' if diropt else 'topdown'}"
            emit(name, r["hmean_s"] * 1e6,
                 f"wallTEPS={r['teps']:.3e};"
                 f"edges_useful={r['counters']['edges_useful']:.3e}")
        work = (res[False]["counters"]["edges_useful"]
                / max(res[True]["counters"]["edges_useful"], 1))
        words = (sum(v for k, v in res[False]["counters"].items()
                     if k.startswith("use_"))
                 / max(sum(v for k, v in res[True]["counters"].items()
                           if k.startswith("use_")), 1))
        emit(f"fig3_weak_s{scale}_speedup", 0.0,
             f"work_speedup={work:.2f}x;comm_speedup={words:.2f}x"
             f";paper_claims=6.5-7.9x")


def fig4_strong_scaling():
    """Fixed graph, growing machine: measured local work + modeled comm."""
    from repro.core.comm_model import AlphaBeta
    r = run_worker({"scale": 14, "grid": [4, 4], "diropt": True, "roots": 4})
    ab = AlphaBeta()
    n, m = r["n"], r["m"]
    base_work_s = r["hmean_s"]
    for p_side in (8, 16, 32, 64, 128):
        p = p_side * p_side
        comm = (ab.expand_cost(n, p_side, p_side)
                + ab.fold_cost(m, p_side, p_side)
                + 4 * ab.bottomup_level_cost(n, p_side, p_side))
        work = base_work_s * 16 / p          # perfect local-work split
        t = max(comm, work) + 0.2 * min(comm, work)
        emit(f"fig4_strong_p{p}", t * 1e6,
             f"modeled_TEPS={r['m_input']/t:.3e}")


def fig5_platforms():
    """alpha-beta model across machines (paper Table 2 + our target)."""
    machines = {
        "xe6_hopper": dict(bw=49e9 / 24, lat=1.5e-6),
        "xk7_titan": dict(bw=52e9 / 16, lat=1.5e-6),
        "xc30_edison": dict(bw=104e9 / 24, lat=1.0e-6),
        "tpu_v5e": dict(bw=50e9, lat=1e-6),
    }
    n, m, s_b = 2 ** 26, 2 ** 30, 4
    for name, mc in machines.items():
        from repro.core.comm_model import AlphaBeta
        ab = AlphaBeta(alpha_n=mc["lat"], beta_n=1.0 / mc["bw"])
        t = (ab.expand_cost(n, 16, 16) + ab.fold_cost(m, 16, 16)
             + s_b * ab.bottomup_level_cost(n, 16, 16))
        emit(f"fig5_{name}", t * 1e6, f"modeled_comm_per_search_s={t:.4f}")


def fig6_dcsc_vs_csr():
    """Paper Fig 6: DCSC pays off in the hypersparse regime (big grids /
    sparse graphs); CSR wins when blocks are dense.  Both regimes shown."""
    for scale, deg, grid, regime in ((13, 16, [4, 4], "dense"),
                                     (14, 4, [8, 8], "hypersparse")):
        for storage in ("csr", "dcsc"):
            r = run_worker({"scale": scale, "degree": deg, "grid": grid,
                            "storage": storage, "roots": 3,
                            "fold_mode": "alltoall" if storage == "csr"
                            else "reduce"},
                           n_devices=grid[0] * grid[1])
            mem = r[f"mem_{storage}"]["total_i32"]
            emit(f"fig6_{regime}_{storage}", r["hmean_s"] * 1e6,
                 f"TEPS={r['teps']:.3e};storage_i32_words={mem}")
        ratio = r["mem_csr"]["pointer_i32"] / r["mem_dcsc"]["pointer_i32"]
        emit(f"fig6_{regime}_ptr_ratio", 0.0,
             f"csr_over_dcsc={ratio:.2f};paper=dcsc_wins_at_scale")


def fig7_multithreading():
    """Rank-granularity analogue: fewer, fatter ranks shrink collective
    participant counts (the paper's 15-17% multithreading win)."""
    from repro.core.comm_model import AlphaBeta
    ab = AlphaBeta()
    n, m = 2 ** 26, 2 ** 30
    for label, (pr, pc) in {"flat_ranks_24x24": (24, 24),
                            "chip_ranks_16x16": (16, 16),
                            "chip_ranks_8x8": (8, 8)}.items():
        t = ab.expand_cost(n, pr, pc) + ab.fold_cost(m, pr, pc) \
            + 4 * ab.bottomup_level_cost(n, pr, pc)
        emit(f"fig7_{label}", t * 1e6, f"modeled_comm_s={t:.4f}")


def fig8_grid_skewness():
    for pr, pc in ((1, 16), (2, 8), (4, 4), (8, 2), (16, 1)):
        r = run_worker({"scale": 14, "grid": [pr, pc], "diropt": True,
                        "roots": 3})
        wire = sum(v for k, v in r["counters"].items()
                   if k.startswith("wire_"))
        emit(f"fig8_grid_{pr}x{pc}", r["hmean_s"] * 1e6,
             f"TEPS={r['teps']:.3e};wire_words={wire:.3e}")


def table1_comm_volume():
    from repro.core import comm_model
    r_td = run_worker({"scale": 14, "grid": [4, 4], "diropt": False,
                       "roots": 3})
    r_do = run_worker({"scale": 14, "grid": [4, 4], "diropt": True,
                       "roots": 3})
    use = lambda r: sum(v for k, v in r["counters"].items()
                        if k.startswith("use_"))
    wt_model = comm_model.topdown_words(r_td["n"], r_td["m"], 4, 4)
    wb_model = comm_model.bottomup_words(r_do["n"], 4, 4, s_b=3)
    emit("tab1_topdown_useful_words", 0.0,
         f"measured={use(r_td):.3e};model_wt={wt_model:.3e}")
    emit("tab1_diropt_useful_words", 0.0,
         f"measured={use(r_do):.3e};model_wb={wb_model:.3e}")
    k = r_td["m"] / r_td["n"]
    emit("tab1_eq2_ratio", 0.0,
         f"measured={use(r_td)/max(use(r_do),1):.1f};"
         f"eq2={comm_model.ratio_eq2(k, 4, 3):.1f}")
    for key, v in sorted(r_do["counters"].items()):
        emit(f"tab1_ctr_{key}", 0.0, f"words={v:.3e}")


def fig9_twitter_standin():
    """Real-graph validation (Twitter replaced by an offline scale-free
    standin of matching skew; see DESIGN.md assumption 5)."""
    r_do = run_worker({"graph": "twitter_standin", "n": 1 << 15,
                       "m": 1 << 19, "grid": [4, 4], "diropt": True,
                       "roots": 4, "validate": True})
    r_td = run_worker({"graph": "twitter_standin", "n": 1 << 15,
                       "m": 1 << 19, "grid": [4, 4], "diropt": False,
                       "roots": 4})
    emit("fig9_twitter_diropt", r_do["hmean_s"] * 1e6,
         f"wallTEPS={r_do['teps']:.3e};"
         f"edges_useful={r_do['counters']['edges_useful']:.3e}")
    emit("fig9_twitter_topdown", r_td["hmean_s"] * 1e6,
         f"wallTEPS={r_td['teps']:.3e};"
         f"edges_useful={r_td['counters']['edges_useful']:.3e}")
    work = (r_td["counters"]["edges_useful"]
            / max(r_do["counters"]["edges_useful"], 1))
    emit("fig9_cores_for_0.2s", 0.0,
         f"economic_ratio={work:.2f}x_fewer_cores_for_same_work")


ALL = [fig3_weak_scaling, fig4_strong_scaling, fig5_platforms,
       fig6_dcsc_vs_csr, fig7_multithreading, fig8_grid_skewness,
       table1_comm_volume, fig9_twitter_standin]


def main() -> None:
    print("name,us_per_call,derived")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for fn in ALL:
        if only and only not in fn.__name__:
            continue
        t0 = time.time()
        fn()
        print(f"# {fn.__name__} done in {time.time()-t0:.0f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
