"""Host data pipeline: deterministic, step-indexed synthetic streams with
double-buffered device prefetch.

Step-indexed determinism matters for fault tolerance: after a restart the
iterator is reconstructed at the resume step and yields bit-identical
batches, so checkpoint/restart is exactly reproducible (tested in
tests/test_runtime.py)."""
from __future__ import annotations

import itertools
import queue
import threading
from typing import Callable, Dict, Iterator

import jax
import numpy as np

from repro.configs.base import LMConfig, RecsysConfig


def lm_batch(cfg: LMConfig, batch: int, seq: int, step: int,
             seed: int = 0) -> Dict[str, np.ndarray]:
    """Zipf-ish synthetic token stream (deterministic per step)."""
    rng = np.random.default_rng((seed, step))
    z = rng.zipf(1.3, size=(batch, seq + 1))
    toks = (z % cfg.vocab).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def recsys_batch(cfg: RecsysConfig, batch: int, step: int,
                 seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng((seed, step))
    cols = [rng.integers(0, v, batch) for v in cfg.vocab_sizes]
    idx = np.stack(cols, 1).astype(np.int32)
    w = rng.normal(size=(cfg.n_sparse,))
    logit = (idx % 7 - 3) @ w / cfg.n_sparse
    labels = (logit + rng.normal(size=batch) * 0.5 > 0).astype(np.float32)
    return {"idx": idx, "labels": labels}


def step_stream(make: Callable[[int], Dict[str, np.ndarray]],
                start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    for step in itertools.count(start_step):
        yield make(step)


class DevicePrefetcher:
    """One-deep background prefetch: overlaps host batch synthesis +
    device_put with the previous step's compute."""

    def __init__(self, it: Iterator, sharding=None, depth: int = 2):
        self._it = it
        self._sharding = sharding
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = False
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        for batch in self._it:
            if self._stop:
                return
            if self._sharding is not None:
                batch = jax.device_put(batch, self._sharding)
            self._q.put(batch)

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop = True
