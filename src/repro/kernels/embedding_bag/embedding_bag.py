"""Pallas TPU kernel: table-batched EmbeddingBag (gather + bag-reduce).

The hot recsys op (FBGEMM TBE): for each bag, gather L rows of the
embedding table and reduce.  Tiled over batch; the row gather is a VMEM
vector gather (interpret-validated; the HBM-streaming variant keeps the
same grid and swaps the table BlockSpec for a scalar-prefetch index map).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, w_ref, table_ref, out_ref, *, bt: int, L: int,
            mean: bool):
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    wsum = jnp.zeros((bt,), jnp.float32)
    for j in range(L):
        ids = ids_ref[:, j]
        valid = ids >= 0
        rows = table_ref[jnp.where(valid, ids, 0), :]
        w = w_ref[:, j] * valid.astype(jnp.float32)
        acc += rows.astype(jnp.float32) * w[:, None]
        wsum += w
    if mean:
        acc = acc / jnp.maximum(wsum, 1e-9)[:, None]
    out_ref[...] = acc.astype(out_ref.dtype)


def embedding_bag_kernel(table, bag_ids, bag_weights=None, mode: str = "sum",
                         bt: int = 128, interpret: bool = True):
    B, L = bag_ids.shape
    V, D = table.shape
    bt = min(bt, B)
    assert B % bt == 0, (B, bt)
    if bag_weights is None:
        bag_weights = jnp.ones((B, L), jnp.float32)
    return pl.pallas_call(
        functools.partial(_kernel, bt=bt, L=L, mean=(mode == "mean")),
        grid=(B // bt,),
        in_specs=[
            pl.BlockSpec((bt, L), lambda b: (b, 0)),
            pl.BlockSpec((bt, L), lambda b: (b, 0)),
            pl.BlockSpec((V, D), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(bag_ids, bag_weights.astype(jnp.float32), table)
