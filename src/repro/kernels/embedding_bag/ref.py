"""Pure-jnp oracle for the table-batched EmbeddingBag."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag(table, bag_ids, bag_weights=None, mode: str = "sum"):
    """table (V, D); bag_ids (B, L) with -1 padding -> (B, D)."""
    valid = bag_ids >= 0
    safe = jnp.where(valid, bag_ids, 0)
    vals = jnp.take(table, safe, axis=0)            # (B, L, D)
    w = valid.astype(table.dtype)
    if bag_weights is not None:
        w = w * bag_weights
    out = jnp.sum(vals * w[..., None], axis=1)
    if mode == "mean":
        out = out / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return out
