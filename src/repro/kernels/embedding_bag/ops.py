"""Jitted wrapper for the EmbeddingBag kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.embedding_bag.embedding_bag import embedding_bag_kernel


@functools.partial(jax.jit, static_argnames=("mode", "bt", "interpret"))
def embedding_bag(table, bag_ids, bag_weights=None, mode: str = "sum",
                  bt: int = 128, interpret: bool = True):
    return embedding_bag_kernel(table, bag_ids, bag_weights, mode=mode,
                                bt=bt, interpret=interpret)
