"""Pure-jnp oracle for the "1ds" frontier codec: fixed-width bit-packed
local offsets with a count prefix.

The sparse 1D exchange ships each owner's frontier as a bucket of ids.
Raw buckets spend a whole 32-bit lane per id, but an owner only ever
ships vertices from its OWN chunk (1D discoveries are locally owned), so
the local offset fits in ``bits = ceil(log2(chunk))`` bits — the
receiver re-adds ``k * chunk`` because bucket position k in the tiled
allgather identifies the owner.  The encoding is:

    word 0            uint32 live-id count for this bucket
    words 1..W        the cap_x offsets bit-packed at ``bits`` bits each
                      (W = ceil(cap_x * bits / 32)); slots >= count are
                      packed as 0 and ignored by the decoder

``bits`` is static (chunk is a partition constant), so encode and decode
are pure vectorized gathers: packed bit b is bit (b % bits) of offset
b // bits — no variable-length scan, unlike a delta-varint stream whose
decode is inherently sequential.  Compression is 32/bits (~3x at
chunk=1024) on the physical buffer and 64/bits on the modeled id words
(``comm_model.compressed_expand_1d_words``).

The count prefix exists for correctness, not just accounting: a
sentinel IN the value domain cannot work, because offset ``chunk``
would decode in bucket k as global id (k+1)*chunk — a valid vertex
owned by the next processor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.comm_model import codec_bits, codec_packed_words


def encode_offsets(off: jax.Array, count: jax.Array, chunk: int
                   ) -> jax.Array:
    """(cap,) i32 sorted local offsets (sentinel-padded past ``count``)
    + scalar live count -> (1 + ceil(cap*bits/32),) uint32 count-prefixed
    bit-packed bucket."""
    cap = off.shape[0]
    bits = codec_bits(chunk)
    w = codec_packed_words(cap, bits)
    count = jnp.minimum(jnp.asarray(count, jnp.uint32), jnp.uint32(cap))
    slot = jnp.arange(cap, dtype=jnp.uint32)
    v = jnp.where(slot < count, off.astype(jnp.uint32), jnp.uint32(0))
    # packed bit b = bit (b % bits) of offset b // bits — one gather,
    # no cross-word shift hazards
    b = jnp.arange(w * 32, dtype=jnp.uint32)
    s = b // jnp.uint32(bits)
    bit = (v[jnp.minimum(s, jnp.uint32(cap - 1))] >> (b % jnp.uint32(bits))
           ) & jnp.uint32(1)
    bit = jnp.where(s < cap, bit, jnp.uint32(0))
    words = jnp.sum(bit.reshape(w, 32) << jnp.arange(32, dtype=jnp.uint32),
                    axis=1, dtype=jnp.uint32)
    return jnp.concatenate([count.reshape(1), words])


def decode_buckets(recv: jax.Array, chunk: int, cap: int, n: int
                   ) -> jax.Array:
    """(p * (1 + W),) uint32 allgathered buckets -> (p * cap,) i32 global
    ids; slots past each bucket's count decode to the ``unpack_ids``
    drop sentinel ``n``.  Bucket position k identifies the owner, so the
    decoded offset is rebased by k * chunk."""
    bits = codec_bits(chunk)
    w = codec_packed_words(cap, bits)
    bufs = recv.reshape(-1, 1 + w)
    p = bufs.shape[0]
    counts = bufs[:, 0].astype(jnp.int32)                     # (p,)
    packed = bufs[:, 1:]                                      # (p, W)
    slot = jnp.arange(cap, dtype=jnp.uint32)
    t = jnp.arange(bits, dtype=jnp.uint32)
    b = slot[:, None] * jnp.uint32(bits) + t[None, :]         # (cap, bits)
    word = packed[:, b >> jnp.uint32(5)]                      # (p, cap, bits)
    bit = (word >> (b & jnp.uint32(31))[None]) & jnp.uint32(1)
    val = jnp.sum(bit << t[None, None, :], axis=-1).astype(jnp.int32)
    k = jnp.arange(p, dtype=jnp.int32)[:, None]
    ids = jnp.where(slot[None, :].astype(jnp.int32) < counts[:, None],
                    k * chunk + val, jnp.int32(n))
    return ids.reshape(-1)
