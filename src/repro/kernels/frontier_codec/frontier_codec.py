"""Pallas TPU kernels: the "1ds" frontier codec (bit-packed offsets).

Same math as the jnp oracle (ref.py) — count-prefixed fixed-width
bit-packing of sorted local offsets — restructured for the VPU:

  * Both directions are PER-BIT GATHERS with static shapes: packed bit b
    is bit (b % bits) of offset b // bits.  No cross-word variable
    shifts (every shift amount is < 32 by construction), no sequential
    carry between words — each of the W output words is an independent
    32-lane reduction, so encode vectorizes the way a delta-varint
    stream never could.
  * Encode runs as ONE program over the bucket (cap_x is small — the
    planned crossover capacity, not the chunk); decode runs a grid
    program per received bucket, rebasing offsets by the bucket's
    owner index k * chunk and emitting the ``unpack_ids`` drop
    sentinel ``n`` for slots past the bucket's count word.

Blocks are VMEM-resident with SMEM scalars, ``interpret=True`` by
default (CPU CI), matching kernels/bottomup.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.comm_model import codec_bits, codec_packed_words


def _encode_kernel(count_ref, off_ref, out_ref, *, cap: int, bits: int,
                   w: int):
    count = jnp.minimum(count_ref[0].astype(jnp.uint32), jnp.uint32(cap))
    slot = jnp.arange(cap, dtype=jnp.uint32)
    v = jnp.where(slot < count, off_ref[...].astype(jnp.uint32),
                  jnp.uint32(0))
    b = jnp.arange(w * 32, dtype=jnp.uint32)
    s = b // jnp.uint32(bits)
    bit = (v[jnp.minimum(s, jnp.uint32(cap - 1))] >> (b % jnp.uint32(bits))
           ) & jnp.uint32(1)
    bit = jnp.where(s < cap, bit, jnp.uint32(0))
    words = jnp.sum(bit.reshape(w, 32) << jnp.arange(32, dtype=jnp.uint32),
                    axis=1, dtype=jnp.uint32)
    out_ref[0] = count
    out_ref[pl.ds(1, w)] = words


def encode_offsets_kernel(off, count, chunk: int, *,
                          interpret: bool = True):
    """(cap,) i32 local offsets + scalar live count -> (1+W,) uint32
    count-prefixed bit-packed bucket (W = ceil(cap*bits/32))."""
    cap = off.shape[0]
    bits = codec_bits(chunk)
    w = codec_packed_words(cap, bits)
    count = jnp.asarray(count, jnp.int32).reshape(1)
    return pl.pallas_call(
        functools.partial(_encode_kernel, cap=cap, bits=bits, w=w),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),        # count scalar
            pl.BlockSpec(off.shape, lambda: (0,)),        # offsets (VMEM)
        ],
        out_specs=pl.BlockSpec((1 + w,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((1 + w,), jnp.uint32),
        interpret=interpret,
    )(count, off)


def _decode_kernel(recv_ref, out_ref, *, cap: int, bits: int, w: int,
                   chunk: int, n: int):
    k = pl.program_id(0)
    base = k * (1 + w)
    count = recv_ref[base].astype(jnp.int32)
    packed = recv_ref[pl.ds(base + 1, w)]
    b = jnp.arange(cap * bits, dtype=jnp.uint32)              # slot-major
    bit = (packed[b >> jnp.uint32(5)] >> (b & jnp.uint32(31))
           ) & jnp.uint32(1)
    t = jnp.arange(bits, dtype=jnp.uint32)
    val = jnp.sum(bit.reshape(cap, bits) << t[None, :],
                  axis=1).astype(jnp.int32)
    slot = jnp.arange(cap, dtype=jnp.int32)
    out_ref[pl.ds(k * cap, cap)] = jnp.where(
        slot < count, k * chunk + val, jnp.int32(n))


def decode_buckets_kernel(recv, chunk: int, cap: int, n: int, p: int, *,
                          interpret: bool = True):
    """(p*(1+W),) uint32 allgathered buckets -> (p*cap,) i32 global ids
    (drop-sentinel ``n`` past each count), one grid program per bucket."""
    bits = codec_bits(chunk)
    w = codec_packed_words(cap, bits)
    return pl.pallas_call(
        functools.partial(_decode_kernel, cap=cap, bits=bits, w=w,
                          chunk=chunk, n=n),
        grid=(p,),
        in_specs=[pl.BlockSpec(recv.shape, lambda k: (0,))],
        out_specs=pl.BlockSpec((p * cap,), lambda k: (0,)),
        out_shape=jax.ShapeDtypeStruct((p * cap,), jnp.int32),
        interpret=interpret,
    )(recv.reshape(-1))
