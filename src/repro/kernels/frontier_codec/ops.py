"""Jitted wrappers for the frontier codec (Pallas kernels + jnp ref)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.frontier_codec.frontier_codec import (
    decode_buckets_kernel, encode_offsets_kernel)
from repro.kernels.frontier_codec.ref import (
    decode_buckets as decode_buckets_ref,
    encode_offsets as encode_offsets_ref)

# the jnp references ride along as part of the public surface so
# callers can A/B a kernel against its ref without a second import
__all__ = ["encode_offsets", "decode_buckets",
           "encode_offsets_ref", "decode_buckets_ref"]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def encode_offsets(off, count, chunk: int, interpret: bool = True):
    return encode_offsets_kernel(off, count, chunk, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "cap", "n", "p", "interpret"))
def decode_buckets(recv, chunk: int, cap: int, n: int, p: int,
                   interpret: bool = True):
    return decode_buckets_kernel(recv, chunk, cap, n, p,
                                 interpret=interpret)
