"""Jitted wrapper for the Flash attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, causal: bool = True, window=None,
                    q_offset: int = 0, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    return flash_attention_kernel(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, bq=bq, bk=bk,
                                  interpret=interpret)
