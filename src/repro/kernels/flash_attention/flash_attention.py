"""Pallas TPU kernel: blocked online-softmax (Flash) attention.

Grid (BH, Sq/BQ); each step streams KV in BK-wide tiles through a
``fori_loop`` with the running (m, l, acc) online-softmax state.  Causal
and sliding-window skips are *block-level*: tiles wholly outside the mask
are never visited (the loop's upper bound is the causal frontier; the
window lower bound advances with q) — the same tile-granular work
skipping used in the bottom-up BFS kernel, applied to attention.
MXU-aligned tile defaults (BQ=BK=128, dh multiple of 128 preferred).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, sk: int,
            causal: bool, window, q_offset: int, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, dh)
    q0 = q_offset + qi * bq
    qpos = q0 + jnp.arange(bq, dtype=jnp.int32)

    hi = sk if not causal else jnp.minimum(sk, q0 + bq)
    lo = 0 if window is None else jnp.maximum(0, q0 - (window - 1))
    lo_blk = (lo // bk) if window is not None else 0
    hi_blk = (hi + bk - 1) // bk

    def body(j, state):
        m, l, acc = state
        # index the leading block dim with a size-1 slice, not a literal
        # int: jax 0.4.x's interpret-mode load discharge only accepts
        # Slice/array indices.
        kj = pl.load(k_ref, (pl.ds(0, 1), pl.ds(j * bk, bk), slice(None)))[0]
        vj = pl.load(v_ref, (pl.ds(0, 1), pl.ds(j * bk, bk), slice(None)))[0]
        s = q @ kj.astype(jnp.float32).T               # (BQ, BK)
        kpos = j * bk + jnp.arange(bk, dtype=jnp.int32)
        mask = kpos[None, :] < sk
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + p @ vj.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, q.shape[-1]), jnp.float32)
    m, l, acc = lax.fori_loop(lo_blk, hi_blk, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window=None,
                           q_offset: int = 0, bq: int = 128, bk: int = 128,
                           interpret: bool = True):
    """q: (BH, Sq, dh); k, v: (BH, Sk, dh) -> (BH, Sq, dh)."""
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    sq_pad = ((Sq + bq - 1) // bq) * bq
    if sq_pad != Sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - Sq), (0, 0)))
    sk_pad = ((Sk + bk - 1) // bk) * bk
    if sk_pad != Sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - Sk), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, sk=Sk, causal=causal,
                          window=window, q_offset=q_offset,
                          scale=dh ** -0.5),
        grid=(BH, sq_pad // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, sk_pad, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, sk_pad, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, sq_pad, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
