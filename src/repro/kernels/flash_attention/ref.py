"""Pure-jnp oracle: plain softmax attention (causal / windowed)."""
from __future__ import annotations

import jax.numpy as jnp


def attention(q, k, v, *, causal: bool = True, window=None, q_offset: int = 0):
    """q: (BH, Sq, dh), k/v: (BH, Sk, dh) -> (BH, Sq, dh)."""
    Sq, Sk = q.shape[1], k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = _softmax(s)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def _softmax(s):
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0))
    return e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
