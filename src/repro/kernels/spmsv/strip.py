"""Pallas TPU kernel: strip SpMSV for the 1D row decomposition.

A 1D strip T[V_i, :] spans *every* global source column, so an
uncompressed CSC col_ptr costs n+1 words per processor — the O(n)
aggregate blow-up the paper's §5.1 charges against 1D compressed
storage, and the reason the 1D path was dense-only until now.  Strip
DCSC stores just the strip's non-empty global columns (``jc``) with
pointers (``cp``) into the CSC-ordered ``row_idx``, O(nzc) words.

The kernel walks ``jc`` — NOT the frontier — because nzc <= nnz is the
strip-local quantity while the frontier is global: for each non-empty
column slot it tests the column id against the allgathered frontier
*bitmap* (packed uint32 words, the same representation the 1D expand
allgathers), and gathers that column's contiguous segment in ET-wide
tiles, reusing the ragged-gather tiling of the 2D kernel (spmsv.py).
Skipped tiles (column not in frontier / beyond the segment) cost only
control overhead, so traffic ~ sum of frontier-column degrees.

As in the 2D split, the SPA accumulation (scatter-min of global source
ids, the paper's §5.2 sparse accumulator) stays outside the kernel where
XLA lowers it to a sorted segment reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _strip_gather_kernel(jc_ref, cp_ref, nzc_ref, fw_ref, ridx_ref, out_ref,
                         *, et: int, n: int):
    g = pl.program_id(0)          # non-empty-column slot
    t = pl.program_id(1)          # edge tile within the slot's segment
    u = jc_ref[g]                 # GLOBAL source column id (sentinel = n)
    uc = jnp.minimum(u, n - 1)
    w = fw_ref[uc >> 5]           # frontier bitmap word (uint32)
    in_f = ((w >> (uc.astype(jnp.uint32) & jnp.uint32(31))) & 1) == 1
    live = (g < nzc_ref[0]) & (u < n) & in_f
    s = cp_ref[g]
    ln = jnp.where(live, cp_ref[g + 1] - s, 0)
    off = t * et

    @pl.when(off < ln)
    def _():
        lane = jnp.arange(et, dtype=jnp.int32)
        v = pl.load(ridx_ref, (pl.ds(s + off, et),))
        out_ref[0, :] = jnp.where(off + lane < ln, v, jnp.int32(-1))

    @pl.when(off >= ln)
    def _():
        out_ref[0, :] = jnp.full((et,), -1, jnp.int32)


def gather_strip_segments(jc, cp, nzc, row_idx, f_words, *, maxdeg: int,
                          et: int = 256, interpret: bool = True):
    """(cap_nzc,) DCSC columns -> (cap_nzc, maxdeg) gathered dest rows of
    the columns present in the frontier bitmap, -1 padded.  row_idx must
    be padded by >= et beyond the last segment."""
    n = f_words.shape[0] * 32
    cap_nzc = jc.shape[0]
    maxdeg = ((max(maxdeg, 1) + et - 1) // et) * et
    grid = (cap_nzc, maxdeg // et)
    return pl.pallas_call(
        functools.partial(_strip_gather_kernel, et=et, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # jc
            pl.BlockSpec(memory_space=pltpu.SMEM),            # cp
            pl.BlockSpec(memory_space=pltpu.SMEM),            # nzc (1,)
            pl.BlockSpec(memory_space=pltpu.SMEM),            # frontier words
            pl.BlockSpec(row_idx.shape, lambda g, t: (0,)),   # edge ids (VMEM)
        ],
        out_specs=pl.BlockSpec((1, et), lambda g, t: (g, t)),
        out_shape=jax.ShapeDtypeStruct((cap_nzc, maxdeg), jnp.int32),
        interpret=interpret,
    )(jc.astype(jnp.int32), cp.astype(jnp.int32),
      jnp.asarray(nzc, jnp.int32).reshape(1), f_words, row_idx)


def _strip_gather_chunk_kernel(jc_ref, cp_ref, nzc_ref, fw_ref, ridx_ref,
                               out_ref, *, et: int, n: int, wpc: int,
                               w_sub: int, k: int):
    """Per-chunk entry of the strip gather: ``fw_ref`` is the RAW gathered
    sub-chunk buffer of one software-pipelined expand step — owner-major
    ``(p * w_sub,)`` u32 words covering owner-local word range
    [k*w_sub, (k+1)*w_sub) of each owner's ``wpc``-word strip — consumed
    directly, so no full-size frontier bitmap is ever materialized.  A
    column is live only when it falls inside sub-chunk k; the caller
    min-combines the per-chunk scatter results (exact under the
    (select-source, min) semiring)."""
    g = pl.program_id(0)          # non-empty-column slot
    t = pl.program_id(1)          # edge tile within the slot's segment
    u = jc_ref[g]                 # GLOBAL source column id (sentinel = n)
    uc = jnp.minimum(u, n - 1)
    wi = uc >> 5                  # global packed-word index
    owner = wi // wpc
    lw = wi - owner * wpc         # word index within the owner's strip
    in_rng = (lw >= k * w_sub) & (lw < (k + 1) * w_sub)
    pos = jnp.where(in_rng, owner * w_sub + (lw - k * w_sub), 0)
    w = fw_ref[pos]
    in_f = ((w >> (uc.astype(jnp.uint32) & jnp.uint32(31))) & 1) == 1
    live = (g < nzc_ref[0]) & (u < n) & in_rng & in_f
    s = cp_ref[g]
    ln = jnp.where(live, cp_ref[g + 1] - s, 0)
    off = t * et

    @pl.when(off < ln)
    def _():
        lane = jnp.arange(et, dtype=jnp.int32)
        v = pl.load(ridx_ref, (pl.ds(s + off, et),))
        out_ref[0, :] = jnp.where(off + lane < ln, v, jnp.int32(-1))

    @pl.when(off >= ln)
    def _():
        out_ref[0, :] = jnp.full((et,), -1, jnp.int32)


def gather_strip_segments_chunk(jc, cp, nzc, row_idx, f_sub, *, n: int,
                                p: int, k: int, n_chunks: int, maxdeg: int,
                                et: int = 256, interpret: bool = True):
    """Chunked variant of ``gather_strip_segments``: ``f_sub`` is the
    owner-major gathered sub-chunk words ``(p * w_sub,)`` of pipeline
    step ``k`` (of ``n_chunks``).  ``n`` and ``p`` are passed explicitly
    — the buffer no longer spans the full vertex range, so neither is
    derivable from its shape (every sub-chunk buffer is exactly
    (n/32)/n_chunks words regardless of p)."""
    wpc = (n // p) // 32                  # packed words per owner strip
    w_sub = wpc // n_chunks
    if f_sub.shape[0] != p * w_sub:
        raise ValueError(
            f"sub-chunk buffer has {f_sub.shape[0]} words, expected "
            f"p*w_sub = {p}*{w_sub} for n={n}, n_chunks={n_chunks}")
    cap_nzc = jc.shape[0]
    maxdeg = ((max(maxdeg, 1) + et - 1) // et) * et
    grid = (cap_nzc, maxdeg // et)
    return pl.pallas_call(
        functools.partial(_strip_gather_chunk_kernel, et=et, n=n, wpc=wpc,
                          w_sub=w_sub, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # jc
            pl.BlockSpec(memory_space=pltpu.SMEM),            # cp
            pl.BlockSpec(memory_space=pltpu.SMEM),            # nzc (1,)
            pl.BlockSpec(memory_space=pltpu.SMEM),            # sub-chunk words
            pl.BlockSpec(row_idx.shape, lambda g, t: (0,)),   # edge ids (VMEM)
        ],
        out_specs=pl.BlockSpec((1, et), lambda g, t: (g, t)),
        out_shape=jax.ShapeDtypeStruct((cap_nzc, maxdeg), jnp.int32),
        interpret=interpret,
    )(jc.astype(jnp.int32), cp.astype(jnp.int32),
      jnp.asarray(nzc, jnp.int32).reshape(1), f_sub, row_idx)
