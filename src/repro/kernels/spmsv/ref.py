"""Pure-jnp oracle for the top-down local discovery (Alg. 3, lines 8-10):
SpMSV in the (select-source, min) semiring over one 2D block.

The oracle is edge-parallel over the *whole* block (dense scan + masked
scatter-min) — work-inefficient but trivially correct; the kernel must
match it bit-for-bit on the candidate vector.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.frontier import INT_INF


def spmsv_dense(edge_src: jnp.ndarray,   # (cap,) i32 local source col, CSC order
                row_idx: jnp.ndarray,    # (cap,) i32 local dest row
                nnz: jnp.ndarray,        # scalar i32 true block nnz
                f_cj: jnp.ndarray,       # (nc,) bool frontier slice
                nr: int,
                col_offset: jnp.ndarray,  # scalar i32 j*nc
                ) -> jnp.ndarray:
    e_mask = jnp.arange(edge_src.shape[0]) < nnz
    active = e_mask & f_cj[edge_src]
    u_global = (col_offset + edge_src).astype(jnp.int32)
    vals = jnp.where(active, u_global, INT_INF)
    return jnp.full((nr,), INT_INF, jnp.int32).at[row_idx].min(vals)
