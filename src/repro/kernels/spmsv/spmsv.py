"""Pallas TPU kernel: frontier-driven adjacency gather for top-down BFS.

The paper's SpMSV reads only the adjacency lists of *frontier* vertices
(CSC/DCSC column segments) — work proportional to the frontier, not the
block.  On TPU we split the op:

  kernel : the irregular part — a ragged gather that walks each frontier
           vertex's contiguous CSC segment in ET-wide tiles, with
           ``@pl.when`` predication skipping tiles beyond the segment
           (the grid is (cap_f, maxdeg/ET); skipped steps cost only
           control overhead, so total traffic ~ sum of frontier degrees).
  XLA    : the SPA accumulation (scatter-min), which XLA lowers to a
           sorted segment reduction — the paper's sparse accumulator
           (§5.2) realized as a dense vector write, its recommended
           choice.

DCSC indirection (the paper's §5.1 hypersparse format) happens *outside*
the kernel: the column-pointer lookup goes through the (JC, CP) parallel
arrays with a binary search, reproducing DCSC's extra access cost that
Figure 6 measures.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(starts_ref, lens_ref, ridx_ref, out_ref, *, et: int):
    g = pl.program_id(0)          # frontier slot
    t = pl.program_id(1)          # edge tile within the slot's segment
    s = starts_ref[g]
    n = lens_ref[g]
    off = t * et

    @pl.when(off < n)
    def _():
        lane = jnp.arange(et, dtype=jnp.int32)
        v = pl.load(ridx_ref, (pl.ds(s + off, et),))
        out_ref[0, :] = jnp.where(off + lane < n, v, jnp.int32(-1))

    @pl.when(off >= n)
    def _():
        out_ref[0, :] = jnp.full((et,), -1, jnp.int32)


def gather_segments(starts, lens, row_idx, *, cap_f: int, maxdeg: int,
                    et: int = 256, interpret: bool = True):
    """(cap_f,) segment starts/lens -> (cap_f, maxdeg) gathered dest rows,
    -1 padded.  row_idx must be padded by >= et beyond the last segment."""
    maxdeg = ((maxdeg + et - 1) // et) * et
    grid = (cap_f, maxdeg // et)
    return pl.pallas_call(
        functools.partial(_gather_kernel, et=et),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),           # starts
            pl.BlockSpec(memory_space=pltpu.SMEM),           # lens
            pl.BlockSpec(row_idx.shape, lambda g, t: (0,)),  # edge ids (VMEM)
        ],
        out_specs=pl.BlockSpec((1, et), lambda g, t: (g, t)),
        out_shape=jax.ShapeDtypeStruct((cap_f, maxdeg), jnp.int32),
        interpret=interpret,
    )(starts.astype(jnp.int32), lens.astype(jnp.int32), row_idx)
