"""Jitted SpMSV wrappers: CSC and DCSC frontier-driven local discovery.

``spmsv_block_csr`` indexes column segments through the full col_ptr
(fast, O(n*pr) aggregate memory); ``spmsv_block_dcsc`` goes through the
compressed (JC, CP) arrays with a binary search per frontier vertex —
the paper's hypersparse trade-off (§5.1), reproduced faithfully.
``spmsv_strip_dcsc`` is the 1D counterpart: it walks the strip's
non-empty global columns against the allgathered frontier bitmap
(kernels/spmsv/strip.py), so no O(n) pointer array ever exists.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.frontier import INT_INF
from repro.kernels.spmsv.spmsv import gather_segments
from repro.kernels.spmsv.strip import (gather_strip_segments,
                                       gather_strip_segments_chunk)


def _scatter_min(dst, ids, col_offset, nr, cap_f):
    """(cap_f, maxdeg) gathered dest rows + frontier ids -> candidates."""
    parent = (col_offset + ids).astype(jnp.int32)[:, None]
    valid = dst >= 0
    vals = jnp.where(valid, jnp.broadcast_to(parent, dst.shape), INT_INF)
    flat_dst = jnp.where(valid, dst, 0).reshape(-1)
    return jnp.full((nr,), INT_INF, jnp.int32).at[flat_dst].min(
        vals.reshape(-1))


def frontier_ids(f_cj: jnp.ndarray, cap_f: int, nc: int):
    ids = jnp.where(f_cj, size=cap_f, fill_value=nc)[0].astype(jnp.int32)
    return ids, ids < nc


def spmsv_block_csr(col_ptr, row_idx, f_cj, nr: int, col_offset,
                    *, cap_f: int, maxdeg: int, interpret: bool = True):
    nc = f_cj.shape[0]
    ids, live = frontier_ids(f_cj, cap_f, nc)
    idc = jnp.minimum(ids, nc - 1)
    starts = col_ptr[idc]
    lens = jnp.where(live, col_ptr[idc + 1] - starts, 0)
    dst = gather_segments(starts, lens, row_idx, cap_f=cap_f,
                          maxdeg=maxdeg, interpret=interpret)
    return _scatter_min(dst, ids, col_offset, nr, cap_f)


def spmsv_strip_dcsc(jc, cp, nzc, row_idx, f_words, nr: int,
                     *, maxdeg: int, interpret: bool = True):
    """1D strip SpMSV over doubly compressed global source columns: the
    kernel walks the nzc slots, bitmap-testing each column against the
    allgathered frontier, so there is no per-frontier-vertex lookup and
    no O(n) pointer array.  Column ids are already global (col_offset is
    structurally 0 in the strip layout)."""
    dst = gather_strip_segments(jc, cp, nzc, row_idx, f_words,
                                maxdeg=maxdeg, interpret=interpret)
    # sentinel slots (jc = n) gather nothing, so their parent value is
    # never scattered; col_offset=0 keeps the ids global
    return _scatter_min(dst, jc, jnp.int32(0), nr, jc.shape[0])


def spmsv_strip_dcsc_chunk(jc, cp, nzc, row_idx, f_sub, nr: int, *, n: int,
                           p: int, k: int, n_chunks: int, maxdeg: int,
                           interpret: bool = True):
    """Software-pipelined strip SpMSV step: consume ONE gathered
    sub-chunk of the chunked expand (owner-major ``(p * w_sub,)`` u32
    words covering owner-local word range [k*w_sub, (k+1)*w_sub)) with
    no full-size frontier bitmap ever built.  The caller min-combines
    the per-chunk candidates — exact, since the scatter below is a MIN
    over global source ids."""
    dst = gather_strip_segments_chunk(jc, cp, nzc, row_idx, f_sub, n=n, p=p,
                                      k=k, n_chunks=n_chunks, maxdeg=maxdeg,
                                      interpret=interpret)
    return _scatter_min(dst, jc, jnp.int32(0), nr, jc.shape[0])


def spmsv_block_dcsc(jc, cp, nzc, row_idx, f_cj, nr: int, col_offset,
                     *, cap_f: int, maxdeg: int, interpret: bool = True):
    nc = f_cj.shape[0]
    ids, live = frontier_ids(f_cj, cap_f, nc)
    # binary search in the compressed column ids (the DCSC indirection)
    pos = jnp.searchsorted(jc, ids).astype(jnp.int32)
    pos = jnp.minimum(pos, jc.shape[0] - 1)
    found = live & (jc[pos] == ids) & (pos < nzc)
    starts = jnp.where(found, cp[pos], 0)
    lens = jnp.where(found, cp[pos + 1] - cp[pos], 0)
    dst = gather_segments(starts, lens, row_idx, cap_f=cap_f,
                          maxdeg=maxdeg, interpret=interpret)
    return _scatter_min(dst, ids, col_offset, nr, cap_f)
