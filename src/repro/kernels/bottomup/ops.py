"""Jitted wrapper for the bottom-up sub-step kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.bottomup.bottomup import bottomup_substep_kernel
from repro.kernels.bottomup.ref import bottomup_substep as substep_ref

# the jnp reference rides along as part of the public surface so
# callers can A/B the kernel against its ref without a second import
__all__ = ["bottomup_substep", "substep_ref"]


@functools.partial(jax.jit, static_argnames=("rt", "et", "interpret"))
def bottomup_substep(rp_seg, ue_win, f_words, cvec, col_offset, n_edges,
                     rt: int = 128, et: int = 512, interpret: bool = True):
    return bottomup_substep_kernel(rp_seg, ue_win, f_words, cvec, col_offset,
                                   n_edges, rt=rt, et=et, interpret=interpret)
