"""Pure-jnp oracle for the bottom-up sub-step (Alg. 4, lines 10-16).

Given one rotating segment of ``chunk`` rows (window-rebased CSR pointers
``rp_seg`` and the source-column window ``ue_win``), a packed frontier
bitmap over the block's column range, and the completed mask, produce the
segment's newly-discovered parents (global source ids; INT_INF = none).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.frontier import INT_INF, test_bits


def bottomup_substep(rp_seg: jnp.ndarray,   # (chunk+1,) i32, rebased to window
                     ue_win: jnp.ndarray,   # (cap_seg,) i32 local source cols
                     f_words: jnp.ndarray,  # (nc//32,) u32 frontier bitmap
                     cvec: jnp.ndarray,     # (chunk,) i32/bool completed
                     col_offset: jnp.ndarray,  # scalar i32: j*nc
                     n_edges: jnp.ndarray,     # scalar i32: window edge count
                     ve_win=None,           # (cap_seg,) i32 per-edge row - row0
                     ) -> jnp.ndarray:
    """ve_win (precomputed per-edge local rows, the CSR edge_dst array)
    replaces the O(E log V) searchsorted with a direct O(E) read — the
    §Perf BFS memory-term optimization (iteration 2)."""
    chunk = rp_seg.shape[0] - 1
    cap = ue_win.shape[0]
    eidx = jnp.arange(cap, dtype=jnp.int32)
    valid = eidx < n_edges
    if ve_win is None:
        # row of each window edge (CSR order => rows nondecreasing)
        erow = jnp.searchsorted(rp_seg, eidx,
                                side="right").astype(jnp.int32) - 1
        erow = jnp.clip(erow, 0, chunk - 1)
    else:
        erow = jnp.clip(ve_win, 0, chunk - 1)
    notdone = (cvec == 0)[erow]
    in_frontier = test_bits(f_words, ue_win)
    hit = valid & notdone & in_frontier
    vals = jnp.where(hit, col_offset + ue_win, INT_INF).astype(jnp.int32)
    out = jnp.full((chunk,), INT_INF, jnp.int32).at[erow].min(
        jnp.where(hit, vals, INT_INF))
    # completed rows can't be rediscovered
    return jnp.where(cvec != 0, INT_INF, out)
