"""Pallas TPU kernel: bottom-up BFS sub-step (Alg. 4, lines 10-16).

TPU adaptation of the paper's serialized inner loop:

  * The per-vertex "scan neighbors until a parent is found, then stop"
    early exit is hostile to SIMD, so it is restructured at *tile*
    granularity: a VMEM-resident row tile (RT rows) scans its contiguous
    CSR edge window in ET-edge tiles inside a ``lax.while_loop`` whose
    predicate stops as soon as EVERY live row in the tile has found a
    parent (or the window is exhausted).  The work skip the paper gets
    from ``break`` is preserved — whole edge tiles are never touched once
    the row tile completes — while each tile step stays fully vectorized
    on the VPU (8x128 lanes).
  * Frontier membership is a packed uint32 bitmap held in VMEM (the
    paper's §4.3 "dense format compressed by a bitmap" — constant-time
    tests with zero network crossings); tests are vector gathers.
  * ``completed`` rows are masked out up front, so rotated-in work that
    earlier sub-steps finished is skipped, exactly like the paper's c
    bitmap filter.

Blocks are VMEM-resident (interpret-validated here; on a real TPU the
edge window would stream HBM->VMEM via a scalar-prefetch index map — the
grid/loop structure is unchanged).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INT_INF = 2**31 - 1  # python literal: pallas kernels must not capture arrays


def _kernel(meta_ref, rp_ref, ue_ref, fb_ref, c_ref, out_ref, *, rt: int,
            et: int):
    r = pl.program_id(0)
    row0 = r * rt
    ptr = rp_ref[pl.ds(row0, rt + 1)]            # (rt+1,) window-rebased
    tile_lo, tile_hi = ptr[0], ptr[rt]
    col_off = meta_ref[0]
    n_edges = meta_ref[1]
    completed = c_ref[pl.ds(row0, rt)] != 0      # (rt,)
    lanes = jnp.arange(rt, dtype=jnp.int32)

    def cond(state):
        t, par, found = state
        return (tile_lo + t * et < tile_hi) & jnp.logical_not(found.all())

    def body(state):
        t, par, found = state
        e0 = tile_lo + t * et
        eidx = e0 + jnp.arange(et, dtype=jnp.int32)
        ue = pl.load(ue_ref, (pl.ds(e0, et),))
        valid = (eidx < tile_hi) & (eidx < n_edges)
        # per-edge row via vectorized ptr compare (rows are sorted in CSR)
        erow = jnp.sum((eidx[:, None] >= ptr[None, 1:]).astype(jnp.int32),
                       axis=1)                                  # (et,)
        w = fb_ref[ue >> 5]
        in_f = ((w >> (ue.astype(jnp.uint32) & jnp.uint32(31))) & 1) == 1
        live = jnp.logical_not(found)[jnp.clip(erow, 0, rt - 1)]
        hit = valid & in_f & live
        val = jnp.where(hit, col_off + ue, jnp.int32(INT_INF))
        onehot = erow[:, None] == lanes[None, :]                # (et, rt)
        tile_min = jnp.min(
            jnp.where(onehot & hit[:, None], val[:, None],
                      jnp.int32(INT_INF)), axis=0)
        par = jnp.minimum(par, tile_min)
        return t + 1, par, par != INT_INF

    par0 = jnp.full((rt,), INT_INF, jnp.int32)
    _, par, _ = lax.while_loop(cond, body, (jnp.int32(0), par0, completed))
    out_ref[pl.ds(row0, rt)] = jnp.where(completed, INT_INF, par)


def bottomup_substep_kernel(rp_seg, ue_win, f_words, cvec, col_offset,
                            n_edges, *, rt: int = 128, et: int = 512,
                            interpret: bool = True):
    """(chunk+1,)(cap,)(ncw,)(chunk,) + scalars -> (chunk,) i32 parents."""
    chunk = rp_seg.shape[0] - 1
    rt = min(rt, chunk)
    assert chunk % rt == 0, (chunk, rt)
    meta = jnp.stack([jnp.asarray(col_offset, jnp.int32),
                      jnp.asarray(n_edges, jnp.int32)])
    grid = (chunk // rt,)
    return pl.pallas_call(
        functools.partial(_kernel, rt=rt, et=et),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),      # meta scalars
            pl.BlockSpec(rp_seg.shape, lambda r: (0,)),  # row ptrs (VMEM)
            pl.BlockSpec(ue_win.shape, lambda r: (0,)),  # edge window
            pl.BlockSpec(f_words.shape, lambda r: (0,)),  # frontier bitmap
            pl.BlockSpec(cvec.shape, lambda r: (0,)),    # completed
        ],
        out_specs=pl.BlockSpec(cvec.shape, lambda r: (0,)),
        out_shape=jax.ShapeDtypeStruct((chunk,), jnp.int32),
        interpret=interpret,
    )(meta, rp_seg, ue_win, f_words, cvec.astype(jnp.int32))
