"""Decoder-only LM family: dense + MoE, GQA, RoPE, optional SWA.

Distribution:
  * DP over ("pod","data"), TP over "model" (Megatron col/row splits via
    PartitionSpecs; XLA inserts the psum on row-parallel matmuls).
  * MoE uses *explicit expert parallelism*: a shard_map token exchange
    with lax.all_to_all along "model" — structurally the paper's *fold*
    step (owner-computes exchange with static capacity), see DESIGN.md
    §Arch-applicability.  When E < tp, each expert is co-owned by a
    tp-subgroup that splits d_ff (duplicated dispatch + partial-sum
    return).  A replicated-token EP-psum path serves decode (tiny token
    counts).
  * FSDP-style extra sharding of big weights over the dp axes for the
    MoE archs (specs produced here; XLA materializes the allgathers).

Layers are stacked (leading L dim) and scanned; remat is configurable.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import LMConfig
from repro.core.compat import shard_map
from repro.models.common import ShardCtx, chunked_attention, rms_norm, rope

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _split(key, n):
    return jax.random.split(key, n)


def init_params(cfg: LMConfig, key: jax.Array, dtype=None) -> Dict[str, Any]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    D, L = cfg.d_model, cfg.n_layers
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = _split(key, 12)

    def nrm(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32)
                * (fan_in ** -0.5)).astype(dtype)

    p = {
        "embed": nrm(ks[0], (cfg.vocab, D), D),
        "final_ln": jnp.ones((D,), jnp.float32),
        "wq": nrm(ks[1], (L, D, Hq * dh), D),
        "wk": nrm(ks[2], (L, D, Hkv * dh), D),
        "wv": nrm(ks[3], (L, D, Hkv * dh), D),
        "wo": nrm(ks[4], (L, Hq * dh, D), Hq * dh),
        "ln1": jnp.ones((L, D), jnp.float32),
        "ln2": jnp.ones((L, D), jnp.float32),
    }
    if cfg.moe is None:
        F = cfg.d_ff
        p["wg"] = nrm(ks[5], (L, D, F), D)
        p["wu"] = nrm(ks[6], (L, D, F), D)
        p["wd"] = nrm(ks[7], (L, F, D), F)
    else:
        E, Fe = cfg.moe.n_experts, cfg.moe.d_ff_expert
        p["router"] = nrm(ks[8], (L, D, E), D)
        p["wg_e"] = nrm(ks[9], (L, E, D, Fe), D)
        p["wu_e"] = nrm(ks[10], (L, E, D, Fe), D)
        p["wd_e"] = nrm(ks[11], (L, E, Fe, D), Fe)
    return p


def param_specs(cfg: LMConfig, ctx: ShardCtx) -> Dict[str, P]:
    """PartitionSpecs per parameter (see module docstring)."""
    tp = ctx.tp
    dp = ctx.dp
    tpn = ctx.tp_size
    head_tp = tp if (tp and cfg.n_heads % tpn == 0) else None
    kv_tp = tp if (tp and cfg.n_kv_heads % tpn == 0) else None
    # heads not divisible by tp (e.g. starcoder's 36): shard the d_model
    # contraction dim instead of replicating — replication would also
    # replicate the f32 optimizer moments (~8 bytes/param) and blow the
    # per-device HBM budget at 7B scale.
    d_tp = None if head_tp else tp
    dkv_tp = None if kv_tp else tp
    # FSDP: additionally shard the free d_model dim of the big matrices
    # over dp (params + optimizer moments scale down n_dev-way; XLA
    # inserts the per-layer allgather)
    fs = (dp if (getattr(cfg, "fsdp", False) and dp) else None)
    specs = {
        "embed": P(tp, None),
        "final_ln": P(None),
        "wq": P(None, d_tp, head_tp if head_tp else fs),
        "wk": P(None, dkv_tp, kv_tp if kv_tp else fs),
        "wv": P(None, dkv_tp, kv_tp if kv_tp else fs),
        "wo": P(None, head_tp if head_tp else tp, fs),
        "ln1": P(None, None),
        "ln2": P(None, None),
    }
    if cfg.moe is None:
        specs.update({"wg": P(None, fs, tp), "wu": P(None, fs, tp),
                      "wd": P(None, tp, fs)})
    else:
        E = cfg.moe.n_experts
        dpa = dp if dp else None
        if tp and E % tpn == 0:
            # EP over model, FSDP over dp on the D dim
            specs.update({
                "router": P(None, None, None),
                "wg_e": P(None, tp, dpa, None),
                "wu_e": P(None, tp, dpa, None),
                "wd_e": P(None, tp, None, dpa),
            })
        else:
            # E < tp: d_ff split over model, FSDP over dp on the D dim
            specs.update({
                "router": P(None, None, None),
                "wg_e": P(None, None, dpa, tp),
                "wu_e": P(None, None, dpa, tp),
                "wd_e": P(None, None, tp, dpa),
            })
    return specs


# ---------------------------------------------------------------------------
# MoE: explicit expert-parallel dispatch (the "fold" exchange)
# ---------------------------------------------------------------------------


def _moe_local_math(xs, wg, wu, wd):
    """xs: (E_loc, C, D) grouped tokens -> SwiGLU expert FFN."""
    g = jnp.einsum("ecd,edf->ecf", xs, wg)
    u = jnp.einsum("ecd,edf->ecf", xs, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


def moe_ep_shardmap(x, router_w, wg, wu, wd, cfg: LMConfig, ctx: ShardCtx,
                    capacity_mult: float = 1.0):
    """Token-exchange expert parallelism along the "model" axis.

    x: (T, D) tokens already sharded P((dp..., "model"), None) — i.e. the
    token batch is split across every device.  Returns same shape/sharding.
    """
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    tpn = ctx.tp_size
    if ctx.mesh is None or tpn == 1:
        return _moe_reference(x, router_w, wg, wu, wd, cfg)
    E_loc = max(E // tpn, 1)            # experts owned per device
    tp_sub = max(tpn // E, 1)           # devices co-owning one expert
    cf = cfg.moe.capacity_factor * capacity_mult

    def body(xl, rw, wgl, wul, wdl):
        # xl: (T_loc, D); wgl: (E_loc, D, Fl).  For tp_sub > 1 the caller
        # pre-reshaped weights to (E*tp_sub, D, F/tp_sub) so sharding dim 0
        # over "model" hands device r = e*tp_sub + sub exactly expert e's
        # sub-th F-chunk (a plain F-shard would strand half of each
        # expert's FFN on devices that never compute it).
        T_loc, D = xl.shape
        cap = int(max(8, np.ceil(T_loc * k * tp_sub * cf / tpn)))
        logits = xl.astype(jnp.float32) @ rw.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, choice = lax.top_k(probs, k)            # (T_loc, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        flat_e = choice.reshape(-1)                   # (T_loc*k,)
        # destination device group + local expert slot
        dest0 = (flat_e // E_loc) * tp_sub if tp_sub == 1 else flat_e * tp_sub
        e_loc = flat_e % E_loc
        # position of each (token,choice) within its (dest, e_loc) queue
        key = (dest0 * E_loc + e_loc).astype(jnp.int32)
        order = jnp.argsort(key, stable=True)
        sorted_key = key[order]
        # rank of each (token, choice) within its (dest, expert) group
        pos = jnp.zeros_like(key).at[order].set(
            jnp.arange(key.size, dtype=jnp.int32)
            - jnp.searchsorted(sorted_key, sorted_key, side="left").astype(
                jnp.int32))
        keep = pos < cap
        tok = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), k)

        outs = []
        for sub in range(tp_sub):
            dest = dest0 + sub
            # dropped (over-capacity) slots are routed out of bounds: JAX
            # scatter drops OOB updates, gather returns fill (masked below)
            slot = jnp.where(keep, e_loc * cap + pos, E_loc * cap)
            buf = jnp.zeros((tpn, E_loc * cap, D), xl.dtype)
            buf = buf.at[dest, slot].set(xl[tok])
            recv = lax.all_to_all(buf, "model", split_axis=0, concat_axis=0)
            xs = recv.reshape(tpn, E_loc, cap, D).transpose(1, 0, 2, 3)
            xs = xs.reshape(E_loc, tpn * cap, D)
            ys = _moe_local_math(xs, wgl, wul, wdl)
            ys = ys.reshape(E_loc, tpn, cap, D).transpose(1, 0, 2, 3)
            ys = ys.reshape(tpn, E_loc * cap, D)
            back = lax.all_to_all(ys, "model", split_axis=0, concat_axis=0)
            outs.append(back[dest, slot] * keep[:, None])
        contrib = sum(outs)                            # (T_loc*k, D)
        contrib = contrib.astype(jnp.float32) * gate.reshape(-1)[:, None]
        out = jnp.zeros((T_loc, D), jnp.float32).at[tok].add(contrib)
        return out.astype(xl.dtype)

    dpa = ctx.dp
    tok_spec = P((*dpa, "model"), None)
    if tp_sub > 1:
        # (E, D, F) -> (E*tp_sub, D, F/tp_sub): expert-major co-owner split
        Eg, D, F = wg.shape
        Fs = F // tp_sub
        wg = wg.reshape(Eg, D, tp_sub, Fs).transpose(0, 2, 1, 3).reshape(
            Eg * tp_sub, D, Fs)
        wu = wu.reshape(Eg, D, tp_sub, Fs).transpose(0, 2, 1, 3).reshape(
            Eg * tp_sub, D, Fs)
        wd = wd.reshape(Eg, tp_sub, Fs, D).reshape(Eg * tp_sub, Fs, D)
    wspec = P("model", None, None)
    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(tok_spec, P(None, None), wspec, wspec, wspec),
        out_specs=tok_spec, check_vma=False,
    )(x, router_w, wg, wu, wd)


def _moe_reference(x, router_w, wg, wu, wd, cfg: LMConfig):
    """Dense reference MoE (single device / smoke tests): exact top-k, no
    capacity drops."""
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, choice = lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(choice, E, dtype=x.dtype)   # (T, k, E)
    w = jnp.einsum("tk,tke->te", gate.astype(x.dtype), onehot)
    g = jnp.einsum("td,edf->tef", x, wg)
    u = jnp.einsum("td,edf->tef", x, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("tef,efd->ted", h, wd)
    return jnp.einsum("ted,te->td", y, w)


def moe_decode_psum(x, router_w, wg, wu, wd, cfg: LMConfig, ctx: ShardCtx):
    """Decode-path MoE: tokens replicated over "model"; each device applies
    its expert shard and a psum combines — no all_to_all for tiny T."""
    if ctx.mesh is None or ctx.tp_size == 1 or cfg.moe.n_experts < ctx.tp_size:
        return _moe_reference(x, router_w, wg, wu, wd, cfg)
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    tpn = ctx.tp_size
    E_loc = E // tpn

    def body(xl, rw, wgl, wul, wdl):
        T, D = xl.shape
        r = lax.axis_index("model")
        logits = xl.astype(jnp.float32) @ rw.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, choice = lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        mine = (choice >= r * E_loc) & (choice < (r + 1) * E_loc)
        out = jnp.zeros((T, D), jnp.float32)
        for e in range(E_loc):
            sel = (jnp.where(mine, choice - r * E_loc, -1) == e)
            wsum = jnp.sum(jnp.where(sel, gate, 0.0), axis=-1)  # (T,)
            g = xl @ wgl[e]
            u = xl @ wul[e]
            h = jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u
            y = (h @ wdl[e]).astype(jnp.float32)
            out = out + y * wsum[:, None]
        return lax.psum(out, "model").astype(xl.dtype)

    dpa = ctx.dp
    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(dpa if dpa else None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(dpa if dpa else None, None), check_vma=False,
    )(x, router_w, wg, wu, wd)


# ---------------------------------------------------------------------------
# Blocks + model passes
# ---------------------------------------------------------------------------


def _attn(h, lp, cfg: LMConfig, ctx: ShardCtx, q_offset, kv_cache=None,
          cache_pos=None, kv_chunk=1024):
    B, S, D = h.shape
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
    q = (hn @ lp["wq"]).reshape(B, S, Hq, dh)
    k = (hn @ lp["wk"]).reshape(B, S, Hkv, dh)
    v = (hn @ lp["wv"]).reshape(B, S, Hkv, dh)
    pos = q_offset + jnp.arange(S)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                             cache_pos, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                             cache_pos, axis=1)
        out = chunked_attention(q, ck, cv, q_offset=q_offset,
                                causal=True, window=cfg.swa_window,
                                kv_chunk=kv_chunk,
                                kv_valid_len=cache_pos + S)
        new_cache = (ck, cv)
    else:
        out = chunked_attention(q, k, v, q_offset=q_offset, causal=True,
                                window=cfg.swa_window, kv_chunk=kv_chunk)
        new_cache = None
    out = out.reshape(B, S, Hq * dh) @ lp["wo"]
    return h + out, new_cache


def _ffn(h, lp, cfg: LMConfig, ctx: ShardCtx, decode: bool = False):
    B, S, D = h.shape
    hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
    if cfg.moe is None:
        g = hn @ lp["wg"]
        u = hn @ lp["wu"]
        y = (jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u) @ lp["wd"]
        return h + y
    x = hn.reshape(B * S, D)
    if decode:
        y = moe_decode_psum(x, lp["router"], lp["wg_e"], lp["wu_e"],
                            lp["wd_e"], cfg, ctx)
    else:
        y = moe_ep_shardmap(x, lp["router"], lp["wg_e"], lp["wu_e"],
                            lp["wd_e"], cfg, ctx)
    return h + y.reshape(B, S, D)


def _stack_layers(params):
    keys = [k for k in params if k not in ("embed", "final_ln")]
    return {k: params[k] for k in keys}


def forward(params, tokens, cfg: LMConfig, ctx: ShardCtx, *, remat=True,
            kv_chunk=1024):
    """Full causal pass -> final hidden states (B, S, D)."""
    B, S = tokens.shape
    # sequence-parallel activation sharding (Megatron-SP): the remat-saved
    # per-layer h is S-sharded over "model", cutting saved-activation HBM
    # by tp at the cost of per-layer gathers inside attention.
    sp = ctx.tp if (ctx.tp and S % ctx.tp_size == 0 and S > 1) else None
    bspec = ctx.dp if ctx.dp else None
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    h = ctx.cons(h, bspec, sp, None)
    layers = _stack_layers(params)

    def block(h, lp):
        h, _ = _attn(h, lp, cfg, ctx, q_offset=0, kv_chunk=kv_chunk)
        h = _ffn(h, lp, cfg, ctx)
        h = ctx.cons(h, bspec, sp, None)
        return h, None

    policy = getattr(cfg, "remat_policy", "full")
    if not remat or policy == "none":
        blk = block
    elif policy == "dots":
        blk = jax.checkpoint(
            block,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        blk = jax.checkpoint(block)
    h, _ = lax.scan(blk, h, layers)
    return rms_norm(h, params["final_ln"], cfg.norm_eps)


def lm_loss(params, tokens, labels, cfg: LMConfig, ctx: ShardCtx,
            seq_chunk: int = 2048, remat: bool = True):
    """Causal-LM cross entropy with sequence-chunked logits (never
    materializes (B, S, V) at once)."""
    h = forward(params, tokens, cfg, ctx, remat=remat)
    B, S, D = h.shape
    emb = params["embed"]
    n_chunks = max(S // min(seq_chunk, S), 1)
    hs = h.reshape(B, n_chunks, S // n_chunks, D)
    ls = labels.reshape(B, n_chunks, S // n_chunks)

    def chunk_loss(carry, inp):
        hc, lc = inp
        if getattr(cfg, "loss_bf16", False):
            # bf16 operands, f32 accumulation: halves logits-path traffic
            logits = jnp.einsum("bsd,vd->bsv", hc, emb,
                                preferred_element_type=jnp.float32)
        else:
            logits = jnp.einsum("bsd,vd->bsv", hc.astype(jnp.float32),
                                emb.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = lax.scan(chunk_loss, jnp.float32(0),
                        (jnp.moveaxis(hs, 1, 0), jnp.moveaxis(ls, 1, 0)))
    return total / (B * S)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, tokens, cache, cfg: LMConfig, ctx: ShardCtx,
            kv_chunk: int = 1024):
    """Full-prompt pass that fills the KV cache; returns (cache, logits of
    the last position)."""
    B, S = tokens.shape
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    layers = _stack_layers(params)

    def block(h, lp_cache):
        lp, (ck, cv) = lp_cache
        h, new_kv = _attn(h, lp, cfg, ctx, q_offset=0,
                          kv_cache=(ck, cv), cache_pos=0, kv_chunk=kv_chunk)
        h = _ffn(h, lp, cfg, ctx)
        return h, new_kv

    h, (k_all, v_all) = lax.scan(block, h, (layers, (cache["k"], cache["v"])))
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", h[:, -1].astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    return {"k": k_all, "v": v_all}, logits


def decode_step(params, cache, token, pos, cfg: LMConfig, ctx: ShardCtx,
                kv_chunk: int = 2048):
    """One decode step: token (B, 1), pos scalar int32 (current length).
    Returns (cache, logits (B, V))."""
    h = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    layers = _stack_layers(params)

    def block(h, lp_cache):
        lp, (ck, cv) = lp_cache
        h, new_kv = _attn(h, lp, cfg, ctx, q_offset=pos,
                          kv_cache=(ck, cv), cache_pos=pos,
                          kv_chunk=kv_chunk)
        h = _ffn(h, lp, cfg, ctx, decode=True)
        return h, new_kv

    h, (k_all, v_all) = lax.scan(block, h, (layers, (cache["k"], cache["v"])))
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", h[:, -1].astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    return {"k": k_all, "v": v_all}, logits
