"""AutoInt (Song et al. 2019): multi-head self-attention over sparse-field
embeddings + residual, final MLP head.  Includes a two-tower retrieval
scorer for the retrieval_cand shape (batched dot, not a loop)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import RecsysConfig
from repro.models import embedding
from repro.models.common import ShardCtx


def init_params(cfg: RecsysConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4 + 4 * cfg.n_attn_layers)
    d, da, H = cfg.embed_dim, cfg.d_attn, cfg.n_heads
    p: Dict[str, Any] = {"table": embedding.init_table(cfg, ks[0])}
    din = d
    for l in range(cfg.n_attn_layers):
        p[f"wq{l}"] = jax.random.normal(ks[4 * l + 1], (din, H, da)) * (din ** -0.5)
        p[f"wk{l}"] = jax.random.normal(ks[4 * l + 2], (din, H, da)) * (din ** -0.5)
        p[f"wv{l}"] = jax.random.normal(ks[4 * l + 3], (din, H, da)) * (din ** -0.5)
        p[f"wres{l}"] = jax.random.normal(ks[4 * l + 4], (din, H * da)) * (din ** -0.5)
        din = H * da
    dims = (cfg.n_sparse * din, *cfg.mlp_hidden, 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        p[f"mlp_w{i}"] = jax.random.normal(ks[-1], (a, b)) * (a ** -0.5)
        p[f"mlp_b{i}"] = jnp.zeros((b,))
    return p


def interact(p, cfg: RecsysConfig, e):
    """e: (B, F, d) field embeddings -> (B, F, H*da) after attn layers."""
    x = e
    for l in range(cfg.n_attn_layers):
        q = jnp.einsum("bfd,dhk->bfhk", x, p[f"wq{l}"])
        k = jnp.einsum("bfd,dhk->bfhk", x, p[f"wk{l}"])
        v = jnp.einsum("bfd,dhk->bfhk", x, p[f"wv{l}"])
        s = jnp.einsum("bfhk,bghk->bhfg", q, k) / jnp.sqrt(float(cfg.d_attn))
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhfg,bghk->bfhk", a, v)
        o = o.reshape(*o.shape[:2], -1)
        x = jax.nn.relu(o + x @ p[f"wres{l}"])
    return x


def forward(p, cfg: RecsysConfig, idx, ctx: ShardCtx):
    """idx: (B, F) sparse-field indices -> (B,) logits."""
    rows = embedding.flat_indices(cfg, idx)
    e = embedding.lookup(p["table"], rows, ctx)          # (B, F, d)
    x = interact(p, cfg, e)
    flat = x.reshape(x.shape[0], -1)
    n_mlp = sum(1 for k in p if k.startswith("mlp_w"))
    for i in range(n_mlp):
        flat = flat @ p[f"mlp_w{i}"] + p[f"mlp_b{i}"]
        if i < n_mlp - 1:
            flat = jax.nn.relu(flat)
    return flat[:, 0]


def bce_loss(p, cfg: RecsysConfig, idx, labels, ctx: ShardCtx):
    logits = forward(p, cfg, idx, ctx)
    z = jax.nn.log_sigmoid(logits)
    zn = jax.nn.log_sigmoid(-logits)
    return -jnp.mean(labels * z + (1 - labels) * zn)


def user_tower(p, cfg: RecsysConfig, idx, ctx: ShardCtx):
    """Mean-pooled interacted fields -> (B, H*da) user vector."""
    rows = embedding.flat_indices(cfg, idx)
    e = embedding.lookup(p["table"], rows, ctx)
    return interact(p, cfg, e).mean(axis=1)


def retrieval_scores(user_vec, cand_table, ctx: ShardCtx):
    """(B, D) x (Ncand, D) -> (B, Ncand) batched dot (sharded over model)."""
    if ctx.mesh is not None:
        from jax import lax
        from jax.sharding import NamedSharding
        cand_table = lax.with_sharding_constraint(
            cand_table, NamedSharding(ctx.mesh, P("model", None)))
    return user_vec @ cand_table.T
