"""MACE: higher-order E(3)-equivariant message passing (Batatia et al.).

Faithful-in-structure implementation for l_max=2, correlation order 3:

  * real spherical harmonics Y_lm (l<=2, 9 components) of edge unit vecs
  * Bessel radial basis (n_rbf) x polynomial cutoff envelope -> radial MLP
    producing per-(channel, l) weights
  * first-order features  A_i = sum_j R(r_ij) * Y(r_hat_ij) * h_j
    (segment-sum aggregation, the paper's 2D-foldable primitive)
  * higher-order features via *Gaunt contractions*: real-basis coupling
    coefficients G[a,b,c] = Int Y_a Y_b Y_c dOmega are precomputed
    numerically (Gauss-Legendre x uniform-phi quadrature, exact for this
    bandwidth).  B2 = G(A, A), B3 = G(B2, A) — correlation order 3,
    intermediates capped at l<=2 like MACE's hidden irreps.
  * per-order, per-l channel mixing + residual update; invariant readout.

Simplification vs. the full paper (noted in DESIGN.md): messages are
built from sender *scalar* channels (MACE layer-1 behavior); node
features carry the full 9-component irrep stack across layers.
Equivariance is property-tested: rotating all positions leaves the
energy invariant (tests/test_models.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.gnn import seg_sum

N_LM = 9          # (l,m) pairs for l <= 2
_LM_L = np.array([0, 1, 1, 1, 2, 2, 2, 2, 2])   # l of each component


def real_sph_harm(u: jnp.ndarray) -> jnp.ndarray:
    """u: (..., 3) unit vectors -> (..., 9) real SH values, l=0,1,2."""
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    c0 = 0.28209479177387814
    c1 = 0.4886025119029199
    c2a = 1.0925484305920792
    c2b = 0.31539156525252005
    c2c = 0.5462742152960396
    return jnp.stack([
        jnp.full_like(x, c0),
        c1 * y, c1 * z, c1 * x,
        c2a * x * y, c2a * y * z, c2b * (3 * z * z - 1),
        c2a * x * z, c2c * (x * x - y * y),
    ], axis=-1)


def _real_sph_harm_np(u: np.ndarray) -> np.ndarray:
    """numpy twin of real_sph_harm (quadrature must not be staged by jax
    tracing — omnistaging would turn the table into a traced value)."""
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    c0, c1 = 0.28209479177387814, 0.4886025119029199
    c2a, c2b, c2c = 1.0925484305920792, 0.31539156525252005, 0.5462742152960396
    return np.stack([
        np.full_like(x, c0), c1 * y, c1 * z, c1 * x,
        c2a * x * y, c2a * y * z, c2b * (3 * z * z - 1),
        c2a * x * z, c2c * (x * x - y * y)], axis=-1)


@functools.lru_cache()
def gaunt_table() -> np.ndarray:
    """(9, 9, 9) real Gaunt coefficients via spherical quadrature."""
    nt, nphi = 32, 64
    xs, ws = np.polynomial.legendre.leggauss(nt)      # cos(theta) nodes
    phi = (np.arange(nphi) + 0.5) * (2 * np.pi / nphi)
    ct = xs[:, None]
    st = np.sqrt(1 - ct ** 2)
    x = st * np.cos(phi)[None, :]
    y = st * np.sin(phi)[None, :]
    z = np.broadcast_to(ct, x.shape)
    pts = np.stack([x, y, z], -1).reshape(-1, 3)
    w = (np.broadcast_to(ws[:, None], x.shape) * (2 * np.pi / nphi)).reshape(-1)
    Y = _real_sph_harm_np(pts)                         # (Q, 9)
    return np.einsum("qa,qb,qc,q->abc", Y, Y, Y, w)


def bessel_basis(d, n_rbf: int, r_cut: float):
    """Sinc-like Bessel radial basis with smooth polynomial cutoff."""
    d = jnp.maximum(d, 1e-9)[..., None]
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rb = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * d / r_cut) / d
    t = jnp.clip(d / r_cut, 0, 1)
    env = 1 - 10 * t ** 3 + 15 * t ** 4 - 6 * t ** 5   # p=3 poly cutoff
    return rb * env


def init_mace(cfg: GNNConfig, key, n_species: int = 16, n_out: int = 1):
    C, L = cfg.d_hidden, cfg.n_layers
    ks = jax.random.split(key, 4 * L + 3)
    p: Dict[str, Any] = {"embed": jax.random.normal(ks[0], (n_species, C)) * 0.5}
    for l in range(L):
        p[f"rad_w0_{l}"] = jax.random.normal(ks[4 * l + 1],
                                             (cfg.n_rbf, 32)) * 0.3
        p[f"rad_w1_{l}"] = jax.random.normal(ks[4 * l + 2], (32, C * 3)) * 0.2
        # channel mixes per correlation order (1, 2, 3) and per l (3)
        p[f"mix_{l}"] = jax.random.normal(ks[4 * l + 3], (3, 3, C, C)) * (
            C ** -0.5)
        p[f"upd_{l}"] = jax.random.normal(ks[4 * l + 4], (C, C)) * (C ** -0.5)
    p["out_w0"] = jax.random.normal(ks[-2], (C, C)) * (C ** -0.5)
    p["out_w1"] = jax.random.normal(ks[-1], (C, n_out)) * (C ** -0.5)
    return p


def _gaunt_contract(a, b, G):
    """a, b: (N, C, 9) -> (N, C, 9) equivariant product, capped at l<=2."""
    return jnp.einsum("nca,ncb,abk->nck", a, b, G)


def mace_forward(p, cfg: GNNConfig, species, pos, senders, receivers,
                 edge_mask, n: int, r_cut: float = 3.0):
    C = cfg.d_hidden
    G = jnp.asarray(gaunt_table(), jnp.float32)
    h = jnp.zeros((n, C, N_LM), jnp.float32)
    h = h.at[:, :, 0].set(p["embed"][species])
    lmap = _LM_L                      # concrete numpy (usable as bool index)

    rvec = pos[receivers] - pos[senders]
    d = jnp.linalg.norm(rvec + 1e-12, axis=-1)
    u = rvec / jnp.maximum(d, 1e-9)[:, None]
    Y = real_sph_harm(u)                                    # (E, 9)
    for l in range(cfg.n_layers):
        rb = bessel_basis(d, cfg.n_rbf, r_cut)              # (E, n_rbf)
        R = jax.nn.silu(rb @ p[f"rad_w0_{l}"]) @ p[f"rad_w1_{l}"]
        R = R.reshape(-1, C, 3)                             # (E, C, l)
        Rlm = R[:, :, lmap]                                 # (E, C, 9)
        msg = Rlm * Y[:, None, :] * h[senders][:, :, 0:1]
        msg = msg * edge_mask[:, None, None]
        A = seg_sum(msg, receivers, n)                      # (N, C, 9)
        B2 = _gaunt_contract(A, A, G)
        B3 = _gaunt_contract(B2, A, G)
        m = jnp.zeros_like(A)
        for o, feat in enumerate((A, B2, B3)):
            for li in range(3):
                sel = lmap == li
                mixed = jnp.einsum("ncm,cd->ndm", feat[:, :, sel],
                                   p[f"mix_{l}"][o, li])
                m = m.at[:, :, sel].add(mixed)
        h = h + m
        h = h.at[:, :, 0].add(h[:, :, 0] @ p[f"upd_{l}"])
    inv = h[:, :, 0]                                        # invariant part
    e_node = jax.nn.silu(inv @ p["out_w0"]) @ p["out_w1"]
    return e_node                                           # (N, n_out)


def mace_energy(p, cfg, species, pos, senders, receivers, edge_mask,
                graph_ids, n_graphs):
    e = mace_forward(p, cfg, species, pos, senders, receivers, edge_mask,
                     species.shape[0])
    return seg_sum(e[:, 0], graph_ids, n_graphs)
