"""GNN family: GIN, GAT, MeshGraphNet — segment-op message passing.

JAX has no sparse-matrix engine beyond BCOO, so message passing is built
on ``jax.ops.segment_sum``/``segment_max`` over an edge-index list — this
IS part of the system (see kernel_taxonomy §GNN).  Distribution follows
the paper's 2D edge decomposition for the full-graph shapes (see
core/spmm.py for the shard_map expand/fold variant used by the optimized
path); the baseline shards edges flat across the mesh and lets GSPMD
place the scatter-add combine.

Graph batches are static-shape: padded edges carry mask=0 and point at
node 0 (their contributions are multiplied away).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import GNNConfig


def seg_sum(x, ids, n):
    return jax.ops.segment_sum(x, ids, num_segments=n)


def seg_max(x, ids, n):
    return jax.ops.segment_max(x, ids, num_segments=n)


def _mlp_init(key, dims, name):
    p = {}
    ks = jax.random.split(key, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        p[f"{name}_w{i}"] = jax.random.normal(ks[i], (a, b)) * (a ** -0.5)
        p[f"{name}_b{i}"] = jnp.zeros((b,))
    return p


def _mlp_apply(p, name, x, n_layers, act=jax.nn.relu, final_act=False,
               layernorm=False):
    for i in range(n_layers):
        x = x @ p[f"{name}_w{i}"] + p[f"{name}_b{i}"]
        if i < n_layers - 1 or final_act:
            x = act(x)
    if layernorm:
        mu = x.mean(-1, keepdims=True)
        var = x.var(-1, keepdims=True)
        x = (x - mu) * lax.rsqrt(var + 1e-6)
    return x


# ---------------------------------------------------------------------------
# GIN  (Xu et al. 2019): h' = MLP((1+eps)h + sum_j h_j)
# ---------------------------------------------------------------------------


def init_gin(cfg: GNNConfig, key, d_in: int, n_out: int):
    ks = jax.random.split(key, cfg.n_layers + 1)
    p: Dict[str, Any] = {}
    d = d_in
    for l in range(cfg.n_layers):
        p.update(_mlp_init(ks[l], (d, cfg.d_hidden, cfg.d_hidden), f"l{l}"))
        p[f"eps{l}"] = jnp.zeros(())
        d = cfg.d_hidden
    p.update(_mlp_init(ks[-1], (cfg.d_hidden, n_out), "head"))
    return p


def gin_forward(p, cfg: GNNConfig, x, senders, receivers, edge_mask, n: int):
    for l in range(cfg.n_layers):
        msg = x[senders] * edge_mask[:, None]
        agg = seg_sum(msg, receivers, n)
        x = _mlp_apply(p, f"l{l}", (1.0 + p[f"eps{l}"]) * x + agg, 2,
                       final_act=True)
    return x


# ---------------------------------------------------------------------------
# GAT  (Velickovic et al. 2018)
# ---------------------------------------------------------------------------


def init_gat(cfg: GNNConfig, key, d_in: int, n_out: int):
    H, dh = cfg.n_heads, cfg.d_hidden
    ks = jax.random.split(key, 2 * cfg.n_layers)
    p: Dict[str, Any] = {}
    d = d_in
    for l in range(cfg.n_layers):
        dout = n_out if l == cfg.n_layers - 1 else dh
        p[f"W{l}"] = jax.random.normal(ks[2 * l], (d, H, dout)) * (d ** -0.5)
        p[f"a_src{l}"] = jax.random.normal(ks[2 * l + 1], (H, dout)) * 0.1
        p[f"a_dst{l}"] = jax.random.normal(ks[2 * l + 1], (H, dout)) * 0.1
        d = H * dh
    return p


def gat_forward(p, cfg: GNNConfig, x, senders, receivers, edge_mask, n: int):
    for l in range(cfg.n_layers):
        last = l == cfg.n_layers - 1
        z = jnp.einsum("nd,dhk->nhk", x, p[f"W{l}"])
        es = jnp.sum(z * p[f"a_src{l}"], -1)       # (N, H)
        ed = jnp.sum(z * p[f"a_dst{l}"], -1)
        logit = jax.nn.leaky_relu(es[senders] + ed[receivers], 0.2)
        logit = jnp.where(edge_mask[:, None] > 0, logit, -1e30)
        mx = seg_max(logit, receivers, n)
        expv = jnp.exp(logit - mx[receivers]) * edge_mask[:, None]
        den = seg_sum(expv, receivers, n)
        alpha = expv / jnp.maximum(den[receivers], 1e-16)
        out = seg_sum(alpha[..., None] * z[senders], receivers, n)  # (N,H,k)
        x = out.mean(1) if last else jax.nn.elu(
            out.reshape(n, -1))
    return x


# ---------------------------------------------------------------------------
# MeshGraphNet  (Pfaff et al. 2021): encode-process(x15)-decode
# ---------------------------------------------------------------------------


def init_mgn(cfg: GNNConfig, key, d_in: int, d_edge_in: int, n_out: int):
    dh, L = cfg.d_hidden, cfg.n_layers
    ks = jax.random.split(key, 2 * L + 3)
    p: Dict[str, Any] = {}
    p.update(_mlp_init(ks[0], (d_in, dh, dh), "enc_n"))
    p.update(_mlp_init(ks[1], (d_edge_in, dh, dh), "enc_e"))
    for l in range(L):
        p.update(_mlp_init(ks[2 + 2 * l], (3 * dh, dh, dh), f"pe{l}"))
        p.update(_mlp_init(ks[3 + 2 * l], (2 * dh, dh, dh), f"pn{l}"))
    p.update(_mlp_init(ks[-1], (dh, dh, n_out), "dec"))
    return p


def mgn_forward(p, cfg: GNNConfig, x, e_feat, senders, receivers, edge_mask,
                n: int):
    h = _mlp_apply(p, "enc_n", x, 2, layernorm=True)
    e = _mlp_apply(p, "enc_e", e_feat, 2, layernorm=True)
    for l in range(cfg.n_layers):
        eu = _mlp_apply(p, f"pe{l}", jnp.concatenate(
            [e, h[senders], h[receivers]], -1), 2, layernorm=True)
        e = e + eu
        agg = seg_sum(e * edge_mask[:, None], receivers, n)
        hu = _mlp_apply(p, f"pn{l}", jnp.concatenate([h, agg], -1), 2,
                        layernorm=True)
        h = h + hu
    return _mlp_apply(p, "dec", h, 2)


# ---------------------------------------------------------------------------
# Task heads / train steps (selected per shape kind by the launcher)
# ---------------------------------------------------------------------------


def node_xent(logits, labels, mask):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)


def graph_readout_xent(node_logits, graph_ids, labels, n_graphs):
    pooled = seg_sum(node_logits, graph_ids, n_graphs)
    logp = jax.nn.log_softmax(pooled.astype(jnp.float32), -1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], -1))


def build_gnn_apply(cfg: GNNConfig, d_in: int, n_out: int,
                    d_edge_in: int = 4):
    """Returns (init_fn(key) -> params, apply_fn(params, batch) -> node out)."""
    if cfg.model == "gin":
        return (lambda k: init_gin(cfg, k, d_in, n_out),
                lambda p, b: _head_gin(p, cfg, b))
    if cfg.model == "gat":
        return (lambda k: init_gat(cfg, k, d_in, n_out),
                lambda p, b: gat_forward(p, cfg, b["x"], b["senders"],
                                         b["receivers"], b["edge_mask"],
                                         b["x"].shape[0]))
    if cfg.model == "meshgraphnet":
        return (lambda k: init_mgn(cfg, k, d_in, d_edge_in, n_out),
                lambda p, b: mgn_forward(p, cfg, b["x"], b["e_feat"],
                                         b["senders"], b["receivers"],
                                         b["edge_mask"], b["x"].shape[0]))
    raise ValueError(cfg.model)


def _head_gin(p, cfg, b):
    h = gin_forward(p, cfg, b["x"], b["senders"], b["receivers"],
                    b["edge_mask"], b["x"].shape[0])
    return _mlp_apply(p, "head", h, 1)
