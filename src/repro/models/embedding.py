"""Sharded EmbeddingBag — the recsys hot path.

JAX has no native EmbeddingBag or CSR sparse; this builds it from
``jnp.take`` + ``jax.ops.segment_sum`` (single-device path) and a
row-sharded shard_map lookup (distributed path).

All field tables are concatenated into one (total_rows, dim) matrix with
per-field offsets.  Distribution: rows sharded over the "model" axis;
each device gathers the rows it owns (mask-clipped local gather) and a
psum over "model" assembles the result — structurally the paper's *fold*
(owner-computes exchange; see DESIGN.md §Arch-applicability).  The
index-exchange (all_to_all) variant lives in the perf notes as the
beyond-baseline option.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import RecsysConfig
from repro.core.compat import shard_map
from repro.models.common import ShardCtx


def table_meta(cfg: RecsysConfig) -> Tuple[np.ndarray, int]:
    offsets = np.concatenate([[0], np.cumsum(cfg.vocab_sizes)])
    total = int(offsets[-1])
    total = ((total + 511) // 512) * 512       # row-shardable on any mesh
    return offsets.astype(np.int64), total


def init_table(cfg: RecsysConfig, key) -> jnp.ndarray:
    _, total = table_meta(cfg)
    return (jax.random.normal(key, (total, cfg.embed_dim), jnp.float32)
            * (cfg.embed_dim ** -0.5))


def flat_indices(cfg: RecsysConfig, idx: jnp.ndarray) -> jnp.ndarray:
    """(B, F) per-field indices -> flat row ids into the concat table."""
    offsets, _ = table_meta(cfg)
    return idx + jnp.asarray(offsets[:-1], idx.dtype)[None, :]


def lookup(table: jnp.ndarray, rows: jnp.ndarray, ctx: ShardCtx):
    """rows: (...,) flat row ids -> (..., D) embeddings.

    Distributed: table rows sharded P("model", None); local masked gather
    + psum along "model"."""
    if ctx.mesh is None or ctx.tp_size == 1:
        return jnp.take(table, rows, axis=0)

    def body(tab, r):
        size = tab.shape[0]
        r0 = lax.axis_index("model") * size
        loc = r - r0
        ok = (loc >= 0) & (loc < size)
        vals = jnp.take(tab, jnp.clip(loc, 0, size - 1), axis=0)
        vals = jnp.where(ok[..., None], vals, 0.0)
        return lax.psum(vals, "model")

    dpa = ctx.dp
    flat = rows.reshape(-1)
    dp_total = int(np.prod([ctx.mesh.shape[a] for a in dpa])) if dpa else 1
    rspec = P(dpa) if (dpa and flat.shape[0] % dp_total == 0) else P(None)
    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P("model", None), rspec),
        out_specs=P(*rspec, None), check_vma=False,
    )(table, flat).reshape(*rows.shape, table.shape[1])


def embedding_bag(table, bag_ids, bag_weights=None, mode: str = "sum",
                  ctx: ShardCtx = ShardCtx(), use_kernel: bool = False):
    """bag_ids: (B, L) multi-hot rows (-1 = pad) -> (B, D) reduced.

    ``use_kernel`` routes the gather-reduce through the Pallas TBE kernel
    (interpret-validated; single-device only)."""
    if use_kernel and (ctx.mesh is None or ctx.tp_size == 1):
        from repro.kernels.embedding_bag import ops as eb_ops
        return eb_ops.embedding_bag(table, bag_ids, bag_weights, mode=mode)
    valid = bag_ids >= 0
    safe = jnp.where(valid, bag_ids, 0)
    vals = lookup(table, safe, ctx)
    w = valid.astype(vals.dtype)
    if bag_weights is not None:
        w = w * bag_weights
    out = jnp.sum(vals * w[..., None], axis=-2)
    if mode == "mean":
        out = out / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return out
