"""Shared model building blocks: sharding context, RMSNorm, RoPE, and
chunked (flash-style online-softmax) attention in pure jnp."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh context threaded through model code.  mesh=None disables all
    constraints (single-device smoke tests)."""
    mesh: Optional[object] = None

    @property
    def dp(self) -> Tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(n for n in self.mesh.axis_names if n in ("pod", "data"))

    @property
    def tp(self) -> Optional[str]:
        if self.mesh is None or "model" not in self.mesh.axis_names:
            return None
        return "model"

    @property
    def tp_size(self) -> int:
        if self.tp is None:
            return 1
        return self.mesh.shape["model"]

    def cons(self, x, *spec):
        if self.mesh is None:
            return x
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))


def rms_norm(x, gamma, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * gamma).astype(x.dtype)


def rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., :, None] * freqs[None, :]
    cos = jnp.cos(ang)[..., :, None, :]      # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def chunked_attention(q, k, v, *, q_offset, causal: bool = True,
                      window: Optional[int] = None, kv_chunk: int = 1024,
                      kv_valid_len=None):
    """Online-softmax attention over KV chunks (the pure-jnp flash pattern;
    the Pallas kernel in kernels/flash_attention mirrors this block
    structure for the TPU).

    q: (B, Sq, Hq, dh);  k,v: (B, Sk, Hkv, dh);  GQA via head repeat.
    q_offset: scalar — absolute position of q[0] (for causal masking of
    decode/prefill-continuation).  kv_valid_len: mask k beyond this length.
    """
    B, Sq, Hq, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    scale = dh ** -0.5
    kv_chunk = min(kv_chunk, Sk)
    n_chunks = (Sk + kv_chunk - 1) // kv_chunk
    Sk_pad = n_chunks * kv_chunk
    if Sk_pad != Sk:
        pad = [(0, 0), (0, Sk_pad - Sk), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    kc = k.reshape(B, n_chunks, kv_chunk, Hkv, dh)
    vc = v.reshape(B, n_chunks, kv_chunk, Hkv, dh)

    q_pos = q_offset + jnp.arange(Sq)
    valid_k = jnp.asarray(Sk if kv_valid_len is None else kv_valid_len)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, c = inp
        k_pos = c * kv_chunk + jnp.arange(kv_chunk)
        kb = jnp.repeat(kb, rep, axis=2)      # (B, C, Hq, dh)
        vb = jnp.repeat(vb, rep, axis=2)
        s = jnp.einsum("bqhd,bchd->bhqc", q.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        mask = (k_pos[None, :] <= q_pos[:, None]) if causal else jnp.ones(
            (Sq, kv_chunk), bool)
        if window is not None:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        mask = mask & (k_pos < valid_k)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqc,bchd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hq, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hq, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hq, Sq, dh), jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0),
                              (kc_t, vc_t, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)   # (B, Sq, Hq, dh)
