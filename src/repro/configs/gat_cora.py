"""gat-cora [arXiv:1710.10903; paper]."""
from repro.configs.base import GNNConfig, register

CONFIG = register(GNNConfig(
    arch="gat-cora",
    model="gat",
    n_layers=2,
    d_hidden=8,
    n_heads=8,
    aggregator="attn",
    n_classes=7,
))
