"""meshgraphnet [arXiv:2010.03409; unverified]."""
from repro.configs.base import GNNConfig, register

CONFIG = register(GNNConfig(
    arch="meshgraphnet",
    model="meshgraphnet",
    n_layers=15,
    d_hidden=128,
    aggregator="sum",
    mlp_layers=2,
))
