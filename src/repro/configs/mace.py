"""mace — higher-order equivariant message passing [arXiv:2206.07697; paper]."""
from repro.configs.base import GNNConfig, register

CONFIG = register(GNNConfig(
    arch="mace",
    model="mace",
    n_layers=2,
    d_hidden=128,
    l_max=2,
    correlation_order=3,
    n_rbf=8,
    aggregator="sum",
))
