"""Config system: typed arch configs, shape sets, and a registry.

Every assigned architecture is a selectable config (``--arch <id>``); each
arch carries its own input-shape set so every (arch x shape) cell is
well-defined.  BFS (the paper's own workload) registers its configs here
too, so the launcher treats it uniformly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

# --------------------------------------------------------------------------
# Shape specs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class LMShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES: Tuple[LMShape, ...] = (
    LMShape("train_4k", 4096, 256, "train"),
    LMShape("prefill_32k", 32768, 32, "prefill"),
    LMShape("decode_32k", 32768, 128, "decode"),
    LMShape("long_500k", 524288, 1, "decode"),
)


@dataclass(frozen=True)
class GNNShape:
    name: str
    n_nodes: int
    n_edges: int
    d_feat: int = 0
    batch_nodes: int = 0            # sampled-training seed batch
    fanout: Tuple[int, ...] = ()    # neighbor-sampler fanouts
    batch_graphs: int = 0           # batched-small-graphs
    kind: str = "full"              # "full" | "sampled" | "batched"


GNN_SHAPES: Tuple[GNNShape, ...] = (
    GNNShape("full_graph_sm", 2708, 10556, d_feat=1433, kind="full"),
    GNNShape("minibatch_lg", 232965, 114615892, batch_nodes=1024,
             fanout=(15, 10), kind="sampled"),
    GNNShape("ogb_products", 2449029, 61859140, d_feat=100, kind="full"),
    GNNShape("molecule", 30, 64, batch_graphs=128, kind="batched"),
)


@dataclass(frozen=True)
class RecsysShape:
    name: str
    batch: int
    n_candidates: int = 0
    kind: str = "train"  # "train" | "serve" | "retrieval"


RECSYS_SHAPES: Tuple[RecsysShape, ...] = (
    RecsysShape("train_batch", 65536, kind="train"),
    RecsysShape("serve_p99", 512, kind="serve"),
    RecsysShape("serve_bulk", 262144, kind="serve"),
    RecsysShape("retrieval_cand", 1, n_candidates=1_000_000, kind="retrieval"),
)


@dataclass(frozen=True)
class BFSShape:
    name: str
    scale: int           # 2**scale vertices (Graph500 convention)
    degree: int = 16
    n_roots: int = 1     # batched roots (pod axis)
    kind: str = "bfs"


BFS_SHAPES: Tuple[BFSShape, ...] = (
    BFSShape("scale22", 22),
    BFSShape("scale26", 26),
    BFSShape("scale30", 30),
)

# --------------------------------------------------------------------------
# Arch configs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class LMConfig:
    arch: str
    family: str            # "dense" | "moe"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0
    rope_theta: float = 10000.0
    swa_window: Optional[int] = None      # sliding-window attention
    moe: Optional[MoEConfig] = None
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat_policy: str = "full"         # "none" | "full" | "dots"
    opt_state_dtype: str = "float32"
    loss_bf16: bool = False            # bf16 logits matmul, f32 accumulate
    fsdp: bool = False                 # shard dense weights over dp too
    shapes: Tuple[LMShape, ...] = LM_SHAPES

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def kind(self) -> str:
        return "lm"

    def n_params(self) -> int:
        """Total parameter count (embedding + blocks)."""
        d, L = self.d_model, self.n_layers
        attn = d * (self.n_heads * self.d_head) + 2 * d * (self.n_kv_heads * self.d_head) \
            + (self.n_heads * self.d_head) * d
        if self.moe is not None:
            ff = self.moe.n_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
        else:
            ff = 3 * d * self.d_ff
        norms = 2 * d
        return L * (attn + ff + norms) + self.vocab * d + d

    def n_active_params(self) -> int:
        d, L = self.d_model, self.n_layers
        attn = d * (self.n_heads * self.d_head) + 2 * d * (self.n_kv_heads * self.d_head) \
            + (self.n_heads * self.d_head) * d
        if self.moe is not None:
            ff = self.moe.top_k * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
        else:
            ff = 3 * d * self.d_ff
        return L * (attn + ff + 2 * d) + self.vocab * d + d


@dataclass(frozen=True)
class GNNConfig:
    arch: str
    model: str              # "gin" | "gat" | "meshgraphnet" | "mace"
    n_layers: int
    d_hidden: int
    n_heads: int = 1
    aggregator: str = "sum"
    l_max: int = 0                   # MACE
    correlation_order: int = 0       # MACE
    n_rbf: int = 0                   # MACE
    eps_learnable: bool = False      # GIN
    mlp_layers: int = 2              # MeshGraphNet
    n_classes: int = 16
    dtype: str = "float32"
    shapes: Tuple[GNNShape, ...] = GNN_SHAPES

    @property
    def kind(self) -> str:
        return "gnn"


@dataclass(frozen=True)
class RecsysConfig:
    arch: str
    n_sparse: int
    embed_dim: int
    n_attn_layers: int
    n_heads: int
    d_attn: int
    vocab_sizes: Tuple[int, ...] = ()
    mlp_hidden: Tuple[int, ...] = (256, 128)
    dtype: str = "float32"
    shapes: Tuple[RecsysShape, ...] = RECSYS_SHAPES

    def __post_init__(self):
        if not self.vocab_sizes:
            # Criteo-like mix: a few huge tables, many medium/small ones.
            sizes = []
            for i in range(self.n_sparse):
                if i % 8 == 0:
                    sizes.append(2_000_000)
                elif i % 4 == 0:
                    sizes.append(200_000)
                elif i % 2 == 0:
                    sizes.append(20_000)
                else:
                    sizes.append(2_000)
            object.__setattr__(self, "vocab_sizes", tuple(sizes))

    @property
    def kind(self) -> str:
        return "recsys"

    def n_embed_rows(self) -> int:
        return sum(self.vocab_sizes)


@dataclass(frozen=True)
class BFSConfig:
    arch: str = "bfs-rmat"
    # "2d" checkerboard (paper §4) | "1d" row strips, dense bitmap
    # allgather (Alg. 1/2 baseline) | "1ds" row strips, sparse
    # owner-directed frontier exchange with bitmap fallback.
    # 1D has no fold/transpose phases: fold_mode only applies to 2D.
    decomposition: str = "2d"
    storage: str = "csr"          # "csr" | "dcsc"
    # fold: "alltoall" (paper-faithful) | "reduce" (ring RS) |
    #       "bitmap"/"bitmap_pure" (beyond-paper compact fold)
    fold_mode: str = "reduce"
    alpha: float = 14.0           # top-down -> bottom-up switch (Beamer)
    beta: float = 24.0            # bottom-up -> top-down switch
    direction_optimizing: bool = True
    # instrument=True compiles the full counter/level_stats bookkeeping
    # into the search program (Eq. 2 validation, crossover artifacts);
    # instrument=False compiles it OUT and fuses the per-level scalar
    # all-reduces the loop genuinely needs into ONE vector psum (+ one
    # pmax under a pod axis) — the latency-lean fast path the paper's
    # depth/time/TEPS runs use.  Parents are identical either way.
    instrument: bool = True
    use_edge_dst: bool = False    # bottom-up O(E) row read (no searchsorted)
    compact_updates: bool = False  # bottom-up compact (child,parent) sends
    # "1ds" sparse-bucket encoding: "packed" bit-packs local offsets at
    # codec_bits(chunk) bits each behind a count word (~3x fewer bucket
    # bytes; kernels/frontier_codec), "none" ships raw i32 global ids.
    # Parents are bit-identical; only wire volume and the planned cap_x
    # crossover change.  Ignored by "1d"/"2d".
    frontier_codec: str = "packed"
    # Software-pipelined level expand (default 1 = today's schedule).
    # 1d/1ds: split the top-down frontier allgather into expand_chunks
    # sub-chunk collectives, each consumed by local discovery while the
    # next is in flight — same bytes, latency overlapped; must divide
    # the per-strip bitmap extent (chunk/32 words; plan_bfs validates)
    # and, for 1ds, the planned bucket capacity cap_x.  2d: any value
    # > 1 switches the bottom-up systolic rotation to the pipelined R/G
    # split ring (the completed-bitmap permute is issued ahead of the
    # local scan; accumulated finds ride a second permute consumed only
    # for the post-scan exactness filter).  Parents are bit-identical
    # to expand_chunks=1 in every decomposition.
    expand_chunks: int = 1
    rmat_a: float = 0.57
    rmat_b: float = 0.19
    rmat_c: float = 0.19
    shapes: Tuple[BFSShape, ...] = BFS_SHAPES

    @property
    def kind(self) -> str:
        return "bfs"


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, Any] = {}


def register(cfg: Any) -> Any:
    if cfg.arch in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.arch}")
    _REGISTRY[cfg.arch] = cfg
    return cfg


def get_config(arch: str) -> Any:
    _ensure_loaded()
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch]


def list_archs() -> Sequence[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def reduced(cfg: Any, **overrides: Any) -> Any:
    """A smoke-test-sized variant of a config (same family, tiny dims)."""
    return dataclasses.replace(cfg, **overrides)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # Importing the per-arch modules populates the registry.
    from repro.configs import (  # noqa: F401
        stablelm_3b, smollm_135m, starcoder2_7b, qwen3_moe_30b_a3b,
        mixtral_8x22b, mace, gin_tu, gat_cora, meshgraphnet, autoint,
        bfs_rmat,
    )
