"""The paper's own workload: direction-optimizing BFS on Graph500 R-MAT."""
from repro.configs.base import BFSConfig, register
import dataclasses

CONFIG = register(BFSConfig(arch="bfs-rmat", storage="dcsc"))
CONFIG_CSR = register(dataclasses.replace(
    CONFIG, arch="bfs-rmat-csr", storage="csr", fold_mode="alltoall"))
CONFIG_TOPDOWN = register(dataclasses.replace(
    CONFIG, arch="bfs-rmat-topdown", direction_optimizing=False))

# --- §Perf hillclimb variants (beyond-paper; see EXPERIMENTS.md §Perf) ---
# i1: compact bitmap fold; i2: + O(E) edge-row reads; opt: + compact
# parent updates.  *_pure folds are the steady-state path the roofline
# lowers; the runtime config (bfs-rmat-opt-rt) keeps capacity fallbacks.
CONFIG_I1 = register(dataclasses.replace(
    CONFIG, arch="bfs-rmat-i1", fold_mode="bitmap_pure"))
CONFIG_I2 = register(dataclasses.replace(
    CONFIG, arch="bfs-rmat-i2", fold_mode="bitmap_pure", use_edge_dst=True))
CONFIG_OPT = register(dataclasses.replace(
    CONFIG, arch="bfs-rmat-opt", fold_mode="bitmap_pure", use_edge_dst=True,
    compact_updates=True))
CONFIG_OPT_RT = register(dataclasses.replace(
    CONFIG, arch="bfs-rmat-opt-rt", fold_mode="bitmap", use_edge_dst=True,
    compact_updates=True))
# batched roots sharded over the pod axis (multi-pod Graph500 pattern)
CONFIG_MULTIROOT = register(dataclasses.replace(
    CONFIG, arch="bfs-rmat-multiroot"))

# --- 1D row-decomposition baseline (the paper's comparison axis) ---
# Same R-MAT shapes and direction-optimizing heuristics; the benchmark
# harness sweeps bfs-rmat vs bfs-rmat-1d on identical graphs for the
# Eq. 2 wire-volume comparison.
CONFIG_1D = register(BFSConfig(arch="bfs-rmat-1d", decomposition="1d"))
CONFIG_1D_TOPDOWN = register(dataclasses.replace(
    CONFIG_1D, arch="bfs-rmat-1d-topdown", direction_optimizing=False))
# 1D with strip-DCSC compressed pointers — the previously missing half
# of the Fig. 6 CSR/DCSC x 1D/2D grid (run with local_mode="kernel" to
# take the Pallas strip SpMSV; see core/local_ops.py)
CONFIG_1D_DCSC = register(dataclasses.replace(
    CONFIG_1D, arch="bfs-rmat-1d-dcsc", storage="dcsc"))
# 1D with the SPARSE owner-directed frontier exchange ("1ds",
# core/steps_1d_sparse.py): capped frontier-id buckets broadcast per
# level with a dense bitmap fallback — the Buluc & Madduri formulation
# whose closed form is comm_model.topdown_1d_words
CONFIG_1DS = register(dataclasses.replace(
    CONFIG_1D, arch="bfs-rmat-1ds", decomposition="1ds"))
# raw-id buckets (frontier_codec="none"): the PR 5 wire baseline the
# packed codec is measured against, and the config whose wire_expand
# matches the uncompressed closed forms (sparse_expand_1d_words)
CONFIG_1DS_RAW = register(dataclasses.replace(
    CONFIG_1DS, arch="bfs-rmat-1ds-raw", frontier_codec="none"))

# --- Latency-lean fast path (instrument=False): counters/level_stats
# compiled out, one fused scalar reduction per level, batched bottom-up
# update exchange — the depth+time+TEPS configuration of the paper's §7
# runs (see README "performance"; instrumented variants above exist for
# Eq. 2 / crossover artifacts)
CONFIG_FAST = register(dataclasses.replace(
    CONFIG, arch="bfs-rmat-fast", instrument=False))
CONFIG_1DS_FAST = register(dataclasses.replace(
    CONFIG_1DS, arch="bfs-rmat-1ds-fast", instrument=False))

# --- Software-pipelined expand (expand_chunks > 1): the 1d/1ds top-down
# gather split into chunks consumed while the next is in flight; the 2d
# bottom-up ring pipelined via the R/G bitmap split (core/steps.py).
# Parents are bit-identical to the unpipelined configs; expand_chunks
# must divide the strip's packed word count (and cap_x for "1ds").
CONFIG_PIPE = register(dataclasses.replace(
    CONFIG_FAST, arch="bfs-rmat-pipe", expand_chunks=2))
CONFIG_1D_PIPE = register(dataclasses.replace(
    CONFIG_1D, arch="bfs-rmat-1d-pipe", instrument=False, expand_chunks=2))
CONFIG_1DS_PIPE = register(dataclasses.replace(
    CONFIG_1DS_FAST, arch="bfs-rmat-1ds-pipe", expand_chunks=4))
