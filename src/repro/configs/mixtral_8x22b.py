"""mixtral-8x22b — 8 experts top-2, SWA [arXiv:2401.04088; hf]."""
from repro.configs.base import LMConfig, MoEConfig, register

CONFIG = register(LMConfig(
    arch="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    swa_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
))
