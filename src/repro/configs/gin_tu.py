"""gin-tu [arXiv:1810.00826; paper]."""
from repro.configs.base import GNNConfig, register

CONFIG = register(GNNConfig(
    arch="gin-tu",
    model="gin",
    n_layers=5,
    d_hidden=64,
    aggregator="sum",
    eps_learnable=True,
))
