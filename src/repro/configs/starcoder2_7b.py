"""starcoder2-7b — GQA, RoPE [arXiv:2402.19173; hf]."""
from repro.configs.base import LMConfig, register

CONFIG = register(LMConfig(
    arch="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    fsdp=True,   # 7B dense: params+opt moments sharded over dp as well
))
