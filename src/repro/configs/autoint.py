"""autoint [arXiv:1810.11921; paper]."""
from repro.configs.base import RecsysConfig, register

CONFIG = register(RecsysConfig(
    arch="autoint",
    n_sparse=39,
    embed_dim=16,
    n_attn_layers=3,
    n_heads=2,
    d_attn=32,
))
