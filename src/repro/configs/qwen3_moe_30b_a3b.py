"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.configs.base import LMConfig, MoEConfig, register

CONFIG = register(LMConfig(
    arch="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,                      # d_ff is per-expert for this config
    vocab=151936,
    d_head=128,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
))

# --- §Perf hillclimb variants (train_4k memory-bound; EXPERIMENTS.md) ---
import dataclasses as _dc
CONFIG_R1 = register(_dc.replace(CONFIG, arch="qwen3-moe-r1",
                                 remat_policy="dots"))
CONFIG_R2 = register(_dc.replace(
    CONFIG_R1, arch="qwen3-moe-r2",
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768,
                  capacity_factor=1.0)))
CONFIG_R3 = register(_dc.replace(CONFIG_R2, arch="qwen3-moe-r3",
                                 opt_state_dtype="bfloat16"))
CONFIG_R4 = register(_dc.replace(CONFIG_R3, arch="qwen3-moe-r4",
                                 loss_bf16=True))
