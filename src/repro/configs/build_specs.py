"""Named distributed-build points (Graph500 R-MAT parameter pins).

The traversal configs in bfs_rmat.py say HOW to search; these say WHICH
graph to born-shard with graph.dist_build.  Pinning (scale, edge_factor,
seed, a/b/c) under a name keeps CI lanes, benchmarks, and store entries
talking about byte-identical graphs — a GraphStore load validated with
``expect_spec=get_build_spec(name)`` can never silently traverse a
different workload.
"""
from repro.graph.dist_build import BuildSpec

BUILD_SPECS = {
    # tiny parity/smoke point (matches the host-parity test pin)
    "g500-s10": BuildSpec(scale=10, edge_factor=16, seed=3),
    # bench trajectory pin: disk->first-traversal vs rebuild+recompile
    "g500-s14": BuildSpec(scale=14, edge_factor=16, seed=1),
    # CI bench-smoke build-then-load lane (16 forced host devices)
    "g500-s16": BuildSpec(scale=16, edge_factor=16, seed=1),
    # the "no host-side edge materialization" acceptance point
    "g500-s18": BuildSpec(scale=18, edge_factor=16, seed=1),
    # headroom pins for real accelerator meshes
    "g500-s20": BuildSpec(scale=20, edge_factor=16, seed=1),
    "g500-s22": BuildSpec(scale=22, edge_factor=16, seed=1),
}


def get_build_spec(name: str) -> BuildSpec:
    try:
        return BUILD_SPECS[name]
    except KeyError:
        raise KeyError(f"unknown build spec {name!r}; registered: "
                       f"{sorted(BUILD_SPECS)}") from None


def store_name(name: str, decomposition: str) -> str:
    """Canonical GraphStore graph name for a (spec, decomposition) pair
    ("1d" and "1ds" share the strip format and therefore the entry)."""
    fmt = "1d" if decomposition in ("1d", "1ds") else "2d"
    return f"{name}-{fmt}"
