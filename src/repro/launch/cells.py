"""Cell builders: every (architecture x input-shape) pair becomes a
(step_fn, abstract-args, in_shardings) triple the dry-run lowers and
compiles on the production mesh.  Nothing here allocates device memory —
all inputs are ShapeDtypeStructs (jax.eval_shape for params).

Cell kinds:
  LM      : train_step (loss+grad+AdamW), prefill, decode (KV cache)
  GNN     : train_step (full-graph / sampled / batched)
  recsys  : train_step, serve, retrieval scoring
  BFS     : whole direction-optimizing search + single-level steps
            (the level steps feed the roofline; the whole search proves
            the multi-pod schedule compiles)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (BFSConfig, BFSShape, GNNConfig, GNNShape,
                                LMConfig, LMShape, RecsysConfig, RecsysShape,
                                get_config)
from repro.core import steps as bfs_steps
from repro.core.compat import shard_map
from repro.core.engine import plan_for_part
from repro.core.local_ops import get_local_ops
from repro.core.partition import make_partition
from repro.graph.sampler import khop_sample
from repro.models import autoint as ai
from repro.models import gnn as gnn_mod
from repro.models import mace as mace_mod
from repro.models import transformer as tf
from repro.models.common import ShardCtx
from repro.optim.adamw import AdamW, AdamWState


class Cell(NamedTuple):
    fn: Callable
    args: Tuple[Any, ...]          # ShapeDtypeStructs / spec pytrees
    in_shardings: Any
    label: str
    meta: Dict[str, Any]           # model-flops accounting inputs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _round_up(x, q):
    return ((x + q - 1) // q) * q


def _dp(mesh):
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def _flat(mesh):
    return tuple(mesh.axis_names)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_param_shardings(cfg, mesh, ctx):
    specs = tf.param_specs(cfg, ctx)
    shapes = jax.eval_shape(lambda k: tf.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    return shapes, {k: NamedSharding(mesh, specs[k]) for k in shapes}


def _cache_spec(cfg, mesh, batch):
    dp = _dp(mesh)
    dp_ok = batch % int(np.prod([mesh.shape[a] for a in dp])) == 0 if dp else False
    bspec = dp if dp_ok else None
    tpn = mesh.shape.get("model", 1)
    if cfg.n_kv_heads % tpn == 0:
        return P(None, bspec, None, "model", None)
    return P(None, bspec, "model", None, None)


def build_lm_cell(cfg: LMConfig, shape: LMShape, mesh) -> Cell:
    if shape.kind != "train" and getattr(cfg, "fsdp", False):
        # FSDP is a training-memory optimization (optimizer moments);
        # serving keeps plain TP weights (no per-layer weight gathers)
        cfg = dataclasses.replace(cfg, fsdp=False)
    ctx = ShardCtx(mesh=mesh)
    dp = _dp(mesh)
    B, S = shape.global_batch, shape.seq_len
    dp_total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    tok_b = dp if (dp and B % dp_total == 0) else None
    params, p_sh = _lm_param_shardings(cfg, mesh, ctx)
    label = f"{cfg.arch}/{shape.name}"
    meta = {"family": "lm", "n_params": cfg.n_params(),
            "n_active_params": cfg.n_active_params(),
            "tokens": B * S, "kind": shape.kind,
            "n_layers": cfg.n_layers, "d_model": cfg.d_model,
            "scan_layers": True, "global_batch": B, "seq_len": S}

    if shape.kind == "train":
        opt = AdamW(state_dtype=getattr(cfg, "opt_state_dtype", "float32"))
        opt_state = jax.eval_shape(opt.init, params)
        opt_sh = AdamWState(step=_ns(mesh), mu=p_sh, nu=p_sh)
        toks = _sds((B, S), jnp.int32)
        tok_sh = _ns(mesh, tok_b, None)

        def train_step(p, ost, tokens, labels):
            loss, g = jax.value_and_grad(
                lambda p_: tf.lm_loss(p_, tokens, labels, cfg, ctx))(p)
            p2, ost2 = opt.update(g, ost, p)
            return p2, ost2, loss

        return Cell(train_step, (params, opt_state, toks, toks),
                    (p_sh, opt_sh, tok_sh, tok_sh), label, meta)

    cache_len = S
    if shape.kind == "decode" and cfg.swa_window:
        cache_len = min(S, cfg.swa_window)       # SWA ring window cache
    cache = jax.eval_shape(
        lambda: tf.init_kv_cache(cfg, B, cache_len))
    cspec = _cache_spec(cfg, mesh, B)
    cache_sh = {k: NamedSharding(mesh, cspec) for k in cache}

    if shape.kind == "prefill":
        toks = _sds((B, S), jnp.int32)

        def prefill_step(p, tokens, c):
            return tf.prefill(p, tokens, c, cfg, ctx)

        return Cell(prefill_step, (params, toks, cache),
                    (p_sh, _ns(mesh, tok_b, None), cache_sh), label,
                    {**meta, "tokens": B * S})

    tok = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)

    def dec_step(p, c, t, pos):
        return tf.decode_step(p, c, t, pos, cfg, ctx)

    return Cell(dec_step, (params, cache, tok, pos),
                (p_sh, cache_sh, _ns(mesh, tok_b, None), _ns(mesh)),
                label, {**meta, "tokens": B, "kv_len": cache_len})


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_loss(cfg: GNNConfig, shape: GNNShape, ctx: ShardCtx, n: int,
              n_graphs: int, d_in: int):
    """Returns (init_shapes, loss_fn(params, batch))."""
    if cfg.model == "mace":
        def loss_fn(p, b):
            e = mace_mod.mace_energy(p, cfg, b["species"], b["pos"],
                                     b["senders"], b["receivers"],
                                     b["edge_mask"], b["graph_ids"],
                                     n_graphs)
            return jnp.mean((e - b["targets_g"]) ** 2)
        init = lambda k: mace_mod.init_mace(cfg, k)
        return init, loss_fn
    init, apply = gnn_mod.build_gnn_apply(cfg, d_in, cfg.n_classes)

    def loss_fn(p, b):
        out = apply(p, b)
        if cfg.model == "meshgraphnet":
            return jnp.mean((out[:, :3] - b["targets"]) ** 2)
        if shape.kind == "batched":
            return gnn_mod.graph_readout_xent(out, b["graph_ids"],
                                              b["labels"], n_graphs)
        return gnn_mod.node_xent(out, b["labels"], b["node_mask"])
    return init, loss_fn


def build_gnn_cell(cfg: GNNConfig, shape: GNNShape, mesh) -> Cell:
    ctx = ShardCtx(mesh=mesh)
    flat = _flat(mesh)
    n_dev = int(np.prod(list(mesh.shape.values())))
    label = f"{cfg.arch}/{shape.name}"

    if shape.kind == "sampled":
        return _gnn_sampled_cell(cfg, shape, mesh, label)

    if shape.kind == "batched":
        n_graphs = shape.batch_graphs
        N = _round_up(n_graphs * shape.n_nodes, n_dev)
        E = _round_up(n_graphs * shape.n_edges, n_dev)
        d_feat = 16
    else:
        n_graphs = 1
        N = _round_up(shape.n_nodes, n_dev)     # padded isolated vertices
        E = _round_up(shape.n_edges, n_dev)
        d_feat = shape.d_feat or 16

    espec = P(flat)
    big = N > 500_000
    nspec = P(flat) if big else P(None)
    batch = {
        "senders": _sds((E,), jnp.int32),
        "receivers": _sds((E,), jnp.int32),
        "edge_mask": _sds((E,), jnp.float32),
        "graph_ids": _sds((N,), jnp.int32),
        "labels": _sds((n_graphs if shape.kind == "batched" else N,),
                       jnp.int32),
        "node_mask": _sds((N,), jnp.float32),
    }
    b_sh = {"senders": _ns(mesh, *espec), "receivers": _ns(mesh, *espec),
            "edge_mask": _ns(mesh, *espec),
            "graph_ids": NamedSharding(mesh, nspec),
            "labels": NamedSharding(mesh, nspec if n_graphs == 1 else P(None)),
            "node_mask": NamedSharding(mesh, nspec)}
    if cfg.model == "mace":
        batch.update({"species": _sds((N,), jnp.int32),
                      "pos": _sds((N, 3), jnp.float32),
                      "targets_g": _sds((n_graphs,), jnp.float32)})
        b_sh.update({"species": NamedSharding(mesh, nspec),
                     "pos": NamedSharding(mesh, nspec),
                     "targets_g": _ns(mesh, None)})
    elif cfg.model == "meshgraphnet":
        batch.update({"x": _sds((N, d_feat), jnp.float32),
                      "e_feat": _sds((E, 4), jnp.float32),
                      "targets": _sds((N, 3), jnp.float32)})
        b_sh.update({"x": NamedSharding(mesh, nspec),
                     "e_feat": _ns(mesh, *espec),
                     "targets": NamedSharding(mesh, nspec)})
    else:
        batch["x"] = _sds((N, d_feat), jnp.float32)
        b_sh["x"] = NamedSharding(mesh, nspec)

    init, loss_fn = _gnn_loss(cfg, shape, ctx, N, n_graphs, d_feat)
    params = jax.eval_shape(init, jax.random.PRNGKey(0))
    p_sh = jax.tree.map(lambda _: _ns(mesh), params)
    opt = AdamW()
    opt_state = jax.eval_shape(opt.init, params)
    opt_sh = AdamWState(step=_ns(mesh), mu=p_sh, nu=p_sh)

    def train_step(p, ost, b):
        loss, g = jax.value_and_grad(loss_fn)(p, b)
        p2, ost2 = opt.update(g, ost, p)
        return p2, ost2, loss

    meta = {"family": "gnn", "model": cfg.model, "n_nodes": N, "n_edges": E,
            "d_hidden": cfg.d_hidden, "n_layers": cfg.n_layers,
            "d_feat": d_feat}
    return Cell(train_step, (params, opt_state, batch),
                (p_sh, opt_sh, b_sh), label, meta)


def _gnn_sampled_cell(cfg: GNNConfig, shape: GNNShape, mesh, label) -> Cell:
    """minibatch_lg: neighbor-sample + train, fused into one step."""
    ctx = ShardCtx(mesh=mesh)
    N, M = shape.n_nodes, shape.n_edges
    Bs = shape.batch_nodes
    fan = shape.fanout
    d_feat = 128
    n_sub = Bs * (1 + fan[0] + fan[0] * fan[1])
    E_sub = Bs * (fan[0] + fan[0] * fan[1])

    init, _ = _gnn_loss(cfg, GNNShape("sub", n_sub, E_sub, d_feat),
                        ctx, n_sub, Bs, d_feat)
    params = jax.eval_shape(init, jax.random.PRNGKey(0))
    p_sh = jax.tree.map(lambda _: _ns(mesh), params)
    opt = AdamW()
    opt_state = jax.eval_shape(opt.init, params)
    opt_sh = AdamWState(step=_ns(mesh), mu=p_sh, nu=p_sh)

    args = (params, opt_state,
            _sds((N + 1,), jnp.int32),            # row_ptr
            _sds((M,), jnp.int32),                # col_idx
            _sds((N, d_feat), jnp.float32),       # features
            _sds((N,), jnp.int32),                # labels (full)
            _sds((Bs,), jnp.int32),               # seeds
            _sds((2,), jnp.uint32))               # rng key
    shard = (p_sh, opt_sh, _ns(mesh, None), _ns(mesh, None),
             _ns(mesh, None), _ns(mesh, None), _ns(mesh, None), _ns(mesh, None))

    def train_step(p, ost, row_ptr, col_idx, feats, labels, seeds, key):
        sub = khop_sample(jax.random.wrap_key_data(key, impl="threefry2x32"),
                          row_ptr, col_idx, seeds, fan)
        b = {
            "senders": sub["senders"], "receivers": sub["receivers"],
            "edge_mask": sub["edge_mask"],
            "x": feats[sub["node_ids"]],
            "graph_ids": jnp.zeros((n_sub,), jnp.int32),
            "labels": labels[sub["node_ids"]],
            "node_mask": (jnp.arange(n_sub) < Bs).astype(jnp.float32),
            "species": sub["node_ids"] % 8,
            "pos": feats[sub["node_ids"]][:, :3],
            "targets": feats[sub["node_ids"]][:, :3] * 0.5,
            "targets_g": jnp.zeros((1,), jnp.float32),
            "e_feat": jnp.concatenate(
                [feats[sub["node_ids"]][sub["senders"], :3]
                 - feats[sub["node_ids"]][sub["receivers"], :3],
                 jnp.ones((E_sub, 1))], axis=1),
        }
        _, loss_fn = _gnn_loss(cfg, GNNShape("sub", n_sub, E_sub, d_feat),
                               ctx, n_sub, 1, d_feat)
        loss, g = jax.value_and_grad(loss_fn)(p, b)
        p2, ost2 = opt.update(g, ost, p)
        return p2, ost2, loss

    meta = {"family": "gnn", "model": cfg.model, "n_nodes": n_sub,
            "n_edges": E_sub, "d_hidden": cfg.d_hidden,
            "n_layers": cfg.n_layers, "d_feat": d_feat, "sampled": True}
    return Cell(train_step, args, shard, label, meta)


# ---------------------------------------------------------------------------
# Recsys cells
# ---------------------------------------------------------------------------


def build_recsys_cell(cfg: RecsysConfig, shape: RecsysShape, mesh) -> Cell:
    ctx = ShardCtx(mesh=mesh)
    dp = _dp(mesh)
    label = f"{cfg.arch}/{shape.name}"
    params = jax.eval_shape(lambda k: ai.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    p_sh = {k: (_ns(mesh, "model", None) if k == "table" else _ns(mesh))
            for k in params}
    B = shape.batch
    dp_total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    bspec = dp if B % max(dp_total, 1) == 0 and B >= dp_total else None
    meta = {"family": "recsys", "batch": B, "n_fields": cfg.n_sparse,
            "embed_dim": cfg.embed_dim, "kind": shape.kind}

    if shape.kind == "train":
        opt = AdamW()
        opt_state = jax.eval_shape(opt.init, params)
        opt_sh = AdamWState(step=_ns(mesh), mu=p_sh, nu=p_sh)
        idx = _sds((B, cfg.n_sparse), jnp.int32)
        lab = _sds((B,), jnp.float32)

        def train_step(p, ost, idx, labels):
            loss, g = jax.value_and_grad(
                lambda p_: ai.bce_loss(p_, cfg, idx, labels, ctx))(p)
            p2, ost2 = opt.update(g, ost, p)
            return p2, ost2, loss

        return Cell(train_step, (params, opt_state, idx, lab),
                    (p_sh, opt_sh, _ns(mesh, bspec, None), _ns(mesh, bspec)),
                    label, meta)

    if shape.kind == "serve":
        idx = _sds((B, cfg.n_sparse), jnp.int32)

        def serve_step(p, idx):
            return jax.nn.sigmoid(ai.forward(p, cfg, idx, ctx))

        return Cell(serve_step, (params, idx),
                    (p_sh, _ns(mesh, bspec, None)), label, meta)

    # retrieval: 1 query vs n_candidates
    NC = shape.n_candidates
    d_user = cfg.n_heads * cfg.d_attn
    idx = _sds((B, cfg.n_sparse), jnp.int32)
    cand = _sds((NC, d_user), jnp.float32)

    def retrieval_step(p, idx, cand):
        u = ai.user_tower(p, cfg, idx, ctx)
        return ai.retrieval_scores(u, cand, ctx)

    return Cell(retrieval_step, (params, idx, cand),
                (p_sh, _ns(mesh, None, None), _ns(mesh, "model", None)),
                label, {**meta, "n_candidates": NC})


# ---------------------------------------------------------------------------
# BFS cells (the paper's workload)
# ---------------------------------------------------------------------------


def _bfs_graph_specs(part, cap, cap_seg, keys):
    nr, nc, chunk, pr, pc = part.nr, part.nc, part.chunk, part.pr, part.pc
    full = {
        "edge_src": (cap,), "row_idx": (cap,), "nnz": (),
        "deg_A": (chunk,), "col_idx": (cap + cap_seg,),
        "edge_dst": (cap + cap_seg,),
        "row_ptr": (nr + 1,), "seg_ptr": (pc + 1,),
        "col_ptr": (nc + 1,), "jc": (cap,), "cp": (cap + 1,), "nzc": (),
    }
    return {k: _sds((pr, pc) + full[k], jnp.int32) for k in keys}


def build_bfs_cell(cfg: BFSConfig, shape: BFSShape, mesh,
                   level_only: bool = False) -> Cell:
    pr = mesh.shape["data"]
    pc = mesh.shape["model"]
    n = 1 << shape.scale
    part = make_partition(n, pr, pc, align=128)
    p = part.p
    # capacity model: symmetrized+deduped R-MAT keeps ~0.94 of 2*ef*n edges;
    # R-MAT block skew needs ~1.4x headroom at this grid size
    m_est = int(2 * shape.degree * n * 0.94)
    cap = _round_up(int(m_est / p * 1.4), 128)
    cap_seg = _round_up(int(cap / pc * 2.0), 128)
    label = f"{cfg.arch}/{shape.name}" + ("/level" if level_only else "")
    meta = {"family": "bfs", "n": part.n, "m": m_est, "pr": pr, "pc": pc,
            "scale": shape.scale, "storage": cfg.storage}

    if level_only:
        ops = get_local_ops("2d", "dense", cfg.storage)
        args_l = bfs_steps.LevelArgs(
            part=part, row_axis="data", col_axis="model",
            fold_mode=cfg.fold_mode, perm=tuple(part.transpose_perm()),
            cap_seg=cap_seg, storage=cfg.storage,
            use_edge_dst=cfg.use_edge_dst,
            compact_updates=cfg.compact_updates, ops=ops)
        keys = ops.keys

        def level_fn(g, pi, front):
            g = {k: v[0, 0] for k, v in g.items()}
            pi1, f1, c1 = bfs_steps.topdown_level(g, pi[0, 0], front[0, 0],
                                                  args_l)
            pi2, f2, c2 = bfs_steps.bottomup_level(g, pi1, f1, args_l)
            return pi2[None, None], f2[None, None]

        spec = P("data", "model")
        mapped = shard_map(
            level_fn, mesh=mesh,
            in_specs=({k: spec for k in keys}, spec, spec),
            out_specs=(spec, spec), check_vma=False)
        g_specs = _bfs_graph_specs(part, cap, cap_seg, keys)
        pi = _sds((pr, pc, part.chunk), jnp.int32)
        fr = _sds((pr, pc, part.chunk), jnp.bool_)
        sh = NamedSharding(mesh, spec)
        return Cell(mapped, (g_specs, pi, fr),
                    ({k: sh for k in g_specs}, sh, sh), label, meta)

    # the engine's plan layer owns dispatch/validation; cells only need
    # the abstract program, so they build a graph-less plan
    plan = plan_for_part(part, cfg, mesh, cap_seg=cap_seg, maxdeg=1024,
                         n_real_edges=float(m_est))
    if "pod" in mesh.axis_names and kwargs_get_multiroot(cfg):
        pods = mesh.shape["pod"]
        fn = plan.build_batch_fn("pod")
        g_specs = _bfs_graph_specs(part, cap, cap_seg, plan.keys)
        sh = NamedSharding(mesh, P("data", "model"))
        return Cell(fn, (g_specs, _sds((pods,), jnp.int32)),
                    ({k: sh for k in g_specs}, _ns(mesh, "pod")),
                    label + "/multiroot", {**meta, "n_roots": pods})
    fn = plan.build_fn()
    g_specs = _bfs_graph_specs(part, cap, cap_seg, plan.keys)
    sh = NamedSharding(mesh, P("data", "model"))
    return Cell(fn, (g_specs, _sds((), jnp.int32)),
                ({k: sh for k in g_specs}, _ns(mesh)), label, meta)


def kwargs_get_multiroot(cfg) -> bool:
    return getattr(cfg, "arch", "").endswith("multiroot")


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

SKIPPED_CELLS = {
    # long_500k needs sub-quadratic attention; these are pure full-attention
    # archs (DESIGN.md §Arch-applicability) — mixtral (SWA) runs it.
    ("stablelm-3b", "long_500k"), ("smollm-135m", "long_500k"),
    ("starcoder2-7b", "long_500k"), ("qwen3-moe-30b-a3b", "long_500k"),
}


def build_cell(arch: str, shape_name: str, mesh, **kw) -> Optional[Cell]:
    if arch == "gin-tu-2d":
        from repro.launch.optimized import build_gin2d_cell
        return build_gin2d_cell(shape_name, mesh)
    if arch == "mace-2d":
        from repro.launch.optimized import build_mace2d_cell
        return build_mace2d_cell(shape_name, mesh)
    cfg = get_config(arch)
    if (arch, shape_name) in SKIPPED_CELLS:
        return None
    shape = next(s for s in cfg.shapes if s.name == shape_name)
    if cfg.kind == "lm":
        return build_lm_cell(cfg, shape, mesh)
    if cfg.kind == "gnn":
        return build_gnn_cell(cfg, shape, mesh)
    if cfg.kind == "recsys":
        return build_recsys_cell(cfg, shape, mesh)
    if cfg.kind == "bfs":
        return build_bfs_cell(cfg, shape, mesh, **kw)
    raise ValueError(arch)


def all_cells():
    """(arch, shape) ids for the full matrix (incl. skips -> None)."""
    out = []
    for arch in ("stablelm-3b", "smollm-135m", "starcoder2-7b",
                 "qwen3-moe-30b-a3b", "mixtral-8x22b"):
        for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            out.append((arch, s))
    for arch in ("mace", "gin-tu", "gat-cora", "meshgraphnet"):
        for s in ("full_graph_sm", "minibatch_lg", "ogb_products",
                  "molecule"):
            out.append((arch, s))
    for s in ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand"):
        out.append(("autoint", s))
    return out


def bfs_cells():
    return [("bfs-rmat", s) for s in ("scale22", "scale26", "scale30")]
