import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import: jax locks the
# device count on first init.  The dry-run (and only the dry-run) builds
# the production 16x16 / 2x16x16 meshes out of 512 host devices.
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_dryrun_cache")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "1")

"""Multi-pod dry-run: .lower().compile() every (architecture x input
shape x mesh) cell on the production mesh, record memory/cost analysis +
collective-bytes for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --cells all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --cells stablelm-3b/train_4k
Results are cached per cell in results/dryrun/<cell>__<mesh>.json, so the
run is resumable.
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.launch import cells as cells_mod
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_bytes_from_hlo, roofline_report

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def run_cell(arch: str, shape: str, multi_pod: bool, level_only: bool = False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    kw = {"level_only": True} if level_only else {}
    cell = cells_mod.build_cell(arch, shape, mesh, **kw)
    if cell is None:
        return {"cell": f"{arch}/{shape}", "skipped": True,
                "reason": "long_500k on pure full-attention arch "
                          "(DESIGN.md §Arch-applicability)"}
    t0 = time.time()
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings)
    lowered = jitted.lower(*cell.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    print(mem)    # proves it fits (per-device argument/output/temp bytes)
    print({k: v for k, v in cost.items()
           if k in ("flops", "bytes accessed")})  # FLOPs/bytes for §Roofline
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    out = {
        "cell": cell.label,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {k: getattr(mem, k) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)},
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collectives": coll,
        "meta": cell.meta,
    }
    out["roofline"] = roofline_report(out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default="all",
                    help="'all', 'bfs', or comma-sep arch/shape ids")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    if args.cells == "all":
        todo = cells_mod.all_cells() + cells_mod.bfs_cells()
    elif args.cells == "bfs":
        todo = cells_mod.bfs_cells()
    else:
        todo = [tuple(c.split("/", 1)) for c in args.cells.split(",")]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(RESULTS, exist_ok=True)
    failures = []
    for arch, shape in todo:
        is_bfs = arch.startswith("bfs")
        for mp in meshes:
            tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
            path = os.path.join(RESULTS, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[cached] {tag}")
                continue
            try:
                # BFS: single-pod run also lowers the level-step (roofline)
                out = run_cell(arch, shape, mp)
                if is_bfs and not mp:
                    lvl = run_cell(arch, shape, mp, level_only=True)
                    out["level_step"] = lvl
                with open(path, "w") as f:
                    json.dump(out, f, indent=1)
                r = out.get("roofline", {})
                print(f"[ok] {tag}: compile={out.get('compile_s')}s "
                      f"flops={out.get('flops', 0):.3g} "
                      f"coll={out.get('collectives', {}).get('total_bytes', 0):.3g}B "
                      f"bound={r.get('dominant', '?')}")
            except Exception as e:
                failures.append((tag, str(e)))
                print(f"[FAIL] {tag}: {e}")
                traceback.print_exc(limit=4)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e.splitlines()[0][:200] if e else "")
        sys.exit(1)
    print("\nDRY-RUN COMPLETE: all cells lowered + compiled.")


if __name__ == "__main__":
    main()
