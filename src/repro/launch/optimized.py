"""§Perf hillclimb cells (beyond-baseline variants).

gin-tu-2d/ogb_products: full-graph GIN training with aggregation routed
through the paper's 2D expand/fold partition (core/spmm.py schedule)
instead of GSPMD gather/scatter.  Napkin math (EXPERIMENTS.md §Perf):
baseline moves ~2*N*d*4B per device per layer in all-reduce traffic;
2D moves (N/pc + N/pr)*d*4B in allgather + reduce-scatter — a ~pc/2 x
reduction at pr=pc=16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.core.compat import shard_map
from repro.core.partition import make_partition
from repro.launch.cells import Cell, _ns, _round_up, _sds
from repro.optim.adamw import AdamW, AdamWState


def build_mace2d_cell(shape_name: str, mesh) -> Cell:
    """MACE with the 2D expand/fold aggregation — the most
    collective-bound baseline cell (mace/ogb_products, 1.8s collective).
    Positions + scalar channels expand along the column; the (nr, C, 9)
    first-order features fold via psum_scatter; Gaunt products stay
    chunk-local."""
    import numpy as _np
    from repro.models import mace as mace_mod
    cfg = get_config("mace")
    shape = next(s for s in cfg.shapes if s.name == shape_name)
    pr, pc = mesh.shape["data"], mesh.shape["model"]
    part = make_partition(shape.n_nodes, pr, pc, align=128)
    chunk, nr, nc = part.chunk, part.nr, part.nc
    cap = _round_up(int(shape.n_edges / part.p * 1.4), 128)
    C, L = cfg.d_hidden, cfg.n_layers
    perm = tuple(part.transpose_perm())
    spec = P("data", "model")
    G = mace_mod.gaunt_table().astype(_np.float32)
    lmap = mace_mod._LM_L

    def loss_body(p, esrc, ridx, nnz, species, pos, target):
        esrc, ridx, nnz = esrc[0, 0], ridx[0, 0], nnz[0, 0]
        species, pos = species[0, 0], pos[0, 0]      # (chunk,), (chunk, 3)
        Gj = jnp.asarray(G)
        e_mask = (jnp.arange(cap) < nnz)[:, None].astype(jnp.float32)

        def expand(x):     # layout A chunk -> C_j slice (nc, ...)
            xb = lax.ppermute(x, ("data", "model"), perm)
            return lax.all_gather(xb, "data", tiled=True)

        def gather_rows(x):  # layout A chunk -> R_i strip (nr, ...)
            return lax.all_gather(x, "model", tiled=True)

        pos_c = expand(pos)                           # (nc, 3)
        pos_r = gather_rows(pos)                      # (nr, 3)
        h = jnp.zeros((chunk, C, mace_mod.N_LM), jnp.float32)
        h = h.at[:, :, 0].set(p["embed"][species])
        rvec = pos_r[ridx] - pos_c[esrc]
        d = jnp.linalg.norm(rvec + 1e-12, axis=-1)
        u = rvec / jnp.maximum(d, 1e-9)[:, None]
        Y = mace_mod.real_sph_harm(u)                 # (cap, 9)
        for l in range(L):
            rb = mace_mod.bessel_basis(d, cfg.n_rbf, 3.0)
            R = jax.nn.silu(rb @ p[f"rad_w0_{l}"]) @ p[f"rad_w1_{l}"]
            R = R.reshape(-1, C, 3)[:, :, lmap]       # (cap, C, 9)
            hs_c = expand(h[:, :, 0])                 # (nc, C)
            msg = R * Y[:, None, :] * hs_c[esrc][:, :, None] * e_mask[..., None]
            partial = jax.ops.segment_sum(msg, ridx, num_segments=nr)
            A = lax.psum_scatter(partial, "model", scatter_dimension=0,
                                 tiled=True)          # (chunk, C, 9)
            B2 = mace_mod._gaunt_contract(A, A, Gj)
            B3 = mace_mod._gaunt_contract(B2, A, Gj)
            m = jnp.zeros_like(A)
            for o, feat in enumerate((A, B2, B3)):
                for li in range(3):
                    sel = lmap == li
                    m = m.at[:, :, sel].add(jnp.einsum(
                        "ncm,cd->ndm", feat[:, :, sel], p[f"mix_{l}"][o, li]))
            h = h + m
            h = h.at[:, :, 0].add(h[:, :, 0] @ p[f"upd_{l}"])
        e_node = jax.nn.silu(h[:, :, 0] @ p["out_w0"]) @ p["out_w1"]
        e_tot = lax.psum(jnp.sum(e_node), ("data", "model"))
        return (e_tot - target[0]) ** 2

    params = jax.eval_shape(lambda k: mace_mod.init_mace(cfg, k),
                            jax.random.PRNGKey(0))
    p_sh = jax.tree.map(lambda _: _ns(mesh), params)
    opt = AdamW()
    opt_state = jax.eval_shape(opt.init, params)
    opt_sh = AdamWState(step=_ns(mesh), mu=p_sh, nu=p_sh)
    mapped = shard_map(
        loss_body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), params), spec, spec, spec,
                  spec, spec, P()),
        out_specs=P(), check_vma=False)

    def train_step(p, ost, esrc, ridx, nnz, species, pos, target):
        loss, g = jax.value_and_grad(
            lambda p_: mapped(p_, esrc, ridx, nnz, species, pos, target))(p)
        p2, ost2 = opt.update(g, ost, p)
        return p2, ost2, loss

    blk = (pr, pc)
    args = (params, opt_state,
            _sds(blk + (cap,), jnp.int32), _sds(blk + (cap,), jnp.int32),
            _sds(blk, jnp.int32), _sds(blk + (chunk,), jnp.int32),
            _sds(blk + (chunk, 3), jnp.float32), _sds((1,), jnp.float32))
    sh = NamedSharding(mesh, spec)
    meta = {"family": "gnn", "model": "mace", "n_nodes": part.n,
            "n_edges": cap * part.p, "d_hidden": C, "n_layers": L,
            "d_feat": 3, "variant": "2d-fold"}
    return Cell(train_step, args, (p_sh, opt_sh, sh, sh, sh, sh, sh,
                                   _ns(mesh)),
                f"mace-2d/{shape_name}", meta)


def build_gin2d_cell(shape_name: str, mesh) -> Cell:
    cfg = get_config("gin-tu")
    shape = next(s for s in cfg.shapes if s.name == shape_name)
    pr = mesh.shape["data"]
    pc = mesh.shape["model"]
    part = make_partition(shape.n_nodes, pr, pc, align=128)
    chunk, nr, nc = part.chunk, part.nr, part.nc
    cap = _round_up(int(shape.n_edges / part.p * 1.4), 128)
    d_feat = shape.d_feat or 16
    dh, L, n_cls = cfg.d_hidden, cfg.n_layers, cfg.n_classes
    perm = tuple(part.transpose_perm())
    spec = P("data", "model")

    def loss_body(p, esrc, ridx, nnz, x, y, mask):
        esrc, ridx, nnz = esrc[0, 0], ridx[0, 0], nnz[0, 0]
        h, y, mask = x[0, 0], y[0, 0], mask[0, 0]
        e_mask = (jnp.arange(cap) < nnz)[:, None].astype(h.dtype)

        def agg2d(h):
            hb = lax.ppermute(h, ("data", "model"), perm)
            h_cj = lax.all_gather(hb, "data", tiled=True)     # (nc, d)
            partial = jax.ops.segment_sum(h_cj[esrc] * e_mask, ridx,
                                          num_segments=nr)
            return lax.psum_scatter(partial, "model",
                                    scatter_dimension=0, tiled=True)

        for l in range(L):
            z = (1.0 + p[f"eps{l}"]) * h + agg2d(h)
            z = jax.nn.relu(z @ p[f"l{l}_w0"] + p[f"l{l}_b0"])
            h = jax.nn.relu(z @ p[f"l{l}_w1"] + p[f"l{l}_b1"])
        logits = h @ p["head_w0"] + p["head_b0"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, y[:, None], -1)[:, 0]
        num = lax.psum(jnp.sum(nll * mask), ("data", "model"))
        den = lax.psum(jnp.sum(mask), ("data", "model"))
        return num / jnp.maximum(den, 1.0)

    from repro.models.gnn import init_gin
    params = jax.eval_shape(
        lambda k: init_gin(cfg, k, d_feat, n_cls), jax.random.PRNGKey(0))
    p_sh = jax.tree.map(lambda _: _ns(mesh), params)
    opt = AdamW()
    opt_state = jax.eval_shape(opt.init, params)
    opt_sh = AdamWState(step=_ns(mesh), mu=p_sh, nu=p_sh)

    mapped = shard_map(
        loss_body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), params), spec, spec, spec,
                  spec, spec, spec),
        out_specs=P(), check_vma=False)

    def train_step(p, ost, esrc, ridx, nnz, x, y, mask):
        loss, g = jax.value_and_grad(
            lambda p_: mapped(p_, esrc, ridx, nnz, x, y, mask))(p)
        p2, ost2 = opt.update(g, ost, p)
        return p2, ost2, loss

    blk = (pr, pc)
    args = (params, opt_state,
            _sds(blk + (cap,), jnp.int32), _sds(blk + (cap,), jnp.int32),
            _sds(blk, jnp.int32),
            _sds(blk + (chunk, d_feat), jnp.float32),
            _sds(blk + (chunk,), jnp.int32),
            _sds(blk + (chunk,), jnp.float32))
    sh = NamedSharding(mesh, spec)
    meta = {"family": "gnn", "model": "gin", "n_nodes": part.n,
            "n_edges": cap * part.p, "d_hidden": dh, "n_layers": L,
            "d_feat": d_feat, "variant": "2d-fold"}
    return Cell(train_step, args, (p_sh, opt_sh, sh, sh, sh, sh, sh, sh),
                f"gin-tu-2d/{shape_name}", meta)
