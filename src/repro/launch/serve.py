"""Serving launcher: batched LM serving or recsys scoring on the local
mesh (reduced configs on CPU; same step fns the dry-run lowers at scale).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m
    PYTHONPATH=src python -m repro.launch.serve --arch autoint
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models.common import ShardCtx


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    ctx = ShardCtx(mesh=None)

    if cfg.kind == "recsys":
        from repro.data.pipeline import recsys_batch
        from repro.models import autoint as ai
        cfg = reduced(cfg, n_sparse=8, embed_dim=8, n_attn_layers=2,
                      n_heads=2, d_attn=8, vocab_sizes=tuple([100] * 8),
                      mlp_hidden=(32,))
        p = ai.init_params(cfg, jax.random.PRNGKey(0))
        b = recsys_batch(cfg, 32, 0)
        scores = jax.jit(lambda idx: jax.nn.sigmoid(
            ai.forward(p, cfg, idx, ctx)))(jnp.asarray(b["idx"]))
        print(f"scored batch of 32: mean p(click)={float(scores.mean()):.3f}")
        return

    from repro.models import transformer as tf
    from repro.runtime.server import Request, Server
    kw = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
              vocab=512, d_head=16)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, n_experts=4, top_k=2,
                                        d_ff_expert=32)
    cfg = reduced(cfg, **kw)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    max_b, max_len = 4, 128

    @jax.jit
    def prefill_fn(tokens):
        cache = tf.init_kv_cache(cfg, max_b, max_len)
        return tf.prefill(params, tokens, cache, cfg, ctx)

    @jax.jit
    def decode_fn(cache, tok, pos):
        return tf.decode_step(params, cache, tok, pos, cfg, ctx)

    server = Server(prefill_fn, decode_fn, max_batch=max_b, bucket=32)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, rng.integers(4, 24))
                    .astype(np.int32), max_new_tokens=5)
            for _ in range(args.requests)]
    done = server.serve(reqs)
    for i, r in enumerate(done):
        print(f"req{i}: {len(r.prompt)} prompt toks -> {r.out.tolist()}")


if __name__ == "__main__":
    main()
