"""Generic training launcher: ``--arch <id>`` selects any registered
architecture; runs the fault-tolerant Trainer on the local mesh.

On the CPU container this uses reduced dims by default (--full for the
real config — intended for the TPU fleet, where the same entry point is
invoked under the cluster scheduler with a real mesh).

    PYTHONPATH=src python -m repro.launch.train --arch gin-tu --steps 20
    PYTHONPATH=src python -m repro.launch.train --arch bfs-rmat --scale 12
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNShape, get_config, reduced
from repro.data.pipeline import lm_batch, recsys_batch
from repro.graph.datasets import build_gnn_batch
from repro.models.common import ShardCtx
from repro.optim.adamw import AdamW
from repro.runtime.trainer import Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--scale", type=int, default=12, help="BFS graph scale")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    ctx = ShardCtx(mesh=None)

    if cfg.kind == "bfs":
        from repro.core.bfs import run_bfs
        from repro.core.ref import validate_parents
        from repro.graph.formats import build_blocked
        from repro.graph.rmat import random_source, rmat_graph
        from repro.launch.mesh import make_local_mesh
        edges = rmat_graph(args.scale, 16, seed=1)
        g = build_blocked(edges, 1, 1, align=32)
        mesh = make_local_mesh(1, 1)
        rng = np.random.default_rng(0)
        for i in range(min(args.steps, 8)):
            root = random_source(edges, rng)
            res = run_bfs(g, root, cfg, mesh)
            ok, msg = validate_parents(edges.n, edges.src, edges.dst, root,
                                       res.parents)
            assert ok, msg
            print(f"search {i}: root={root} levels={res.n_levels} valid")
        return

    opt = AdamW(lr=1e-3, total_steps=args.steps)
    if cfg.kind == "lm":
        from repro.models import transformer as tf
        if not args.full:
            kw = dict(n_layers=2, d_model=64, d_ff=128, vocab=512,
                      n_heads=4, n_kv_heads=2, d_head=16)
            if cfg.moe is not None:
                kw["moe"] = dataclasses.replace(cfg.moe, n_experts=4,
                                                top_k=2, d_ff_expert=32)
            cfg = reduced(cfg, **kw)
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        state = (params, opt.init(params))

        @jax.jit
        def step_fn(state, b):
            p, ost = state
            loss, g = jax.value_and_grad(lambda p_: tf.lm_loss(
                p_, b["tokens"], b["labels"], cfg, ctx, seq_chunk=64))(p)
            p, ost = opt.update(g, ost, p)
            return (p, ost), {"loss": loss}

        mk = lambda s: {k: jnp.asarray(v)
                        for k, v in lm_batch(cfg, 4, 64, s).items()}
    elif cfg.kind == "gnn":
        from repro.launch.cells import _gnn_loss
        shape = GNNShape("smoke", 512, 2048, d_feat=32, kind="full")
        b0 = build_gnn_batch(cfg, shape, seed=0)
        b0["node_mask"] = np.ones(b0["x"].shape[0], np.float32)
        b0["targets_g"] = np.zeros(1, np.float32)
        bj = {k: jnp.asarray(v) for k, v in b0.items()}
        init, loss_fn = _gnn_loss(cfg, shape, ctx, b0["x"].shape[0], 1, 32)
        params = init(jax.random.PRNGKey(0))
        state = (params, opt.init(params))

        @jax.jit
        def step_fn(state, b):
            p, ost = state
            loss, g = jax.value_and_grad(loss_fn)(p, bj)
            p, ost = opt.update(g, ost, p)
            return (p, ost), {"loss": loss}

        mk = lambda s: {}
    else:  # recsys
        from repro.models import autoint as ai
        if not args.full:
            cfg = reduced(cfg, n_sparse=8, embed_dim=8, n_attn_layers=2,
                          n_heads=2, d_attn=8, vocab_sizes=tuple([100] * 8),
                          mlp_hidden=(32,))
        params = ai.init_params(cfg, jax.random.PRNGKey(0))
        state = (params, opt.init(params))

        @jax.jit
        def step_fn(state, b):
            p, ost = state
            loss, g = jax.value_and_grad(lambda p_: ai.bce_loss(
                p_, cfg, b["idx"], b["labels"], ctx))(p)
            p, ost = opt.update(g, ost, p)
            return (p, ost), {"loss": loss}

        mk = lambda s: {k: jnp.asarray(v)
                        for k, v in recsys_batch(cfg, 64, s).items()}

    tr = Trainer(step_fn, mk, args.ckpt_dir, ckpt_every=10,
                 meta={"arch": args.arch})
    state, log = tr.run(state, args.steps)
    print(f"{args.arch}: {len(log)} steps, "
          f"loss {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
