"""EXPERIMENTS.md table generation from results/dryrun/*.json.

Scan correction (documented): XLA cost_analysis counts a lax.scan/while
body ONCE.  LM steps scan layers, so raw HLO flops/bytes/in-loop
collectives are corrected by the layer trip count with analytic per-layer
estimates (napkin formulas below).  GNN/recsys models use unrolled Python
layer loops — no correction.  BFS uses the separately-lowered level-step
(no outer loop) — no correction.

    PYTHONPATH=src python -m repro.launch.report > /tmp/tables.md
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def _lm_layer_correction(rec: Dict) -> Dict[str, float]:
    """Analytic per-layer (per-device) flops/bytes for the scanned block."""
    m = rec["meta"]
    L = m["n_layers"]
    n_dev = rec["n_devices"]
    toks = m["tokens"]
    emb = 0  # embedding outside the scan
    p_layer = (m.get("n_active_params", m["n_params"]) - emb) / L
    mult = 6.0 if m.get("kind") == "train" else 2.0
    flops_layer = mult * p_layer * toks / n_dev
    # weight traffic: fwd read + bwd read + grad write (train) or 1 read
    w_traffic = (3.0 if m.get("kind") == "train" else 1.0) * p_layer * 2
    # params are sharded at least over the model axis (16)
    w_traffic /= 16
    act = toks / max(n_dev // 16, 1) * m["d_model"] * 2 * 12
    if m.get("kind") == "decode":
        kv = m.get("kv_len", m.get("seq_len", 0))
        B = m.get("global_batch", 1)
        act += B * kv * m["d_model"] * 2 * 2 / n_dev  # KV read, sharded
    return {"flops": flops_layer, "bytes": w_traffic + act}


def corrected_terms(rec: Dict) -> Optional[Dict[str, float]]:
    if rec.get("skipped"):
        return None
    flops = rec.get("flops", 0.0) or 0.0
    bytes_acc = rec.get("bytes_accessed", 0.0) or 0.0
    coll = rec.get("collectives", {})
    total_c = coll.get("total_bytes", 0.0)
    inloop = coll.get("inloop_bytes", 0.0)
    meta = rec.get("meta", {})
    n_dev = rec.get("n_devices", 256)
    if meta.get("scan_layers"):
        L = meta["n_layers"]
        est = _lm_layer_correction(rec)
        flops = flops + (L - 1) * est["flops"]
        bytes_acc = bytes_acc + (L - 1) * est["bytes"]
        total_c = (total_c - inloop) + L * inloop
    t = {"compute_s": flops / PEAK_FLOPS,
         "memory_s": bytes_acc / HBM_BW,
         "collective_s": total_c / LINK_BW}
    mf = model_flops(meta)
    hlo_total = flops * n_dev
    t["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                        key=lambda k: t[k])
    t["model_flops"] = mf
    t["useful_ratio"] = mf / hlo_total if hlo_total else 0.0
    t["bound_s"] = max(t["compute_s"], t["memory_s"], t["collective_s"])
    t["roofline_frac"] = (t["compute_s"] / t["bound_s"]) if t["bound_s"] else 0.0
    return t


_NOTES = {
    ("lm", "memory"): "raise arithmetic intensity: larger per-device batch "
                      "or fused attention (flash kernel) to cut HBM traffic",
    ("lm", "collective"): "overlap TP collectives with compute; reduce "
                          "fold volume (reduce-scatter matmuls)",
    ("lm", "compute"): "near roofline: only kernel-level MXU utilization "
                       "gains remain",
    ("gnn", "collective"): "replace GSPMD gather/scatter with the paper's "
                           "2D expand/fold partition (core/spmm.py)",
    ("gnn", "memory"): "edge-block the segment ops; cache sender features "
                       "in VMEM tiles",
    ("gnn", "compute"): "dense MLP-bound: fuse aggregation into the MLP",
    ("recsys", "memory"): "embedding rows dominate: pack rows (bf16), "
                          "batch the gather (TBE kernel)",
    ("recsys", "collective"): "switch psum-lookup to index all_to_all "
                              "exchange (ship ids, not dense sums)",
    ("recsys", "compute"): "attention over 39 fields is tiny; batch more",
    ("bfs", "collective"): "bitmap-compress the fold; overlap rotation "
                           "with local discovery",
    ("bfs", "memory"): "edge-stream is HBM-bound: DCSC tiling into VMEM",
    ("bfs", "compute"): "BFS has no MXU work: memory/collective only",
}


def load_all():
    recs = {}
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        recs[os.path.basename(f)[:-5]] = json.load(open(f))
    return recs


def dryrun_table(recs) -> str:
    rows = ["| cell | mesh | compile s | args GiB/dev | temps GiB/dev | "
            "collectives (count) | HLO flops/dev |",
            "|---|---|---|---|---|---|---|"]
    for tag, r in recs.items():
        if r.get("skipped"):
            rows.append(f"| {tag} | - | - | - | - | SKIPPED: "
                        f"{r['reason'][:60]} | - |")
            continue
        mem = r.get("memory", {})
        gib = 1 << 30
        args = mem.get("argument_size_in_bytes", 0) / gib
        temps = mem.get("temp_size_in_bytes", 0) / gib
        c = r.get("collectives", {})
        counts = ", ".join(f"{k.replace('count_', '')}:{int(v)}"
                           for k, v in sorted(c.items())
                           if k.startswith("count_"))
        rows.append(
            f"| {r['cell']} | {r['mesh']} | {r.get('compile_s', 0)} | "
            f"{args:.2f} | {temps:.2f} | {counts or '-'} | "
            f"{r.get('flops', 0):.3g} |")
    return "\n".join(rows)


def roofline_table(recs) -> str:
    rows = ["| cell | compute s | memory s | collective s | bound | "
            "MODEL_FLOPS | useful ratio | what would move the bound |",
            "|---|---|---|---|---|---|---|---|"]
    for tag, r in recs.items():
        if not tag.endswith("__sp") or r.get("skipped"):
            continue
        use = r.get("level_step", r)
        t = corrected_terms(use)
        if t is None:
            continue
        fam = use.get("meta", {}).get("family", "?")
        note = _NOTES.get((fam, t["dominant"].replace("_s", "")), "")
        rows.append(
            f"| {r['cell']} | {t['compute_s']:.3e} | {t['memory_s']:.3e} | "
            f"{t['collective_s']:.3e} | {t['dominant'].replace('_s','')} | "
            f"{t['model_flops']:.3g} | {t['useful_ratio']:.3f} | {note} |")
    return "\n".join(rows)


def main():
    recs = load_all()
    n_ok = sum(1 for r in recs.values() if not r.get("skipped"))
    n_skip = sum(1 for r in recs.values() if r.get("skipped"))
    print(f"## Dry-run ({n_ok} compiled cells, {n_skip} documented skips)\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16, scan-corrected)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
