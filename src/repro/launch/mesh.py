"""Mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state.  The production
meshes are:

  single-pod : (16, 16)     axes ("data", "model")   = the paper's (pr, pc)
  multi-pod  : (2, 16, 16)  axes ("pod", "data", "model")

For BFS the ("data", "model") axes play the roles of the paper's processor
(row, column) grid; the "pod" axis batches independent BFS roots.
"""
from __future__ import annotations

import jax
import numpy as np

# BFS axis-name aliases: the paper's pr x pc grid mapped onto the mesh.
ROW_AXIS = "data"    # pr: processor rows   (expand/allgather axis)
COL_AXIS = "model"   # pc: processor cols   (fold/alltoall + rotation axis)
POD_AXIS = "pod"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(pr: int, pc: int, pods: int = 1):
    """An arbitrary rectangular grid (the paper's generalization)."""
    if pods > 1:
        return jax.make_mesh((pods, pr, pc), (POD_AXIS, ROW_AXIS, COL_AXIS))
    return jax.make_mesh((pr, pc), (ROW_AXIS, COL_AXIS))


def make_local_mesh(pr: int = 1, pc: int = 1, pods: int = 0):
    """Mesh over however many devices this process actually has.
    ``pods > 0`` prepends a pod axis of that size (pods=1 costs no extra
    devices and enables ``BFSEngine.run_batch``)."""
    n = len(jax.devices())
    need = max(pods, 1) * pr * pc
    if need > n:
        raise ValueError(f"grid {pods or ''}{'x' if pods else ''}{pr}x{pc} "
                         f"needs {need} devices, have {n}")
    if pods > 0:
        devs = np.asarray(jax.devices()[:need]).reshape(pods, pr, pc)
        return jax.sharding.Mesh(devs, (POD_AXIS, ROW_AXIS, COL_AXIS))
    devs = np.asarray(jax.devices()[:need]).reshape(pr, pc)
    return jax.sharding.Mesh(devs, (ROW_AXIS, COL_AXIS))


def make_local_mesh_1d(p: int = 1, pods: int = 0):
    """Single-axis mesh for the 1D row decomposition (axis name ROW_AXIS,
    matching the default ``row_axis`` the BFS driver shards over).
    ``pods > 0`` prepends a pod axis for pod-batched multi-source runs —
    the 1D counterpart of the multi-pod 2D mesh."""
    n = len(jax.devices())
    need = max(pods, 1) * p
    if need > n:
        raise ValueError(f"1d grid needs {need} devices, have {n}")
    if pods > 0:
        devs = np.asarray(jax.devices()[:need]).reshape(pods, p)
        return jax.sharding.Mesh(devs, (POD_AXIS, ROW_AXIS))
    devs = np.asarray(jax.devices()[:need])
    return jax.sharding.Mesh(devs, (ROW_AXIS,))
