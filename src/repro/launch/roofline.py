"""Roofline-term derivation from compiled dry-run artifacts.

TPU v5e hardware constants (targets; the container runs CPU so these are
analytic):  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

  compute term    = HLO_FLOPs / (chips * peak)
  memory term     = HLO_bytes / (chips * hbm_bw)
  collective term = collective_bytes / (chips * link_bw)

collective_bytes comes from summing operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute in the
compiled HLO (cost_analysis does not expose it)."""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"^\s*(?:[%\w.\-]+\s*=\s*)?"
    r"(\([^=]*\)|[\w\[\],{}\s/]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[\w-]*\(", re.M)

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Sum *output* operand sizes per collective kind.  HLO shapes are
    per-device (SPMD), so totals are per-device bytes moved.

    Collectives are attributed to loop bodies vs straight-line code:
    XLA cost analysis counts while/scan bodies ONCE, so the report must
    multiply in-loop traffic by the trip count (``inloop_bytes``)."""
    out: Dict[str, float] = {}
    # attribute lines to computations: "body"-named computations are the
    # lowering of lax.scan/while bodies
    comp = None
    inloop = 0.0
    for line in hlo.splitlines():
        ls = line.strip()
        if ls.endswith("{") and ("(" in ls) and not ls.startswith("ROOT"):
            head = ls.split("(")[0].strip().lstrip("%")
            comp = head
        m = _COLL_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0.0) + b
        out.setdefault(f"count_{kind}", 0.0)
        out[f"count_{kind}"] += 1
        if comp and ("body" in comp or "while" in comp or "scan" in comp):
            inloop += b
    out["total_bytes"] = sum(v for k, v in out.items()
                             if not k.startswith("count") and k != "total_bytes")
    out["inloop_bytes"] = inloop
    return out


def model_flops(meta: Dict) -> float:
    """Useful-FLOPs accounting per family (documented in EXPERIMENTS.md):
    LM: 6*N*D (dense) / 6*N_active*D (MoE), D = tokens processed;
        decode adds 12*L*kv_len*d_model*B attention-read FLOPs.
    GNN: per layer ~ 2*mlp_cost(V) + 2*E*d (aggregation) * 3 (fwd+bwd).
    Recsys: 6 * (lookup+attn+mlp params touched) * batch."""
    fam = meta.get("family")
    if fam == "lm":
        n = meta.get("n_active_params") or meta["n_params"]
        toks = meta["tokens"]
        mult = 6.0 if meta.get("kind") == "train" else 2.0
        return mult * n * toks
    if fam == "gnn":
        V, E = meta["n_nodes"], meta["n_edges"]
        d, L = meta["d_hidden"], meta["n_layers"]
        per_layer = 2 * V * (2 * d * d) + 2 * E * d
        mult = 3.0   # fwd + bwd
        return mult * (L * per_layer + 2 * V * meta.get("d_feat", d) * d)
    if fam == "recsys":
        B, F, d = meta["batch"], meta["n_fields"], meta["embed_dim"]
        attn = 3 * 2 * F * F * 64 * B + 3 * 2 * F * d * 64 * B
        mlp = 2 * B * (F * 64 * 256 + 256 * 128)
        mult = 3.0 if meta.get("kind") == "train" else 1.0
        base = mult * (attn + mlp)
        if meta.get("n_candidates"):
            base += 2.0 * meta["n_candidates"] * 64
        return base
    if fam == "bfs":
        # BFS has no FLOP workload: useful work = edge examinations.
        return float(meta.get("m", 0))
    return 0.0


def roofline_report(rec: Dict) -> Dict:
    n_dev = rec.get("n_devices", 256)
    flops = rec.get("flops", 0.0) or 0.0
    bytes_acc = rec.get("bytes_accessed", 0.0) or 0.0
    coll = rec.get("collectives", {}).get("total_bytes", 0.0)
    # cost_analysis flops/bytes are per-device under SPMD on the host
    # backend; collective bytes (from per-device HLO shapes) likewise.
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec.get("meta", {}))
    hlo_total = flops * n_dev
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": (mf / hlo_total) if hlo_total else None,
        "bound_time_s": max(terms.values()),
    }
