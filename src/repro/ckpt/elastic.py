"""Elastic re-scaling: reshard a checkpointed state onto a different mesh
(grown/shrunk cluster), and re-partition a BlockedGraph onto a different
(pr, pc) processor grid.

Training state is mesh-agnostic on disk (full logical arrays), so elastic
scaling is device_put with the new mesh's shardings — plus validation
that every spec still divides evenly.  Graphs must be structurally
re-blocked (the paper's data layout is grid-dependent)."""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.graph.formats import BlockedGraph, build_blocked
from repro.graph.rmat import EdgeList


def reshard_state(state: Any, specs: Any, new_mesh) -> Any:
    """Place a (host) state pytree onto new_mesh with the given specs."""
    def put(x, spec):
        sh = NamedSharding(new_mesh, spec if spec is not None else P())
        return jax.device_put(np.asarray(x), sh)
    return jax.tree.map(put, state, specs,
                        is_leaf=lambda x: isinstance(x, (np.ndarray,)) or
                        hasattr(x, "shape"))


def repartition_graph(edges: "EdgeList | None" = None, pr: int = 1,
                      pc: int = 1, align: int = 128, cap_pad: int = 128,
                      *, spec=None, mesh=None, decomposition: str = "2d",
                      **build_kw) -> BlockedGraph:
    """Re-block a graph for a new (pr, pc) grid — used when a pod joins or
    leaves mid-campaign (BFS state is cheap to rebuild: one search).

    Two sources:

    * **host EdgeList** (legacy): re-run ``build_blocked`` on the host
      edge array.
    * **BuildSpec** (born-sharded, PR 8): pass ``spec=`` (a
      ``dist_build.BuildSpec``) and ``mesh=`` sized for the NEW grid —
      the graph is rebuilt device-side by ``dist_build`` straight onto
      the new (pr, pc) blocking from the counter stream; no host edge
      list ever exists.  ``decomposition`` picks the target format
      ("2d" checkerboard, "1d"/"1ds" strips on pr*pc devices), and
      extra ``build_kw`` (route_slack, max_attempts, ...) flow through
      to ``dist_build``.  Bit-identical to a host re-block of the same
      stream at matching align/cap_pad (test_faultinject pins p=1
      parity).
    """
    if spec is not None:
        if mesh is None:
            raise ValueError(
                "repartition_graph(spec=...) needs mesh= sized for the "
                "new grid (BuildSpec repartitioning is device-side)")
        from repro.graph.dist_build import dist_build
        graph, _ = dist_build(spec, decomposition, mesh, (pr, pc),
                              align=align, cap_pad=cap_pad, **build_kw)
        return graph
    if edges is None:
        raise ValueError("repartition_graph needs an EdgeList or a "
                         "BuildSpec (spec=...)")
    return build_blocked(edges, pr, pc, align=align, cap_pad=cap_pad)
