"""Elastic re-scaling: reshard a checkpointed state onto a different mesh
(grown/shrunk cluster), and re-partition a BlockedGraph onto a different
(pr, pc) processor grid.

Training state is mesh-agnostic on disk (full logical arrays), so elastic
scaling is device_put with the new mesh's shardings — plus validation
that every spec still divides evenly.  Graphs must be structurally
re-blocked (the paper's data layout is grid-dependent)."""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.graph.formats import BlockedGraph, build_blocked
from repro.graph.rmat import EdgeList


def reshard_state(state: Any, specs: Any, new_mesh) -> Any:
    """Place a (host) state pytree onto new_mesh with the given specs."""
    def put(x, spec):
        sh = NamedSharding(new_mesh, spec if spec is not None else P())
        return jax.device_put(np.asarray(x), sh)
    return jax.tree.map(put, state, specs,
                        is_leaf=lambda x: isinstance(x, (np.ndarray,)) or
                        hasattr(x, "shape"))


def repartition_graph(edges: EdgeList, pr: int, pc: int, align: int = 128,
                      cap_pad: int = 128) -> BlockedGraph:
    """Re-block a graph for a new (pr, pc) grid — used when a pod joins or
    leaves mid-campaign (BFS state is cheap to rebuild: one search)."""
    return build_blocked(edges, pr, pc, align=align, cap_pad=cap_pad)
