"""Fault-tolerant checkpointing: atomic write (tmp + rename), step-indexed
directories, metadata (config hash + mesh shape) validation, retention.

Multi-host posture: each host writes only its addressable shards; in this
single-process container that degenerates to full arrays, but the layout
(one npz per host + shared meta.json) is the multi-host one."""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _canonical(obj: Any) -> Any:
    """JSON-serializable canonical form of a config object: dataclasses
    become {field: value} dicts tagged with the class name, dicts are
    key-sorted, numpy scalars unboxed.  Anything else is refused loudly
    — falling back to repr() would silently embed ``object.__repr__``
    memory addresses and make the hash differ across processes."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {"__dataclass__": type(obj).__name__,
                **{f.name: _canonical(getattr(obj, f.name))
                   for f in dataclasses.fields(obj)}}
    if isinstance(obj, dict):
        return {str(k): _canonical(v)
                for k, v in sorted(obj.items(), key=lambda kv: str(kv[0]))}
    if isinstance(obj, (list, tuple)):
        return [_canonical(v) for v in obj]
    if isinstance(obj, (np.integer, np.floating, np.bool_)):
        return obj.item()
    if obj is None or isinstance(obj, (str, int, float, bool)):
        return obj
    raise TypeError(
        f"config_hash cannot canonicalize {type(obj).__name__!r} "
        f"({obj!r:.80}): pass a dataclass, dict, list/tuple, or JSON "
        f"scalar — arbitrary objects hash their repr(), which embeds "
        f"the memory address and breaks cross-process stability")


def config_hash(obj: Any) -> str:
    """Process-stable 16-hex-digit digest of a config: canonical JSON of
    dataclass/dict fields (sorted keys, no whitespace), never repr()."""
    payload = json.dumps(_canonical(obj), sort_keys=True,
                         separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree: Any, meta: Optional[Dict] = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "host0.npz"),
                 **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(leaves),
                       "treedef": str(treedef), **(meta or {})}, f)
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            expect_meta: Optional[Dict] = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (values replaced)."""
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    if expect_meta:
        for k, v in expect_meta.items():
            if meta.get(k) != v:
                raise ValueError(f"checkpoint meta mismatch on {k!r}: "
                                 f"{meta.get(k)!r} != {v!r}")
    data = np.load(os.path.join(d, "host0.npz"))
    leaves, treedef = _flatten(like)
    if len(leaves) != meta["n_leaves"]:
        raise ValueError("checkpoint structure mismatch")
    new = [data[f"leaf_{i}"].astype(np.asarray(l).dtype)
           for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, new), meta
