"""Graph + executable checkpoint store (disk -> traversing in seconds).

Built on ckpt/checkpoint.py's primitives (atomic tmp+rename publish,
step directories, retention, meta validation), this store persists the
two expensive artifacts of a traversal session so a fleet process skips
both the distributed build and the XLA compile:

  * **graph shards** — the device arrays of a ``Blocked1DGraph`` /
    ``BlockedGraph`` (host- or device-built) plus enough metadata to
    reconstruct the dataclass: partition, capacities, per-field
    shapes/dtypes, and the config hash of the BuildSpec that generated
    the edges.  Loading with a mesh lands each array directly in its
    sharded placement (one device_put per field, no repartitioning).
  * **AOT executables** — ``BFSEngine``'s compiled search program via
    ``jax.experimental.serialize_executable``, keyed by a canonical
    config hash over (cfg, partition, statics, mesh axes, shipped keys,
    jax version).  ``BFSPlan.compile(store=...)`` deserializes on hash
    hit and persists on miss; a stale hash or absent serializer just
    recompiles — graph loads, by contrast, FAIL LOUDLY on spec-hash or
    mesh-shape mismatch (a silently wrong graph is worse than a
    recompile).

Store layout::

    <root>/graphs/<name>/step_NNNNNNNNNN/{host0.npz, meta.json}
    <root>/execs/exec_<key>_<hash16>/{payload.bin, trees.pkl, meta.json}
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import tempfile
import time
from dataclasses import asdict, is_dataclass
from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import checkpoint
from repro.core.partition import Partition1D, Partition2D
from repro.graph.formats import Blocked1DGraph, BlockedGraph

try:
    from jax.experimental import serialize_executable as _serialize_exec
except Exception:                                    # pragma: no cover
    _serialize_exec = None

FORMAT_VERSION = 1

_GRAPH_KINDS = {"Blocked1DGraph": Blocked1DGraph,
                "BlockedGraph": BlockedGraph}
# dataclass fields that are ints/metadata, not shipped arrays
_SCALAR_FIELDS = {
    "Blocked1DGraph": ("cap", "cap_nzc", "maxdeg_col"),
    "BlockedGraph": ("cap", "cap_seg", "maxdeg_col"),
}


def _mesh_axes(mesh) -> list:
    return [[str(k), int(v)] for k, v in mesh.shape.items()]


def plan_exec_hash(plan) -> str:
    """Canonical hash of everything that determines the compiled search
    program: config, partition, static capacities, mesh axes, the keys
    shipped, and the jax version the executable was built by."""
    return checkpoint.config_hash({
        "cfg": plan.cfg, "part": plan.part, "statics": plan.statics,
        "axes": list(plan.axes), "keys": list(plan.keys),
        "mesh": _mesh_axes(plan.mesh), "jax": jax.__version__,
        "format": FORMAT_VERSION})


class GraphStore:
    """One directory of persisted graphs + executables (see module
    docstring for layout).  ``keep`` bounds retained graph steps per
    name, exactly as ckpt.checkpoint.save does."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep

    # ------------------------------------------------------------------
    # graphs
    # ------------------------------------------------------------------

    def _graph_dir(self, name: str) -> str:
        return os.path.join(self.root, "graphs", name)

    def save_graph(self, name: str, graph, spec=None,
                   step: Optional[int] = None,
                   extra_meta: Optional[Dict] = None) -> str:
        """Persist a graph's device arrays + reconstruction metadata
        under ``graphs/<name>/step_*`` (atomic publish, ``keep``
        retention).  ``spec`` (e.g. dist_build.BuildSpec) is hashed into
        the meta so loads can validate they get the graph they asked
        for."""
        kind = type(graph).__name__
        if kind not in _GRAPH_KINDS:
            raise TypeError(f"cannot store graph of type {kind!r}")
        part = graph.part
        arrays = {k: np.asarray(v)
                  for k, v in graph.device_arrays().items()}
        if isinstance(part, Partition1D):
            part_meta = {"kind": "1d", "n": part.n, "n_orig": part.n_orig,
                         "p": part.p}
        else:
            part_meta = {"kind": "2d", "n": part.n, "n_orig": part.n_orig,
                         "pr": part.pr, "pc": part.pc}
        meta = {
            "graph_kind": kind, "format_version": FORMAT_VERSION,
            "part": json.dumps(part_meta, sort_keys=True),
            "m": int(graph.m), "m_input": int(graph.m_input),
            "scalars": json.dumps(
                {f: int(getattr(graph, f)) for f in _SCALAR_FIELDS[kind]},
                sort_keys=True),
            "fields": json.dumps(
                {k: [list(v.shape), str(v.dtype)]
                 for k, v in sorted(arrays.items())}),
            **({"spec_hash": checkpoint.config_hash(spec),
                "spec": json.dumps(asdict(spec), sort_keys=True)}
               if is_dataclass(spec) and spec is not None else {}),
            **(extra_meta or {}),
        }
        if step is None:
            latest = checkpoint.latest_step(self._graph_dir(name))
            step = 0 if latest is None else latest + 1
        return checkpoint.save(self._graph_dir(name), step, arrays,
                               meta=meta, keep=self.keep)

    def load_graph(self, name: str, mesh=None,
                   step: Optional[int] = None, expect_spec=None,
                   row_axis: str = "data", col_axis: str = "model"):
        """Reconstruct a stored graph.  ``expect_spec`` makes a stale
        graph fail loudly (spec-hash mismatch raises instead of handing
        back the wrong edges); ``mesh`` validates its axis sizes against
        the stored partition and lands every array sharded over the
        graph axes (ready for BFSEngine's no-round-trip ship)."""
        gdir = self._graph_dir(name)
        if step is None:
            step = checkpoint.latest_step(gdir)
            if step is None:
                raise FileNotFoundError(f"no graph steps under {gdir}")
        with open(os.path.join(gdir, f"step_{step:010d}",
                               "meta.json")) as f:
            meta = json.load(f)
        expect = {"format_version": FORMAT_VERSION}
        if expect_spec is not None:
            expect["spec_hash"] = checkpoint.config_hash(expect_spec)
        fields = json.loads(meta["fields"])
        like = {k: np.zeros(shape, dtype=dt)
                for k, (shape, dt) in fields.items()}
        arrays, meta = checkpoint.restore(gdir, step, like,
                                          expect_meta=expect)
        part_meta = json.loads(meta["part"])
        if part_meta["kind"] == "1d":
            part = Partition1D(n=part_meta["n"], n_orig=part_meta["n_orig"],
                               p=part_meta["p"])
            axes, sizes = (row_axis,), (part.p,)
        else:
            part = Partition2D(n=part_meta["n"], n_orig=part_meta["n_orig"],
                               pr=part_meta["pr"], pc=part_meta["pc"])
            axes, sizes = (row_axis, col_axis), (part.pr, part.pc)
        if mesh is not None:
            for ax, want in zip(axes, sizes):
                have = dict(mesh.shape).get(ax)
                if have != want:
                    raise ValueError(
                        f"stored graph {name!r} was partitioned for "
                        f"{ax}={want} but the mesh has {ax}={have} "
                        f"(mesh axes {_mesh_axes(mesh)})")
            sh = NamedSharding(mesh, P(*axes))
            arrays = {k: jax.device_put(v, sh) for k, v in arrays.items()}
        cls = _GRAPH_KINDS[meta["graph_kind"]]
        return cls(part=part, m_input=meta["m_input"], m=meta["m"],
                   **json.loads(meta["scalars"]), **arrays)

    # ------------------------------------------------------------------
    # executables
    # ------------------------------------------------------------------

    def _exec_dir(self, key: str, h: str) -> str:
        return os.path.join(self.root, "execs", f"exec_{key}_{h}")

    def save_executable(self, engine, key: str = "default") -> Optional[str]:
        """Serialize a BFSEngine's compiled single-root search under its
        plan's config hash (atomic publish).  Returns the path, or None
        when jax.experimental.serialize_executable is unavailable (the
        store then persists graphs only)."""
        if _serialize_exec is None:
            return None
        h = plan_exec_hash(engine.plan)
        payload, in_tree, out_tree = _serialize_exec.serialize(engine._exec)
        final = self._exec_dir(key, h)
        os.makedirs(os.path.dirname(final), exist_ok=True)
        tmp = tempfile.mkdtemp(dir=os.path.dirname(final), prefix=".tmp_")
        try:
            with open(os.path.join(tmp, "payload.bin"), "wb") as f:
                f.write(payload)
            with open(os.path.join(tmp, "trees.pkl"), "wb") as f:
                pickle.dump((in_tree, out_tree), f)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"key": key, "hash": h, "jax": jax.__version__,
                           "saved_at": time.time()}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        return final

    def load_executable(self, plan, key: str = "default"):
        """The compiled executable previously saved for an equivalent
        plan (same config hash), or None on miss / absent serializer —
        BFSPlan.compile then falls back to a fresh XLA compile."""
        if _serialize_exec is None:
            return None
        d = self._exec_dir(key, plan_exec_hash(plan))
        if not os.path.isdir(d):
            return None
        with open(os.path.join(d, "payload.bin"), "rb") as f:
            payload = f.read()
        with open(os.path.join(d, "trees.pkl"), "rb") as f:
            in_tree, out_tree = pickle.load(f)
        return _serialize_exec.deserialize_and_load(payload, in_tree,
                                                    out_tree)


def plan_bfs_from_store(store: GraphStore, name: str, cfg, mesh,
                        expect_spec=None, **plan_kw):
    """The disk -> traversal entry point: load a stored graph sharded
    onto ``mesh`` and plan a session over it.  Chain with
    ``.compile(store=store)`` to also reuse the stored executable."""
    from repro.core.engine import plan_bfs
    graph = store.load_graph(name, mesh=mesh, expect_spec=expect_spec,
                             row_axis=plan_kw.get("row_axis", "data"),
                             col_axis=plan_kw.get("col_axis", "model"))
    return plan_bfs(graph, cfg, mesh, **plan_kw)
