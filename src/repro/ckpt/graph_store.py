"""Graph + executable checkpoint store (disk -> traversing in seconds).

Built on ckpt/checkpoint.py's primitives (atomic tmp+rename publish,
step directories, retention, meta validation), this store persists the
two expensive artifacts of a traversal session so a fleet process skips
both the distributed build and the XLA compile:

  * **graph shards** — the device arrays of a ``Blocked1DGraph`` /
    ``BlockedGraph`` (host- or device-built) plus enough metadata to
    reconstruct the dataclass: partition, capacities, per-field
    shapes/dtypes, and the config hash of the BuildSpec that generated
    the edges.  Loading with a mesh lands each array directly in its
    sharded placement (one device_put per field, no repartitioning).
  * **AOT executables** — ``BFSEngine``'s compiled search program via
    ``jax.experimental.serialize_executable``, keyed by a canonical
    config hash over (cfg, partition, statics, mesh axes, shipped keys,
    jax version).  ``BFSPlan.compile(store=...)`` deserializes on hash
    hit and persists on miss; a stale hash or absent serializer just
    recompiles — graph loads, by contrast, FAIL LOUDLY on spec-hash or
    mesh-shape mismatch (a silently wrong graph is worse than a
    recompile).

Store layout (format v2, one file PER SHARD)::

    <root>/graphs/<name>/step_NNNNNNNNNN/{shard_00000.npz, ...,
                                          meta.json}
    <root>/execs/exec_<key>_<hash16>/{payload.bin, trees.pkl, meta.json}

**Content integrity.**  ``meta.json`` carries a CRC32 per shard
(computed over each array's name, dtype, shape, and raw bytes — not
over the npz container, whose zip timestamps are not deterministic).
``load_graph`` verifies every shard's CRC; a corrupted, truncated, or
unreadable shard is *quarantined* (renamed ``*.quarantined``) and
**regenerated in place** from the stored BuildSpec's counter stream
(``graph/dist_build.regen_shard`` — only that shard's slice of the
stream, bit-identical by stream-slice independence).  The regenerated
arrays must reproduce the stored CRC exactly or the load fails loudly;
``store.last_load_report`` records what was checked and repaired.
Writers that crash between ``mkdtemp`` and the atomic rename leak
``.tmp_*`` directories — ``GraphStore.__init__`` sweeps them.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import tempfile
import time
import zlib
from dataclasses import asdict, is_dataclass
from typing import Any, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import checkpoint
from repro.core.partition import Partition1D, Partition2D
from repro.graph.formats import Blocked1DGraph, BlockedGraph

try:
    from jax.experimental import serialize_executable as _serialize_exec
except Exception:                                    # pragma: no cover
    _serialize_exec = None

FORMAT_VERSION = 2

_GRAPH_KINDS = {"Blocked1DGraph": Blocked1DGraph,
                "BlockedGraph": BlockedGraph}
# dataclass fields that are ints/metadata, not shipped arrays
_SCALAR_FIELDS = {
    "Blocked1DGraph": ("cap", "cap_nzc", "maxdeg_col"),
    "BlockedGraph": ("cap", "cap_seg", "maxdeg_col"),
}


def _mesh_axes(mesh) -> list:
    return [[str(k), int(v)] for k, v in mesh.shape.items()]


def shard_crc32(arrays: Dict[str, np.ndarray]) -> int:
    """CRC32 over one shard's arrays in a canonical byte stream: for
    each field in sorted order, its name, dtype string, shape, and raw
    C-contiguous bytes.  Container-independent on purpose — npz zip
    metadata (timestamps) is not reproducible, array content is."""
    c = 0
    for k in sorted(arrays):
        v = np.ascontiguousarray(arrays[k])
        c = zlib.crc32(k.encode(), c)
        c = zlib.crc32(str(v.dtype).encode(), c)
        c = zlib.crc32(np.asarray(v.shape, np.int64).tobytes(), c)
        c = zlib.crc32(v.tobytes(), c)
    return c & 0xFFFFFFFF


def _n_shards(part) -> int:
    return part.p


def _shard_slice(arrays: Dict[str, np.ndarray], part,
                 k: int) -> Dict[str, np.ndarray]:
    """Shard ``k``'s slice of every field (leading block dims dropped:
    (p, ...) -> (...) for strips, (pr, pc, ...) -> (...) for 2d)."""
    if isinstance(part, Partition1D):
        return {f: v[k] for f, v in arrays.items()}
    return {f: v[k // part.pc, k % part.pc] for f, v in arrays.items()}


def _part_from_meta(meta: Dict) -> Any:
    pm = json.loads(meta["part"])
    if pm["kind"] == "1d":
        return Partition1D(n=pm["n"], n_orig=pm["n_orig"], p=pm["p"])
    return Partition2D(n=pm["n"], n_orig=pm["n_orig"], pr=pm["pr"],
                       pc=pm["pc"])


def plan_exec_hash(plan) -> str:
    """Canonical hash of everything that determines the compiled search
    program: config, partition, static capacities, mesh axes, the keys
    shipped, and the jax version the executable was built by."""
    return checkpoint.config_hash({
        "cfg": plan.cfg, "part": plan.part, "statics": plan.statics,
        "axes": list(plan.axes), "keys": list(plan.keys),
        "mesh": _mesh_axes(plan.mesh), "jax": jax.__version__,
        "format": FORMAT_VERSION})


class GraphStore:
    """One directory of persisted graphs + executables (see module
    docstring for layout).  ``keep`` bounds retained graph steps per
    name, exactly as ckpt.checkpoint.save does."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        # forensic state of the most recent load_graph (shards checked,
        # shards repaired + why); None until a graph is loaded
        self.last_load_report: Optional[Dict[str, Any]] = None
        # a writer that died between mkdtemp and the atomic rename left
        # an orphaned .tmp_* dir that can never be published — sweep on
        # open (single-writer discipline: opening a store while another
        # process is mid-save is outside the store's contract)
        self.swept: List[str] = self._sweep_tmp()

    def _sweep_tmp(self) -> List[str]:
        removed = []
        if not os.path.isdir(self.root):
            return removed
        for dirpath, dirnames, _ in os.walk(self.root):
            for d in list(dirnames):
                if d.startswith(".tmp_"):
                    full = os.path.join(dirpath, d)
                    shutil.rmtree(full, ignore_errors=True)
                    dirnames.remove(d)
                    removed.append(full)
        return removed

    # ------------------------------------------------------------------
    # graphs
    # ------------------------------------------------------------------

    def _graph_dir(self, name: str) -> str:
        return os.path.join(self.root, "graphs", name)

    def save_graph(self, name: str, graph, spec=None,
                   step: Optional[int] = None,
                   extra_meta: Optional[Dict] = None) -> str:
        """Persist a graph's device arrays + reconstruction metadata
        under ``graphs/<name>/step_*`` (atomic publish, ``keep``
        retention).  ``spec`` (e.g. dist_build.BuildSpec) is hashed into
        the meta so loads can validate they get the graph they asked
        for."""
        kind = type(graph).__name__
        if kind not in _GRAPH_KINDS:
            raise TypeError(f"cannot store graph of type {kind!r}")
        part = graph.part
        arrays = {k: np.asarray(v)
                  for k, v in graph.device_arrays().items()}
        if isinstance(part, Partition1D):
            part_meta = {"kind": "1d", "n": part.n, "n_orig": part.n_orig,
                         "p": part.p}
        else:
            part_meta = {"kind": "2d", "n": part.n, "n_orig": part.n_orig,
                         "pr": part.pr, "pc": part.pc}
        meta = {
            "graph_kind": kind, "format_version": FORMAT_VERSION,
            "part": json.dumps(part_meta, sort_keys=True),
            "m": int(graph.m), "m_input": int(graph.m_input),
            "scalars": json.dumps(
                {f: int(getattr(graph, f)) for f in _SCALAR_FIELDS[kind]},
                sort_keys=True),
            "fields": json.dumps(
                {k: [list(v.shape), str(v.dtype)]
                 for k, v in sorted(arrays.items())}),
            **({"spec_hash": checkpoint.config_hash(spec),
                "spec": json.dumps(asdict(spec), sort_keys=True)}
               if is_dataclass(spec) and spec is not None else {}),
            **(extra_meta or {}),
        }
        if step is None:
            latest = checkpoint.latest_step(self._graph_dir(name))
            step = 0 if latest is None else latest + 1
        shards = [_shard_slice(arrays, part, k)
                  for k in range(_n_shards(part))]
        meta["shards"] = len(shards)
        meta["shard_crc32"] = [shard_crc32(s) for s in shards]
        gdir = self._graph_dir(name)
        os.makedirs(gdir, exist_ok=True)
        tmp = tempfile.mkdtemp(dir=gdir, prefix=".tmp_")
        try:
            for k, s in enumerate(shards):
                np.savez(os.path.join(tmp, f"shard_{k:05d}.npz"), **s)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({**meta, "step": step, "saved_at": time.time()},
                          f)
            final = os.path.join(gdir, f"step_{step:010d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        checkpoint._retain(gdir, self.keep)
        return final

    def _read_shard(self, path: str) -> Dict[str, np.ndarray]:
        with np.load(path) as z:
            return {k: z[k] for k in z.files}

    def _repair_shard(self, path: str, k: int, meta: Dict, part,
                      want_crc: int) -> Dict[str, np.ndarray]:
        """Quarantine shard ``k``'s file and regenerate its arrays from
        the stored BuildSpec's counter stream; the result must hit the
        stored CRC exactly (stream-slice independence makes the regen
        bit-identical to the original build) or the repair fails."""
        from repro.graph.dist_build import BuildSpec, regen_shard
        if "spec" not in meta:
            raise RuntimeError(
                f"shard {k} of {os.path.dirname(path)} failed its CRC "
                f"check and the graph was stored without a BuildSpec — "
                f"cannot regenerate")
        if os.path.exists(path):
            os.replace(path, path + ".quarantined")
        spec = BuildSpec(**json.loads(meta["spec"]))
        arrs = regen_shard(spec, meta["graph_kind"], part, k,
                           json.loads(meta["scalars"]),
                           json.loads(meta["fields"]))
        got = shard_crc32(arrs)
        if got != want_crc:
            raise RuntimeError(
                f"regenerated shard {k} CRC {got:#010x} does not match "
                f"the stored CRC {want_crc:#010x} — the store meta and "
                f"the BuildSpec disagree; refusing to publish")
        tmp = path + ".tmp_regen.npz"
        try:
            np.savez(tmp, **arrs)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.remove(tmp)
            raise
        return arrs

    def load_graph(self, name: str, mesh=None,
                   step: Optional[int] = None, expect_spec=None,
                   row_axis: str = "data", col_axis: str = "model",
                   repair: bool = True):
        """Reconstruct a stored graph, verifying every shard's CRC.

        ``expect_spec`` makes a stale graph fail loudly (spec-hash
        mismatch raises instead of handing back the wrong edges);
        ``mesh`` validates its axis sizes against the stored partition
        and lands every array sharded over the graph axes (ready for
        BFSEngine's no-round-trip ship).

        A shard whose file is corrupted, truncated, or missing is
        quarantined and regenerated from the stored BuildSpec
        (``repair=False`` raises instead); the regenerated shard must
        reproduce the stored CRC bit-for-bit.  ``self.last_load_report``
        records the verification outcome either way."""
        gdir = self._graph_dir(name)
        if step is None:
            step = checkpoint.latest_step(gdir)
            if step is None:
                raise FileNotFoundError(f"no graph steps under {gdir}")
        sdir = os.path.join(gdir, f"step_{step:010d}")
        with open(os.path.join(sdir, "meta.json")) as f:
            meta = json.load(f)
        if meta.get("format_version") != FORMAT_VERSION:
            raise ValueError(
                f"graph {name!r} step {step} has format_version="
                f"{meta.get('format_version')}; this reader handles "
                f"{FORMAT_VERSION} (re-save the graph)")
        if expect_spec is not None:
            want = checkpoint.config_hash(expect_spec)
            if meta.get("spec_hash") != want:
                raise ValueError(
                    f"graph {name!r} step {step} spec_hash="
                    f"{meta.get('spec_hash')} does not match the "
                    f"expected spec ({want})")
        part = _part_from_meta(meta)
        fields = json.loads(meta["fields"])
        crcs = meta["shard_crc32"]
        shards = []
        repaired = []
        for k in range(meta["shards"]):
            path = os.path.join(sdir, f"shard_{k:05d}.npz")
            arrs, err = None, None
            try:
                arrs = self._read_shard(path)
                got = shard_crc32(arrs)
                if got != crcs[k]:
                    err = (f"CRC mismatch: {got:#010x} != stored "
                           f"{crcs[k]:#010x}")
            except Exception as e:       # unreadable/truncated npz
                err = f"unreadable shard: {e}"
            if err is not None:
                if not repair:
                    raise RuntimeError(
                        f"graph {name!r} step {step} shard {k}: {err} "
                        f"(repair disabled)")
                arrs = self._repair_shard(path, k, meta, part, crcs[k])
                repaired.append({"shard": k, "reason": err})
            shards.append(arrs)
        self.last_load_report = {
            "name": name, "step": step, "shards": meta["shards"],
            "repaired": repaired,
        }
        arrays = {}
        for fname, (shape, dt) in fields.items():
            stacked = np.stack([s[fname] for s in shards])
            arrays[fname] = stacked.reshape(shape).astype(dt, copy=False)
        if isinstance(part, Partition1D):
            axes, sizes = (row_axis,), (part.p,)
        else:
            axes, sizes = (row_axis, col_axis), (part.pr, part.pc)
        if mesh is not None:
            for ax, want in zip(axes, sizes):
                have = dict(mesh.shape).get(ax)
                if have != want:
                    raise ValueError(
                        f"stored graph {name!r} was partitioned for "
                        f"{ax}={want} but the mesh has {ax}={have} "
                        f"(mesh axes {_mesh_axes(mesh)})")
            sh = NamedSharding(mesh, P(*axes))
            arrays = {k: jax.device_put(v, sh) for k, v in arrays.items()}
        cls = _GRAPH_KINDS[meta["graph_kind"]]
        return cls(part=part, m_input=meta["m_input"], m=meta["m"],
                   **json.loads(meta["scalars"]), **arrays)

    # ------------------------------------------------------------------
    # executables
    # ------------------------------------------------------------------

    def _exec_dir(self, key: str, h: str) -> str:
        return os.path.join(self.root, "execs", f"exec_{key}_{h}")

    def save_executable(self, engine, key: str = "default") -> Optional[str]:
        """Serialize a BFSEngine's compiled single-root search under its
        plan's config hash (atomic publish).  Returns the path, or None
        when jax.experimental.serialize_executable is unavailable (the
        store then persists graphs only)."""
        if _serialize_exec is None:
            return None
        h = plan_exec_hash(engine.plan)
        payload, in_tree, out_tree = _serialize_exec.serialize(engine._exec)
        final = self._exec_dir(key, h)
        os.makedirs(os.path.dirname(final), exist_ok=True)
        tmp = tempfile.mkdtemp(dir=os.path.dirname(final), prefix=".tmp_")
        try:
            with open(os.path.join(tmp, "payload.bin"), "wb") as f:
                f.write(payload)
            with open(os.path.join(tmp, "trees.pkl"), "wb") as f:
                pickle.dump((in_tree, out_tree), f)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"key": key, "hash": h, "jax": jax.__version__,
                           "saved_at": time.time()}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        return final

    def load_executable(self, plan, key: str = "default"):
        """The compiled executable previously saved for an equivalent
        plan (same config hash), or None on miss / absent serializer —
        BFSPlan.compile then falls back to a fresh XLA compile."""
        if _serialize_exec is None:
            return None
        d = self._exec_dir(key, plan_exec_hash(plan))
        if not os.path.isdir(d):
            return None
        with open(os.path.join(d, "payload.bin"), "rb") as f:
            payload = f.read()
        with open(os.path.join(d, "trees.pkl"), "rb") as f:
            in_tree, out_tree = pickle.load(f)
        return _serialize_exec.deserialize_and_load(payload, in_tree,
                                                    out_tree)


def plan_bfs_from_store(store: GraphStore, name: str, cfg, mesh,
                        expect_spec=None, **plan_kw):
    """The disk -> traversal entry point: load a stored graph sharded
    onto ``mesh`` and plan a session over it.  Chain with
    ``.compile(store=store)`` to also reuse the stored executable."""
    from repro.core.engine import plan_bfs
    graph = store.load_graph(name, mesh=mesh, expect_spec=expect_spec,
                             row_axis=plan_kw.get("row_axis", "data"),
                             col_axis=plan_kw.get("col_axis", "model"))
    return plan_bfs(graph, cfg, mesh, **plan_kw)
