"""Vertex/edge partitions behind one API: 1D row blocks and the paper's
2D (pr x pc) Eq. (1) checkerboard.

Both partition classes share the duck-typed surface the drivers rely on
(``n``, ``n_orig``, ``p``, ``chunk``, ``decomposition``, ``vec_to_blocks``
/ ``blocks_to_vec``); ``make_partition_1d`` / ``make_partition`` are the
two constructors, and ``repro.core.bfs`` dispatches on the config's
``decomposition`` field ("1d" | "2d").

1D (Buluc & Madduri's baseline, the paper's comparison axis): processor i
owns the vertex chunk V_i = [i*chunk, (i+1)*chunk) and the adjacency
*row* strip T[V_i, :] (T[v, u] = 1 iff edge u->v) — all edges pointing
into its vertices.  There is only one vector layout, so the expand step
is a single allgather of the frontier along the one mesh axis and both
the fold and transpose phases of the 2D algorithm vanish (at the price
of the O(n)-per-processor frontier storage the paper's Eq. 2 charges).

Vertex-vector layouts (the paper's distributed-vector conventions):

  layout A ("row-aligned"): the n-vector is split into p = pr*pc chunks of
    size ``chunk``; device (i,j) owns chunk k = i*pc + j.  Consecutive j
    tile the row strip R_i = [i*nr, (i+1)*nr).  Parents/completed live here;
    the fold (alltoall along the processor row) lands here natively.

  layout B ("col-aligned"): device (i,j) owns chunk k = j*pr + i.
    Consecutive i tile the column strip C_j = [j*nc, (j+1)*nc), so an
    allgather along the processor *column* (mesh axis "data") reconstructs
    exactly C_j — the expand step.  TransposeVector converts A -> B with a
    single collective-permute (the paper's p2p transpose, Table 1).

The adjacency block at device (i,j) is T[R_i, C_j] where T[v, u] = 1 iff
edge u->v (pre-transposed, as the paper assumes for top-down).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Partition1D:
    """1D row decomposition over ``p`` processors (single mesh axis)."""
    n: int        # padded vertex count
    n_orig: int   # original vertex count
    p: int

    @property
    def decomposition(self) -> str:
        return "1d"

    @property
    def chunk(self) -> int:      # owned vertices per processor (= nr)
        return self.n // self.p

    @property
    def nr(self) -> int:         # rows per block strip
        return self.chunk

    @property
    def nc(self) -> int:         # cols per block strip = all of them
        return self.n

    # ---- layout maps (host-side helpers; device code uses axis_index) ----

    def owner(self, v: np.ndarray):
        return v // self.chunk, v % self.chunk

    def vec_to_blocks(self, x: np.ndarray) -> np.ndarray:
        """(n,) -> (p, chunk)."""
        return x.reshape(self.p, self.chunk)

    def blocks_to_vec(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(x).reshape(self.n)[: self.n_orig]


@dataclass(frozen=True)
class Partition2D:
    n: int        # padded vertex count
    n_orig: int   # original vertex count
    pr: int
    pc: int

    @property
    def decomposition(self) -> str:
        return "2d"

    @property
    def p(self) -> int:
        return self.pr * self.pc

    @property
    def chunk(self) -> int:
        return self.n // self.p

    @property
    def nr(self) -> int:          # rows per block (R_i size)
        return self.n // self.pr

    @property
    def nc(self) -> int:          # cols per block (C_j size)
        return self.n // self.pc

    # ---- layout maps (host-side helpers; device code uses axis_index) ----

    def owner_A(self, v: np.ndarray):
        k = v // self.chunk
        return k // self.pc, k % self.pc, v % self.chunk

    def owner_B(self, v: np.ndarray):
        k = v // self.chunk
        return k % self.pr, k // self.pr, v % self.chunk

    def transpose_perm(self):
        """ppermute pairs for TransposeVector (layout A chunk k -> B owner)."""
        return [(k, (k % self.pr) * self.pc + (k // self.pr))
                for k in range(self.p)]

    def inverse_transpose_perm(self):
        return [(d, s) for (s, d) in self.transpose_perm()]

    def vec_to_blocks(self, x: np.ndarray) -> np.ndarray:
        """(n,) -> (pr, pc, chunk) in layout A."""
        return x.reshape(self.pr, self.pc, self.chunk)

    def blocks_to_vec(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(x).reshape(self.n)[: self.n_orig]


def make_partition(n_orig: int, pr: int, pc: int, align: int = 128) -> Partition2D:
    """Pad n so chunk = n/(pr*pc) is a multiple of ``align`` (>=32 so bitmap
    words tile chunks exactly; 128 matches TPU lane width)."""
    if align % 32:
        raise ValueError("align must be a multiple of 32 (bitmap words)")
    p = pr * pc
    quantum = p * align
    n = ((max(n_orig, 1) + quantum - 1) // quantum) * quantum
    return Partition2D(n=n, n_orig=n_orig, pr=pr, pc=pc)


def make_partition_1d(n_orig: int, p: int, align: int = 128) -> Partition1D:
    """1D counterpart of :func:`make_partition` with identical padding
    rules, so a (p,) 1D and a (pr, pc) 2D partition of the same graph
    with pr*pc == p agree on the padded ``n`` (depth arrays comparable
    element-for-element)."""
    if align % 32:
        raise ValueError("align must be a multiple of 32 (bitmap words)")
    quantum = p * align
    n = ((max(n_orig, 1) + quantum - 1) // quantum) * quantum
    return Partition1D(n=n, n_orig=n_orig, p=p)
