"""2D (pr x pc) vertex/edge partition — the paper's Eq. (1) checkerboard.

Vertex-vector layouts (the paper's distributed-vector conventions):

  layout A ("row-aligned"): the n-vector is split into p = pr*pc chunks of
    size ``chunk``; device (i,j) owns chunk k = i*pc + j.  Consecutive j
    tile the row strip R_i = [i*nr, (i+1)*nr).  Parents/completed live here;
    the fold (alltoall along the processor row) lands here natively.

  layout B ("col-aligned"): device (i,j) owns chunk k = j*pr + i.
    Consecutive i tile the column strip C_j = [j*nc, (j+1)*nc), so an
    allgather along the processor *column* (mesh axis "data") reconstructs
    exactly C_j — the expand step.  TransposeVector converts A -> B with a
    single collective-permute (the paper's p2p transpose, Table 1).

The adjacency block at device (i,j) is T[R_i, C_j] where T[v, u] = 1 iff
edge u->v (pre-transposed, as the paper assumes for top-down).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Partition2D:
    n: int        # padded vertex count
    n_orig: int   # original vertex count
    pr: int
    pc: int

    @property
    def p(self) -> int:
        return self.pr * self.pc

    @property
    def chunk(self) -> int:
        return self.n // self.p

    @property
    def nr(self) -> int:          # rows per block (R_i size)
        return self.n // self.pr

    @property
    def nc(self) -> int:          # cols per block (C_j size)
        return self.n // self.pc

    # ---- layout maps (host-side helpers; device code uses axis_index) ----

    def owner_A(self, v: np.ndarray):
        k = v // self.chunk
        return k // self.pc, k % self.pc, v % self.chunk

    def owner_B(self, v: np.ndarray):
        k = v // self.chunk
        return k % self.pr, k // self.pr, v % self.chunk

    def transpose_perm(self):
        """ppermute pairs for TransposeVector (layout A chunk k -> B owner)."""
        return [(k, (k % self.pr) * self.pc + (k // self.pr))
                for k in range(self.p)]

    def inverse_transpose_perm(self):
        return [(d, s) for (s, d) in self.transpose_perm()]

    def vec_to_blocks(self, x: np.ndarray) -> np.ndarray:
        """(n,) -> (pr, pc, chunk) in layout A."""
        return x.reshape(self.pr, self.pc, self.chunk)

    def blocks_to_vec(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(x).reshape(self.n)[: self.n_orig]


def make_partition(n_orig: int, pr: int, pc: int, align: int = 128) -> Partition2D:
    """Pad n so chunk = n/(pr*pc) is a multiple of ``align`` (>=32 so bitmap
    words tile chunks exactly; 128 matches TPU lane width)."""
    if align % 32:
        raise ValueError("align must be a multiple of 32 (bitmap words)")
    p = pr * pc
    quantum = p * align
    n = ((max(n_orig, 1) + quantum - 1) // quantum) * quantum
    return Partition2D(n=n, n_orig=n_orig, pr=pr, pc=pc)
