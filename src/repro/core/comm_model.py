"""The paper's §6 alpha-beta communication model (Table 1 / Eq. 2).

Counts are 64-bit words per *entire search*, matching the paper's units.
The distributed implementation threads live counters through every
collective; benchmarks compare measured "useful words" against these
closed forms.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


def topdown_words(n: int, m: int, pr: int, pc: int) -> float:
    """w_t ~= 4m + n*pr  (undirected: each edge examined from both sides,
    2 words per edge endpoint pair; expand replicates n along columns)."""
    return 4.0 * m + float(n) * pr


def bottomup_words(n: int, pr: int, pc: int, s_b: float = 4.0) -> float:
    """w_b ~= n * (s_b*(pr+pc+1)/64 + 2)   (Table 1 total)."""
    return n * (s_b * (pr + pc + 1) / 64.0 + 2.0)


def ratio_eq2(k: float, pc: int, s_b: float = 4.0) -> float:
    """Eq. (2), square grid pr=pc: (pc + 4k) / (s_b(2pc+1)/64 + 2)."""
    return (pc + 4.0 * k) / (s_b * (2.0 * pc + 1.0) / 64.0 + 2.0)


def fold_bitmap_level_words(nr: int, pc: int, cap_w: int) -> float:
    """Per-level, per-device wire of the bitmap fold (steps._fold_bitmap):
    the exchange is exactly 2 bitmap all_to_all rounds (candidate
    presence out, winner bits back — nr bits = nr/64 words each) plus
    2 id all_to_alls (winner parent values + local offsets, pc*cap_w
    ids each, 1 id = 1 word):

        2 * nr/64  +  2 * pc * cap_w

    This is the ONE place the formula lives: the live ``wire_fold``
    counter multiplies it by p, and tests pin the counter against it —
    docstring, counter, and model cannot drift."""
    return 2.0 * nr / 64.0 + 2.0 * pc * cap_w


def level_collective_budget(decomposition: str, mode: str, pc: int = 1,
                            fold_mode: str = "alltoall",
                            compact_updates: bool = False,
                            codec: str = "none",
                            expand_chunks: int = 1) -> int:
    """Per-level collective-op budget of the ``instrument=False`` fast
    path, counted as collective ops in the LOWERED level body (both
    branches of a lax.cond count — StableHLO keeps them in the text
    even though only one executes).  ``tests/test_perf_guard.py``
    asserts the compiled programs stay within these, so future PRs
    cannot silently re-bloat the schedule; the shared ``_search_loop``
    adds exactly one fused vector psum per level on top (plus one pmax
    when searches are pod-batched).

      2d top-down : transpose ppermute + allgather + fold
                    (alltoall: 1 op; ring reduce: pc-1 ppermutes;
                    bitmap: 4 all_to_alls — 2 bitmap rounds + winner
                    values + offsets — and the runtime-fallback variant
                    adds its overflow pmax + dense all_to_all branch)
      2d bottom-up: transpose ppermute + allgather + (pc-1) hoisted
                    rotation ppermutes + ONE batched update all_to_all
                    (compact updates add 1 pmax + the dense-fallback
                    all_to_all in the other cond branch).  With
                    ``expand_chunks > 1`` the systolic rotation is
                    SOFTWARE-PIPELINED: the carried bitmap splits into a
                    pure-rotation R chain (pre-level completed, issued
                    ahead of the local scan with no data dependency on
                    it) and a G chain of accumulated finds (consumed
                    only at scan end for the exactness post-filter) —
                    2(pc-1) ppermutes instead of pc-1, buying overlap
                    with an extra latency-cheap permute per sub-step.
      1d          : one bitmap allgather per level; ``expand_chunks=C``
                    splits it into C pipelined sub-chunk allgathers
                    (budget C), each consumed while the next is in
                    flight — same total bytes
                    (``chunked_expand_1d_level_words``).
      1ds td      : sparse/dense allgather pair (one cond, 2 in text;
                    1 executes) — the overflow predicate rides the
                    previous level's fused reduction.  The packed codec
                    (codec="packed") changes the BYTES on the wire, not
                    the op count: the count word rides inside the same
                    allgathered bucket buffer, so the budget is
                    identical by construction and the guard pins that.
                    ``expand_chunks=C`` runs C sub-bucket exchanges per
                    branch: budget 2C in text, C execute.
    """
    if codec not in ("none", "packed"):
        raise ValueError(f"no collective budget modeled for "
                         f"codec={codec!r}")
    if expand_chunks < 1:
        raise ValueError(f"no collective budget modeled for "
                         f"expand_chunks={expand_chunks!r}")
    if decomposition == "2d":
        if mode == "td":
            folds = {"alltoall": 1, "reduce": max(pc - 1, 1),
                     "bitmap_pure": 4, "bitmap": 6}
            if fold_mode not in folds:
                raise ValueError(f"no collective budget modeled for "
                                 f"fold_mode={fold_mode!r}")
            return 2 + folds[fold_mode]
        if mode == "bu":
            rot = (2 if expand_chunks > 1 else 1) * (pc - 1)
            return rot + 3 + (2 if compact_updates else 0)
    if decomposition in ("1d", "1ds") and mode in ("td", "bu"):
        if decomposition == "1ds" and mode == "td":
            return 2 * expand_chunks
        if decomposition == "1d" and mode == "td":
            return expand_chunks
        return 1     # bottom-up always exchanges the one dense bitmap
    raise ValueError(f"no collective budget modeled for "
                     f"decomposition={decomposition!r} mode={mode!r}")


def level_budgets_for(decomposition: str, *, pc: int, p: int,
                      fold_mode: str = "alltoall",
                      compact_updates: bool = False,
                      frontier_codec: str = "none",
                      expand_chunks: int = 1) -> Dict[str, int]:
    """Both per-level budgets for one registry-enumerated schedule case
    (``repro.analysis.registry.budget_cases``): the keyword names match
    the BFSConfig fields a Decomposition entry lists in its
    ``schedule_dims``, so the enumeration needs no per-entry adapter.
    The grid size the budget scales with is the fold/ring extent ``pc``
    for the 2d checkerboard and the strip count ``p`` for 1d/1ds."""
    grid = pc if decomposition == "2d" else p
    return {mode: level_collective_budget(
        decomposition, mode, grid, fold_mode=fold_mode,
        compact_updates=compact_updates, codec=frontier_codec,
        expand_chunks=expand_chunks) for mode in ("td", "bu")}


# ---------------------------------------------------------------------------
# 1D row decomposition (the paper's comparison baseline, Alg. 1/2)
# ---------------------------------------------------------------------------


def expand_1d_level_words(n, p):
    """Per-level wire of the DENSE 1D frontier exchange: one n-bit bitmap
    per level, every chunk replicated to the other p-1 processors ->
    (p-1) * n/64 global 64-bit words.  Pure arithmetic, so it is the ONE
    place the word-size conversion lives: the live ``wire_expand``
    counter (core/steps_1d.py, traced values) and the host-side closed
    forms both call it and cannot drift."""
    return (p - 1) * (n / 64.0)


def chunked_expand_1d_level_words(n, p, n_chunks: int):
    """Per-level wire of the CHUNKED (software-pipelined) dense 1D
    expand: the one bitmap allgather splits into ``n_chunks`` sub-chunk
    allgathers — each owner ships chunk/n_chunks bits per step, all
    steps together exactly the chunk — so the total is IDENTICAL to the
    single-gather schedule.  Chunking moves latency (overlap with the
    per-sub-chunk SpMSV), not bytes; this form exists so the measured
    ``wire_expand`` counter and the overlap artifact pin that invariant
    rather than assume it.  ``n_chunks`` must divide the per-strip
    bitmap extent (chunk/32 packed words) — the same constraint
    ``plan_bfs`` validates."""
    if n_chunks < 1:
        raise ValueError(f"expand_chunks must be >= 1, got {n_chunks}")
    chunk_words = (n // max(p, 1)) // 32
    if chunk_words % n_chunks:
        raise ValueError(
            f"expand_chunks={n_chunks} does not divide the per-strip "
            f"bitmap extent ({chunk_words} packed words)")
    return expand_1d_level_words(n, p)


def expand_1d_words(n: int, p: int, n_levels: int) -> float:
    """Exact wire volume of the allgather-based ``"1d"`` implementation
    over a whole search: ``n_levels`` dense bitmap exchanges.  This is
    the closed form the 1D ``wire_expand`` counter must reproduce (there
    is no fold/transpose/rotate wire in 1D)."""
    return float(n_levels) * expand_1d_level_words(n, p)


def sparse_expand_1d_words(n_f, p):
    """Per-level wire of the SPARSE owner-directed 1D frontier exchange
    (``"1ds"``): each of the ``n_f`` global frontier ids is shipped by
    its owner to the other p-1 processors, 1 id = 1 word.  Works on
    traced values (the live counter) and on host floats (the model)."""
    return n_f * (p - 1.0)


def codec_bits(chunk: int) -> int:
    """Fixed offset width of the packed ``"1ds"`` frontier codec: local
    offsets live in [0, chunk), so ceil(log2(chunk)) bits each.  Static
    — chunk is a partition constant — which is what lets encode/decode
    be pure gathers (kernels/frontier_codec)."""
    return max(1, int(chunk - 1).bit_length())


def codec_packed_words(cap_x: int, bits: int) -> int:
    """u32 words holding ``cap_x`` offsets bit-packed at ``bits`` each."""
    return -((-cap_x * bits) // 32)


def codec_bucket_words(cap_x: int, bits: int) -> int:
    """Physical u32 words of one encoded bucket: 1 count word + the
    packed payload.  The tiled allgather moves p of these per level."""
    return 1 + codec_packed_words(cap_x, bits)


def compressed_expand_1d_words(n_f, p, bits: int, n_chunks: int = 1):
    """Per-level wire of the PACKED sparse 1D exchange in the paper's
    64-bit-word units: each of the ``n_f`` frontier ids costs ``bits``
    bits instead of a 64-bit word, plus one u32 count word per bucket
    from each of the p owners.  Everything is replicated to the other
    p-1 processors.  Works on traced values (the live counter) and on
    host floats (the model); the raw-id counterpart is
    ``sparse_expand_1d_words``.

    ``n_chunks > 1`` models the software-pipelined exchange: each owner
    ships ``n_chunks`` sub-range buckets per level (one count word
    each), with offsets packed at ``codec_bits(chunk / n_chunks)`` bits
    — callers pass the narrower width.  Id bytes shrink, count-word
    bytes grow n_chunks-fold; the raw codec and the dense fallback are
    byte-identical to the unchunked schedule."""
    return (p - 1.0) * (n_f * bits + 32.0 * p * n_chunks) / 64.0


def compressed_expand_padded_words(cap_x: int, p: int, bits: int) -> float:
    """Physical buffer volume of the packed static-shape exchange, in
    64-bit words: p owners x (p-1) peers x the full encoded bucket
    (``codec_bucket_words`` u32 = half that many paper words), sentinel
    slots included.  Compare against ``sparse_expand_padded_words``
    (whose i32 ids are likewise 1/2 paper word each, reported in id
    units there) and the dense ``expand_1d_level_words``."""
    return float(p) * (p - 1.0) * codec_bucket_words(cap_x, bits) / 2.0


def hybrid_expand_1d_level_words(n_f_local_max: float, n_f: float, n: int,
                                 p: int, cap_x: int,
                                 bits: int = 0) -> float:
    """Overflow model for one ``"1ds"`` level: the sparse exchange ships
    ids while every per-processor bucket fits ``cap_x``; any overflow
    falls back to the dense bitmap for that level (the per-level hybrid,
    mirroring the direction-optimizing switch).  ``bits > 0`` models the
    packed codec on the sparse branch; 0 keeps raw 1-id-=-1-word ids."""
    if n_f_local_max > cap_x:
        return expand_1d_level_words(n, p)
    if bits > 0:
        return compressed_expand_1d_words(n_f, p, bits)
    return sparse_expand_1d_words(n_f, p)


def sparse_expand_padded_words(cap_x: int, p) -> float:
    """Physical buffer volume of the STATIC-SHAPE sparse exchange: the
    tiled allgather always moves the full cap_x-slot bucket — sentinels
    included — from each of the p owners to its p-1 peers, whatever the
    live frontier size.  Reported in the same 1-id-=-1-word units as
    ``sparse_expand_1d_words`` so the two are directly comparable; note
    ids are i32 on the wire, so at the planned crossover capacity
    (cap_x ~ n/(64p)) the padded buckets cost the same BYTES as the
    n-bit dense bitmap — the id counter measures the alltoallv volume
    of the sparse formulation the exchange models, not the padding."""
    return float(p) * (p - 1.0) * cap_x


def plan_cap_x(n: int, p: int, m: int, align: int = 32,
               bits: int = 64) -> int:
    """Plan the ``"1ds"`` per-destination send-bucket capacity from the
    graph degree stats.  The dense bitmap costs (p-1)*n/64 words a level
    while the sparse exchange costs n_f*bits/64*(p-1) (``bits`` = 64 for
    raw ids, ``codec_bits(chunk)`` for the packed codec), so sparse only
    wins while the global frontier is under n/bits ids — n/(bits*p) per
    processor.  The bucket cap bounds the PER-PROCESSOR frontier, so the
    degree-stat headroom is the expected per-bucket level-1 load,
    (2m/n)/p on a symmetrized graph (a whole level-1 frontier spreads
    over all p owners); the ``align`` floor absorbs skew.  Capping at
    the crossover keeps the planned hybrid within bucket granularity of
    the per-level optimum: a fitting level ships at most the dense
    bitmap volume, and levels the sparse path cannot win overflow to the
    bitmap.  ``m`` is required: planning without edge stats silently
    collapses the headroom term, which is exactly the call-site bug this
    signature exists to refuse."""
    if m <= 0:
        raise ValueError(
            f"plan_cap_x needs the real edge count to size the level-1 "
            f"headroom (got m={m}); thread PlanStatics.n_real_edges or "
            f"graph.m from the call site")
    chunk = max(n // max(p, 1), 1)
    d_avg = int(2.0 * m / n) if n else 0
    cap = max(n // (max(bits, 1) * max(p, 1)), d_avg // max(p, 1) + 1,
              align)
    cap = ((cap + align - 1) // align) * align
    return min(cap, ((chunk + align - 1) // align) * align)


def topdown_1d_words(m: int, p: int) -> float:
    """Classic sparse 1D top-down volume (Buluc & Madduri): every
    cross-processor edge endpoint is shipped once as a vertex id, and a
    random partition leaves a (p-1)/p fraction of the 2m directed
    endpoints remote.  The measured counterpart is the ``"1ds"``
    ``wire_expand`` counter with overflow disabled (cap_x = chunk)."""
    return 2.0 * m * (p - 1) / p


def strip_csr_pointer_words(n: int, p: int) -> float:
    """§5.1 storage charge against 1D compressed formats: an uncompressed
    strip CSC needs n+1 column pointers on EVERY processor — O(n*p)
    aggregate words, growing with the machine at fixed n."""
    return float(p) * (n + 1)


def strip_dcsc_pointer_words(nzc_total: float, p: int) -> float:
    """Strip DCSC answer: (jc, cp) pairs over non-empty columns only,
    2*nzc + 2 words per strip — O(min(n, m)) aggregate, independent of n
    per processor.  ``nzc_total`` = sum of per-strip non-empty column
    counts (<= m, and <= the 2*ef*n distinct sources for R-MAT)."""
    return 2.0 * float(nzc_total) + 2.0 * p


# ---------------------------------------------------------------------------
# Build-phase (distributed graph construction) closed forms
# ---------------------------------------------------------------------------


def build_route_1d_words(m_input: int, p: int) -> float:
    """Expected owner-routing volume of the 1D distributed build: every
    generated edge is emitted in both directions (symmetrization happens
    before routing, 2*m_input records), each record is one 64-bit word
    (two i32 endpoints), and a uniformly partitioned destination leaves
    a (p-1)/p fraction remote.  One all_to_all round."""
    return 2.0 * m_input * (p - 1) / p


def build_route_2d_words(m_input: int, pr: int, pc: int) -> float:
    """Expected two-hop routing volume of the 2D build: hop 1 moves each
    record to its block COLUMN owner along the pc-sized axis, hop 2 to
    its block ROW owner along the pr-sized axis — the same record count
    as 1D, charged per hop."""
    return 2.0 * m_input * ((pc - 1) / pc + (pr - 1) / pr)


def build_route_padded_words(p: int, cap_route: int) -> float:
    """Actual shipped volume of one capped all_to_all routing round:
    every device ships its full (p, cap_route) record buckets minus the
    diagonal, regardless of fill — the static-shape tax the expected
    forms above are compared against."""
    return float(p) * (p - 1) * cap_route


def rmat_strip_skew(p: int, a: float = 0.57, b: float = 0.19) -> float:
    """Expected fraction of R-MAT edge endpoints owned by the heaviest
    1/p vertex range (the low-id strip): each of the log2(p) leading
    quadrant draws lands in the top half with probability a+b, so strip
    0 receives ~(a+b)**log2(p) of all endpoints — the factor a uniform
    cap_route must be inflated by before skewed routing fits."""
    import math
    if p <= 1:
        return 1.0
    return float((a + b) ** math.log2(p))


def plan_cap_route(records: int, p: int, a: float = 0.57, b: float = 0.19,
                   slack: float = 1.5, pad: int = 32) -> int:
    """Static per-destination bucket capacity for one routing round:
    ``records`` locally generated records spread over p buckets whose
    heaviest takes ~rmat_strip_skew(p), inflated by ``slack`` for
    sampling noise.  Overflow is detected on device and raised loudly —
    the build never silently drops an edge."""
    frac = max(rmat_strip_skew(p, a, b), 1.0 / max(p, 1))
    cap = int(slack * frac * records) + pad
    return ((cap + pad - 1) // pad) * pad


@dataclass(frozen=True)
class AlphaBeta:
    """Machine terms for the latency/bandwidth model. Defaults are TPU v5e
    ICI-flavored stand-ins (used for *relative* predictions only)."""
    alpha_n: float = 1e-6        # network latency (s)
    beta_n: float = 1.0 / 50e9   # s per byte per link

    def expand_cost(self, n: int, pr: int, pc: int, word_bytes: int = 8) -> float:
        return pr * self.alpha_n + (n / pc) * word_bytes * self.beta_n

    def fold_cost(self, m: int, pr: int, pc: int, word_bytes: int = 8) -> float:
        p = pr * pc
        return pc * self.alpha_n + (m / p) * word_bytes * self.beta_n

    def bottomup_level_cost(self, n: int, pr: int, pc: int) -> float:
        # pc sub-steps of rotation + updates, bitmap-compressed
        rotate = pc * (self.alpha_n + (n / (pr * pc) / 8) * self.beta_n)
        gather = pr * self.alpha_n + (n / pc / 8) * self.beta_n
        updates = pc * self.alpha_n + (n / (pr * pc)) * 8 * self.beta_n
        return rotate + gather + updates


# ---------------------------------------------------------------------------
# Graph500 validator collective budget (core/validate.py)
# ---------------------------------------------------------------------------


def validate_collective_budget(decomposition: str) -> Dict[str, int]:
    """Whole-program collective budget for the sharded parent-tree
    validator, per decomposition (pinned in tests/test_perf_guard.py).

    The validator spends exactly: one tiled all_gather per mesh axis to
    replicate the candidate parents (1 for the strip entries, 2 for
    2d), one psum to OR the per-shard tree-edge-existence marks, and
    one psum for the final (6,) verdict vector.  Everything else —
    pointer-doubling depth resolution, per-edge level/reachability
    checks — is shard-local.
    """
    if decomposition == "2d":
        gathers = 2
    elif decomposition in ("1d", "1ds"):
        gathers = 1
    else:
        raise ValueError(
            f"no validator collective budget for {decomposition!r}; "
            "extend validate_collective_budget alongside the new "
            "decomposition's local_edges hook")
    return {"all-gather": gathers, "all-reduce": 2,
            "total": gathers + 2}
