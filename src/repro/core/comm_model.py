"""The paper's §6 alpha-beta communication model (Table 1 / Eq. 2).

Counts are 64-bit words per *entire search*, matching the paper's units.
The distributed implementation threads live counters through every
collective; benchmarks compare measured "useful words" against these
closed forms.
"""
from __future__ import annotations

from dataclasses import dataclass


def topdown_words(n: int, m: int, pr: int, pc: int) -> float:
    """w_t ~= 4m + n*pr  (undirected: each edge examined from both sides,
    2 words per edge endpoint pair; expand replicates n along columns)."""
    return 4.0 * m + float(n) * pr


def bottomup_words(n: int, pr: int, pc: int, s_b: float = 4.0) -> float:
    """w_b ~= n * (s_b*(pr+pc+1)/64 + 2)   (Table 1 total)."""
    return n * (s_b * (pr + pc + 1) / 64.0 + 2.0)


def ratio_eq2(k: float, pc: int, s_b: float = 4.0) -> float:
    """Eq. (2), square grid pr=pc: (pc + 4k) / (s_b(2pc+1)/64 + 2)."""
    return (pc + 4.0 * k) / (s_b * (2.0 * pc + 1.0) / 64.0 + 2.0)


# ---------------------------------------------------------------------------
# 1D row decomposition (the paper's comparison baseline, Alg. 1/2)
# ---------------------------------------------------------------------------


def expand_1d_words(n: int, p: int, n_levels: int) -> float:
    """Exact wire volume of our allgather-based 1D implementation: each
    level moves one dense n-bit frontier bitmap, every chunk replicated
    to the other p-1 processors -> (p-1) * n/64 global 64-bit words per
    level.  This is the closed form the 1D ``wire_expand`` counter must
    reproduce (there is no fold/transpose/rotate wire in 1D)."""
    return float(n_levels) * (p - 1) * n / 64.0


def topdown_1d_words(m: int, p: int) -> float:
    """Classic sparse 1D top-down volume (Buluc & Madduri): every
    cross-processor edge endpoint is shipped once as a vertex id, and a
    random partition leaves a (p-1)/p fraction of the 2m directed
    endpoints remote."""
    return 2.0 * m * (p - 1) / p


def strip_csr_pointer_words(n: int, p: int) -> float:
    """§5.1 storage charge against 1D compressed formats: an uncompressed
    strip CSC needs n+1 column pointers on EVERY processor — O(n*p)
    aggregate words, growing with the machine at fixed n."""
    return float(p) * (n + 1)


def strip_dcsc_pointer_words(nzc_total: float, p: int) -> float:
    """Strip DCSC answer: (jc, cp) pairs over non-empty columns only,
    2*nzc + 2 words per strip — O(min(n, m)) aggregate, independent of n
    per processor.  ``nzc_total`` = sum of per-strip non-empty column
    counts (<= m, and <= the 2*ef*n distinct sources for R-MAT)."""
    return 2.0 * float(nzc_total) + 2.0 * p


@dataclass(frozen=True)
class AlphaBeta:
    """Machine terms for the latency/bandwidth model. Defaults are TPU v5e
    ICI-flavored stand-ins (used for *relative* predictions only)."""
    alpha_n: float = 1e-6        # network latency (s)
    beta_n: float = 1.0 / 50e9   # s per byte per link

    def expand_cost(self, n: int, pr: int, pc: int, word_bytes: int = 8) -> float:
        return pr * self.alpha_n + (n / pc) * word_bytes * self.beta_n

    def fold_cost(self, m: int, pr: int, pc: int, word_bytes: int = 8) -> float:
        p = pr * pc
        return pc * self.alpha_n + (m / p) * word_bytes * self.beta_n

    def bottomup_level_cost(self, n: int, pr: int, pc: int) -> float:
        # pc sub-steps of rotation + updates, bitmap-compressed
        rotate = pc * (self.alpha_n + (n / (pr * pc) / 8) * self.beta_n)
        gather = pr * self.alpha_n + (n / pc / 8) * self.beta_n
        updates = pc * self.alpha_n + (n / (pr * pc)) * 8 * self.beta_n
        return rotate + gather + updates
