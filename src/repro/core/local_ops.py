"""LocalOps: the pluggable local-discovery layer behind both BFS
decompositions.

The paper's §5.1 axis — which *local* data structure (CSR vs DCSC) backs
the per-processor SpMSV — is orthogonal to the decomposition (1D strips
vs 2D blocks), but the drivers used to hard-code it as string checks and
shipping-key tuples spread across core/bfs.py, core/steps.py,
core/steps_1d.py and graph/formats.py (and the 1D path rejected
everything but dense).  This module makes the axis explicit: a
``LocalOps`` entry, registered under ``(decomposition, local_mode,
storage)``, declares

  * ``keys``           — which graph device arrays the driver ships
  * ``topdown``        — the SpMSV closure (frontier -> candidate parents)
  * ``bottomup``       — the unvisited-row scan closure (one sub-step)
  * ``storage_words``  — the §5.1 word-accounting model for the format

The plan layer (``core/engine.py``) looks the entry up once at plan
time and threads it through LevelArgs via the Decomposition entry's
``make_level_args`` (``core/decomp.py``); the step modules just call
the closures.  Registered combos (Fig. 6 grid):

  2d  x {dense, kernel} x {csr, dcsc}  (dense ignores pointer storage)
  1d  x {dense, kernel} x {csr, dcsc}  (kernel/dcsc = the Pallas strip
                                        SpMSV over doubly compressed
                                        global source columns)
  1ds x {dense, kernel} x {csr, dcsc}  (mirrors the 1d entries: the
                                        sparse-exchange decomposition
                                        changes the expand collective,
                                        not local discovery)

Closure signatures (all arrays squeezed to the local block/strip):

  topdown(g, f_words, f_mask, nr, col_offset, args)
      -> (cand (nr,) i32 candidate parents, edges_examined_local f32)
  bottomup(rp_seg, ue_win, f_words, cvec, col_offset, n_edges, ve_win)
      -> (chunk,) i32 newly discovered parents (INT_INF = none)

``f_words`` is the packed frontier bitmap over the block's column range
(uint32 words), ``f_mask`` its unpacked bool form; 2D passes the C_j
slice with col_offset = j*nc, 1D passes the full allgathered frontier
with col_offset = 0 (strip ids are global).  ``args`` is the LevelArgs /
LevelArgs1D NamedTuple (cap_f, maxdeg statics).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class LocalOps:
    decomposition: str            # "1d" | "2d"
    local_mode: str               # "dense" | "kernel"
    storage: str                  # "csr" | "dcsc"
    keys: Tuple[str, ...]         # graph device arrays to ship
    topdown: Callable             # SpMSV closure (see module docstring)
    bottomup: Callable            # bottom-up sub-step closure
    storage_words: Callable       # (graph) -> Dict[str, int], §5.1 words
    # Optional per-chunk SpMSV for the software-pipelined 1d/1ds expand
    # (expand_chunks > 1): consumes ONE raw gathered sub-chunk buffer
    # (owner-major (p * w_sub,) u32 words) without materializing the
    # full-size frontier bitmap.  Signature:
    #   topdown_chunk(g, g_sub, k, n_chunks, nr, col_offset, args)
    #       -> (cand (nr,) i32, edges_examined_local f32)
    # Entries without one fall back to scattering the sub-chunk into a
    # full-size partial bitmap and calling ``topdown`` (exact either
    # way: candidates min-combine across chunks).
    topdown_chunk: Callable = None


_REGISTRY: Dict[Tuple[str, str, str], LocalOps] = {}


def register_local_ops(ops: LocalOps) -> LocalOps:
    key = (ops.decomposition, ops.local_mode, ops.storage)
    if key in _REGISTRY:
        raise ValueError(f"duplicate LocalOps {key}")
    _REGISTRY[key] = ops
    return ops


def get_local_ops(decomposition: str, local_mode: str,
                  storage: str) -> LocalOps:
    key = (decomposition, local_mode, storage)
    if key not in _REGISTRY:
        raise ValueError(
            f"no LocalOps registered for {key}; have "
            f"{sorted(_REGISTRY)}")
    return _REGISTRY[key]


def registered_combos() -> Tuple[Tuple[str, str, str], ...]:
    return tuple(sorted(_REGISTRY))


def unregister_local_ops(decomposition: str, local_mode: str,
                         storage: str) -> None:
    """Remove an entry — for scoped test/fixture registrations only
    (mirrors decomp.unregister_decomposition)."""
    key = (decomposition, local_mode, storage)
    if key not in _REGISTRY:
        raise ValueError(f"no LocalOps registered for {key}")
    del _REGISTRY[key]


# ---------------------------------------------------------------------------
# Top-down SpMSV closures
# ---------------------------------------------------------------------------


def _td_dense(g, f_words, f_mask, nr, col_offset, args):
    """Edge-parallel dense scan over the whole block/strip (oracle path):
    work O(nnz) regardless of frontier size."""
    from repro.kernels.spmsv.ref import spmsv_dense
    cand = spmsv_dense(g["edge_src"], g["row_idx"], g["nnz"], f_mask, nr,
                       col_offset)
    ex = jnp.sum(jnp.arange(g["edge_src"].shape[0]) < g["nnz"],
                 dtype=jnp.float32)
    return cand, ex


def _td_kernel_csr(g, f_words, f_mask, nr, col_offset, args):
    """Pallas ragged gather through the uncompressed col_ptr — O(n)
    pointer words per block column range (strip: per processor).  The
    cap_f=0 fallback covers the whole column range, so in 1D the gather
    scratch is O(n * maxdeg) per strip — the deliberately unscalable
    Fig. 6 comparison cell; pass cap_f (a bound the frontier never
    exceeds: larger frontiers are silently truncated) to shrink it."""
    from repro.kernels.spmsv import ops as spmsv_ops
    cap_f = args.cap_f or f_mask.shape[0]
    ridx = jnp.pad(g["row_idx"], (0, 256))
    cand = spmsv_ops.spmsv_block_csr(g["col_ptr"], ridx, f_mask, nr,
                                     col_offset, cap_f=cap_f,
                                     maxdeg=args.maxdeg)
    ex = jnp.sum(jnp.where(f_mask, g["col_ptr"][1:] - g["col_ptr"][:-1], 0),
                 dtype=jnp.float32)
    return cand, ex


def _dcsc_edges_examined(jc, cp, nzc, f_mask):
    """Sum of frontier-column segment lengths straight off the compressed
    pointers (padded slots have zero-length segments)."""
    nc = f_mask.shape[0]
    slot = jnp.arange(jc.shape[0])
    live = (slot < nzc) & (jc < nc) & f_mask[jnp.minimum(jc, nc - 1)]
    return jnp.sum(jnp.where(live, cp[1:] - cp[:-1], 0), dtype=jnp.float32)


def _td_kernel_dcsc_2d(g, f_words, f_mask, nr, col_offset, args):
    """Pallas gather through (JC, CP) with the per-frontier-vertex binary
    search — the paper's hypersparse indirection cost, Fig. 6."""
    from repro.kernels.spmsv import ops as spmsv_ops
    cap_f = args.cap_f or f_mask.shape[0]
    ridx = jnp.pad(g["row_idx"], (0, 256))
    cand = spmsv_ops.spmsv_block_dcsc(g["jc"], g["cp"], g["nzc"], ridx,
                                      f_mask, nr, col_offset, cap_f=cap_f,
                                      maxdeg=args.maxdeg)
    return cand, _dcsc_edges_examined(g["jc"], g["cp"], g["nzc"], f_mask)


def _td_strip_dcsc(g, f_words, f_mask, nr, col_offset, args):
    """The 1D strip SpMSV: walk the strip's non-empty GLOBAL columns
    against the allgathered frontier bitmap (kernels/spmsv/strip.py) —
    no O(n) pointer array and no per-frontier-vertex search."""
    from repro.kernels.spmsv import ops as spmsv_ops
    ridx = jnp.pad(g["row_idx"], (0, 256))
    cand = spmsv_ops.spmsv_strip_dcsc(g["jc"], g["cp"], g["nzc"], ridx,
                                      f_words, nr, maxdeg=args.maxdeg)
    return cand, _dcsc_edges_examined(g["jc"], g["cp"], g["nzc"], f_mask)


def _dcsc_edges_examined_chunk(jc, cp, nzc, g_sub, k, n_chunks, chunk, n):
    """Frontier-column segment-length sum for ONE pipelined sub-chunk:
    bitmap-tests each column id against the raw owner-major sub-chunk
    buffer (no full-size bitmap), so the per-chunk sums add up exactly
    to the unchunked ``_dcsc_edges_examined``."""
    wpc = chunk // 32
    w_sub = wpc // n_chunks
    slot = jnp.arange(jc.shape[0])
    uc = jnp.minimum(jc, n - 1)
    wi = uc >> 5
    owner = wi // wpc
    lw = wi - owner * wpc
    in_rng = (lw >= k * w_sub) & (lw < (k + 1) * w_sub)
    pos = jnp.where(in_rng, owner * w_sub + (lw - k * w_sub), 0)
    bit = ((g_sub[pos] >> (uc.astype(jnp.uint32) & jnp.uint32(31)))
           & jnp.uint32(1)) == 1
    live = (slot < nzc) & (jc < n) & in_rng & bit
    return jnp.sum(jnp.where(live, cp[1:] - cp[:-1], 0), dtype=jnp.float32)


def _td_strip_dcsc_chunk(g, g_sub, k, n_chunks, nr, col_offset, args):
    """Per-chunk entry of the strip SpMSV for the software-pipelined
    expand: the Pallas kernel consumes the raw gathered sub-chunk buffer
    directly (kernels/spmsv/strip.py chunk entry point); the caller
    min-combines candidates across chunks."""
    from repro.kernels.spmsv import ops as spmsv_ops
    part = args.part
    ridx = jnp.pad(g["row_idx"], (0, 256))
    cand = spmsv_ops.spmsv_strip_dcsc_chunk(
        g["jc"], g["cp"], g["nzc"], ridx, g_sub, nr, n=part.n, p=part.p,
        k=k, n_chunks=n_chunks, maxdeg=args.maxdeg)
    ex = _dcsc_edges_examined_chunk(g["jc"], g["cp"], g["nzc"], g_sub, k,
                                    n_chunks, part.chunk, part.n)
    return cand, ex


# ---------------------------------------------------------------------------
# Bottom-up sub-step closures
# ---------------------------------------------------------------------------


def _bu_ref(rp_seg, ue_win, f_words, cvec, col_offset, n_edges, ve_win):
    from repro.kernels.bottomup.ref import bottomup_substep
    return bottomup_substep(rp_seg, ue_win, f_words, cvec, col_offset,
                            n_edges, ve_win=ve_win)


def _bu_kernel(rp_seg, ue_win, f_words, cvec, col_offset, n_edges, ve_win):
    """Pallas tile-granular early-exit scan; per-edge rows come from the
    CSR pointers inside the kernel, so ve_win is unused."""
    from repro.kernels.bottomup import ops as bu_ops
    chunk = rp_seg.shape[0] - 1
    return bu_ops.bottomup_substep(rp_seg, jnp.pad(ue_win, (0, 512)),
                                   f_words, cvec, col_offset, n_edges,
                                   rt=min(128, chunk))


# ---------------------------------------------------------------------------
# Registry entries
# ---------------------------------------------------------------------------

_DENSE_KEYS_2D = ("edge_src", "row_idx", "nnz", "deg_A", "col_idx",
                  "row_ptr", "seg_ptr", "edge_dst")
_KERNEL_CSR_KEYS_2D = ("col_ptr", "row_idx", "nnz", "deg_A", "col_idx",
                       "row_ptr", "seg_ptr")
_KERNEL_DCSC_KEYS_2D = ("jc", "cp", "nzc", "row_idx", "nnz", "deg_A",
                        "col_idx", "row_ptr", "seg_ptr")
_DENSE_KEYS_1D = ("edge_src", "row_idx", "nnz", "deg_A", "col_idx",
                  "row_ptr", "edge_dst")
_KERNEL_CSR_KEYS_1D = ("col_ptr", "row_idx", "nnz", "deg_A", "col_idx",
                       "row_ptr")
_KERNEL_DCSC_KEYS_1D = ("jc", "cp", "nzc", "row_idx", "nnz", "deg_A",
                        "col_idx", "row_ptr")


def _words(mode):
    return lambda graph: graph.storage_words(mode)


for _storage in ("csr", "dcsc"):
    # dense local discovery reads per-edge arrays only — no pointer
    # arrays shipped, but the storage model still reports the mode the
    # caller would pay for on a real deployment
    register_local_ops(LocalOps(
        decomposition="2d", local_mode="dense", storage=_storage,
        keys=_DENSE_KEYS_2D, topdown=_td_dense, bottomup=_bu_ref,
        storage_words=_words(_storage)))
    register_local_ops(LocalOps(
        decomposition="1d", local_mode="dense", storage=_storage,
        keys=_DENSE_KEYS_1D, topdown=_td_dense, bottomup=_bu_ref,
        storage_words=_words(_storage)))

register_local_ops(LocalOps(
    decomposition="2d", local_mode="kernel", storage="csr",
    keys=_KERNEL_CSR_KEYS_2D, topdown=_td_kernel_csr, bottomup=_bu_kernel,
    storage_words=_words("csr")))
register_local_ops(LocalOps(
    decomposition="2d", local_mode="kernel", storage="dcsc",
    keys=_KERNEL_DCSC_KEYS_2D, topdown=_td_kernel_dcsc_2d,
    bottomup=_bu_kernel, storage_words=_words("dcsc")))
register_local_ops(LocalOps(
    decomposition="1d", local_mode="kernel", storage="csr",
    keys=_KERNEL_CSR_KEYS_1D, topdown=_td_kernel_csr, bottomup=_bu_kernel,
    storage_words=_words("csr")))
register_local_ops(LocalOps(
    decomposition="1d", local_mode="kernel", storage="dcsc",
    keys=_KERNEL_DCSC_KEYS_1D, topdown=_td_strip_dcsc, bottomup=_bu_kernel,
    storage_words=_words("dcsc"), topdown_chunk=_td_strip_dcsc_chunk))

# "1ds" (sparse-exchange 1D, core/steps_1d_sparse.py) traverses the same
# row strips with the same local kernels — only the expand collective
# differs — so its LocalOps entries mirror "1d" exactly.
for _combo in [k for k in sorted(_REGISTRY) if k[0] == "1d"]:
    register_local_ops(dataclasses.replace(_REGISTRY[_combo],
                                           decomposition="1ds"))
