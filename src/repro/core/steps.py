"""Per-level BFS steps: parallel 2D top-down (Alg. 3) and bottom-up
(Alg. 4), written for shard_map bodies over mesh axes (row, col) = the
paper's (pr, pc) processor grid.

Conventions (see core/partition.py):
  * block at device (i,j) = T[R_i, C_j], T[v,u]=1 iff edge u->v
  * parents pi / frontier f are layout-A chunks of size ``chunk``
  * expand allgathers the C_j frontier slice along mesh axis ``row``
  * fold exchanges candidate parents along mesh axis ``col``
  * bottom-up rotates the completed bitmap along ``col`` (pc sub-steps)

Counters (dict of f32 scalars, *global* paper-units: 1 id = 1 word,
1 bitmap bit = 1/64 word):
  wire_*   what our static-shape implementation actually moves
  use_*    the paper's sparse-equivalent volume (for Eq.2 validation)
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import comm_model
from repro.core.frontier import (INT_INF, expand_bitmap, pack_bits,
                                 unpack_bits)

COUNTER_KEYS = ("wire_transpose", "wire_expand", "wire_fold", "wire_rotate",
                "wire_updates", "use_expand", "use_fold", "use_rotate",
                "use_updates", "edges_examined", "edges_useful")


def zero_counters() -> Dict[str, jax.Array]:
    return {k: jnp.float32(0) for k in COUNTER_KEYS}


class LevelArgs(NamedTuple):
    """Static/per-search context threaded into level steps."""
    part: "object"            # Partition2D (static)
    row_axis: str
    col_axis: str
    fold_mode: str            # "alltoall" | "reduce"
    perm: tuple               # transpose perm A->B
    cap_seg: int = 0          # static bottom-up sub-step edge window
    local_mode: str = "dense"  # "dense" | "kernel" (Pallas)
    storage: str = "csr"      # "csr" | "dcsc" (kernel pointer indirection)
    cap_f: int = 0            # kernel mode: frontier capacity (0 = nc)
    maxdeg: int = 0           # kernel mode: max column-segment length
    cap_w: int = 0            # bitmap fold: winner capacity (0 = chunk//16)
    use_edge_dst: bool = False  # bottom-up: read per-edge rows (no search)
    compact_updates: bool = False  # bottom-up: compact (child,parent) sends
    cap_u: int = 0            # compact updates capacity (0 = chunk//8)
    ops: "object" = None      # LocalOps entry (None = look up from strings)
    instrument: bool = True   # False: compile out counters/level_stats
    #                           (the latency-lean fast path; parents
    #                           identical, ctr returned empty)
    # > 1 switches the bottom-up systolic rotation to the software-
    # pipelined R/G split ring (see bottomup_level); the value itself is
    # a toggle for 2D — the chunk count only shapes the 1d/1ds expand
    expand_chunks: int = 1


def _resolve_ops(args: "LevelArgs"):
    """The LocalOps entry for this step config (builders pass it
    pre-resolved; direct LevelArgs constructions fall back to the
    registry lookup on the string fields)."""
    if args.ops is not None:
        return args.ops
    from repro.core.local_ops import get_local_ops
    return get_local_ops("2d", args.local_mode, args.storage)


# ---------------------------------------------------------------------------
# Top-down (Algorithm 3)
# ---------------------------------------------------------------------------


def _fold_alltoall(cand: jax.Array, pc: int, chunk: int, col_axis: str):
    """Paper-faithful fold: Alltoall along the processor row + local min."""
    t = cand.reshape(pc, chunk)
    r = lax.all_to_all(t, col_axis, split_axis=0, concat_axis=0, tiled=False)
    return jnp.min(r, axis=0)


def _fold_bitmap(cand: jax.Array, pc: int, chunk: int, col_axis: str,
                 cap_w: int):
    """Beyond-paper fold: exchange *presence bitmaps* instead of dense
    candidate arrays, then fetch only the winners' parent ids
    (Checconi-style single-parent-update, restructured for static shapes).

    Round 1: all_to_all of packed candidate-presence bitmaps
             (nr/64 words vs nr words dense -> 64x smaller).
    Round 2: owners pick the lowest source column with a bit set and
             return per-source winner bitmaps (again nr/64 words).
    Round 3: each source compacts the parent ids it won (static cap
             ``cap_w`` per destination chunk; overflow falls back to the
             dense fold via lax.cond) and two all_to_alls deliver the
             winner values + their local offsets.

    Wire per level (the ``comm_model.fold_bitmap_level_words`` closed
    form): 2 bitmap rounds + 2 id exchanges = 2*nr/64 + 2*pc*cap_w words
    per device, vs nr dense.  With cap_w = chunk/4: ~3.4x less fold
    traffic at pc=16."""
    present = cand != INT_INF                         # (nr,)
    pb = pack_bits(present).reshape(pc, chunk // 32)
    # round 1: per-source presence bitmaps for each destination chunk
    recv = lax.all_to_all(pb, col_axis, split_axis=0, concat_axis=0)
    bits = unpack_bits(recv.reshape(-1)).reshape(pc, chunk)  # src j -> bit
    # owner picks winner source column = lowest j with a bit
    j_idx = jnp.arange(pc)[:, None]
    winner = jnp.min(jnp.where(bits, j_idx, pc), axis=0)     # (chunk,)
    # round 2: tell each source which vertices it won
    win_bits = winner[None, :] == j_idx                      # (pc, chunk)
    wb = pack_bits(win_bits.reshape(-1)).reshape(pc, chunk // 32)
    back = lax.all_to_all(wb, col_axis, split_axis=0, concat_axis=0)
    my_wins = unpack_bits(back.reshape(-1)).reshape(pc, chunk)  # dest q
    # round 3: compact won parent ids per destination chunk.
    # jnp.where(..., size=k) returns win positions in ASCENDING order
    # (fills at the end), so the rank of a win within its destination
    # chunk is its global position minus the win count of all earlier
    # chunks — one cumsum over per-chunk counts, O(nr) on the hot fold
    # path instead of the former argsort+searchsorted O(nr log nr).
    flat_wins = my_wins.reshape(-1)                           # (nr,)
    idx_s = jnp.where(flat_wins, size=pc * cap_w, fill_value=-1)[0]
    counts = jnp.sum(my_wins, axis=1)                         # per-dest wins
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    q_s = jnp.where(idx_s >= 0, idx_s // chunk, pc)
    rank = (jnp.arange(idx_s.size, dtype=jnp.int32)
            - starts[jnp.minimum(q_s, pc - 1)].astype(jnp.int32))
    ok = (idx_s >= 0) & (rank < cap_w)
    vals = jnp.where(ok, cand[jnp.maximum(idx_s, 0)], INT_INF)
    offs = jnp.where(ok, idx_s % chunk, chunk)                # local offset
    send_v = jnp.full((pc, cap_w), INT_INF, jnp.int32).at[
        jnp.where(ok, q_s, pc), jnp.where(ok, rank, 0)].set(vals, mode="drop")
    send_o = jnp.full((pc, cap_w), chunk, jnp.int32).at[
        jnp.where(ok, q_s, pc), jnp.where(ok, rank, 0)].set(
        offs.astype(jnp.int32), mode="drop")
    rv = lax.all_to_all(send_v, col_axis, split_axis=0, concat_axis=0)
    ro = lax.all_to_all(send_o, col_axis, split_axis=0, concat_axis=0)
    t = jnp.full((chunk,), INT_INF, jnp.int32).at[
        ro.reshape(-1)].min(rv.reshape(-1), mode="drop")
    return t, my_wins


def _fold_ring_reduce(cand: jax.Array, pc: int, chunk: int, col_axis: str):
    """Bandwidth-optimal ring reduce-scatter in the (min) semiring: pc-1
    neighbor hops on the torus instead of a full all-to-all (beyond-paper:
    contention-free on ICI, in-network combining of duplicate updates)."""
    if pc == 1:
        return cand.reshape(pc, chunk)[0]
    acc = cand.reshape(pc, chunk)
    j = lax.axis_index(col_axis)
    perm = [(q, (q + 1) % pc) for q in range(pc)]
    for t in range(pc - 1):
        idx_s = (j - t - 1) % pc
        piece = lax.dynamic_slice_in_dim(acc, idx_s, 1, axis=0)
        recv = lax.ppermute(piece, col_axis, perm)
        idx_r = (j - t - 2) % pc
        cur = lax.dynamic_slice_in_dim(acc, idx_r, 1, axis=0)
        acc = lax.dynamic_update_slice_in_dim(
            acc, jnp.minimum(cur, recv), idx_r, axis=0)
    out = lax.dynamic_slice_in_dim(acc, j % pc, 1, axis=0)
    return out[0]


def topdown_level(g: Dict[str, jax.Array], pi: jax.Array, front: jax.Array,
                  args: LevelArgs, lv=None
                  ) -> Tuple[jax.Array, jax.Array, Dict]:
    """One top-down level. g holds the local block arrays (squeezed).
    ``lv`` is the fast-path per-level context from ``_search_loop``
    (unused by the 2D steps); with ``args.instrument`` False every
    counter psum is compiled out and ``ctr`` comes back empty."""
    part = args.part
    pr, pc, chunk, nc, nr = part.pr, part.pc, part.chunk, part.nc, part.nr
    p = float(part.p)
    instr = args.instrument
    ctr = zero_counters() if instr else {}

    # --- Expand: transpose + allgather along processor column ------------
    f_words, wire = expand_bitmap(front, args.perm,
                                  (args.row_axis, args.col_axis))
    f_cj = unpack_bits(f_words)                      # (nc,) bool
    if instr:
        n_f = lax.psum(jnp.sum(front, dtype=jnp.float32),
                       (args.row_axis, args.col_axis))
        ctr["wire_transpose"] = jnp.float32(chunk / 64.0) * p
        ctr["wire_expand"] = wire * p - ctr["wire_transpose"]
        ctr["use_expand"] = n_f * (pr - 1)           # sparse ids, replicated

    # --- Local discovery: SpMSV in the (select-source, min) semiring -----
    # format-specific work lives behind the LocalOps entry (CSR/DCSC x
    # dense/kernel); the step only owns the collectives and counters
    j = lax.axis_index(args.col_axis)
    col_offset = (j * nc).astype(jnp.int32)
    cand, ex_local = _resolve_ops(args).topdown(g, f_words, f_cj, nr,
                                                col_offset, args)
    if instr:
        ctr["edges_examined"] = lax.psum(ex_local,
                                         (args.row_axis, args.col_axis))
        m_f = lax.psum(jnp.sum(jnp.where(front, g["deg_A"], 0),
                               dtype=jnp.float32),
                       (args.row_axis, args.col_axis))
        ctr["edges_useful"] = m_f

    # --- Fold: exchange candidates along the processor row ---------------
    if args.fold_mode == "alltoall":
        t = _fold_alltoall(cand, pc, chunk, args.col_axis)
        if instr:
            ctr["wire_fold"] = jnp.float32((pc - 1) * chunk) * p
    elif args.fold_mode in ("bitmap", "bitmap_pure"):
        cap_w = args.cap_w or max(chunk // 16, 32)
        t, my_wins = _fold_bitmap(cand, pc, chunk, args.col_axis, cap_w)
        if args.fold_mode == "bitmap":
            # runtime fallback: a source chunk overflowing cap_w wins
            # re-runs the dense fold (compiled but executed only then).
            # NB: the predicate must be GLOBALLY consistent — the branch
            # contains collectives that lower as whole-mesh ops.
            overflow = lax.pmax(
                jnp.max(jnp.sum(my_wins, axis=1)),
                (args.row_axis, args.col_axis)) > cap_w
            t = lax.cond(overflow,
                         lambda c: _fold_alltoall(c, pc, chunk,
                                                  args.col_axis),
                         lambda c: t, cand)
        if instr:
            ctr["wire_fold"] = jnp.float32(
                comm_model.fold_bitmap_level_words(pc * chunk, pc,
                                                   cap_w)) * p
    else:
        t = _fold_ring_reduce(cand, pc, chunk, args.col_axis)
        if instr:
            ctr["wire_fold"] = jnp.float32((pc - 1) * chunk) * p
    if instr:
        n_cand = lax.psum(jnp.sum(cand != INT_INF, dtype=jnp.float32),
                          (args.row_axis, args.col_axis))
        ctr["use_fold"] = 2.0 * n_cand               # (child, parent) pairs

    # --- Local update -----------------------------------------------------
    newly = (pi == -1) & (t != INT_INF)
    pi = jnp.where(newly, t, pi)
    return pi, newly, ctr


# ---------------------------------------------------------------------------
# Bottom-up (Algorithm 4)
# ---------------------------------------------------------------------------


def bottomup_level(g: Dict[str, jax.Array], pi: jax.Array, front: jax.Array,
                   args: LevelArgs, lv=None
                   ) -> Tuple[jax.Array, jax.Array, Dict]:
    """One bottom-up level: pc sub-steps with systolic rotation of the
    completed bitmap along the processor row (Fig. 1).

    The per-sub-step update exchange is BATCHED: sub-step s discovers
    parents for the segment owned (layout A) by device (j-s) mod pc —
    destination-disjoint by construction — so the segments accumulate in
    a per-destination buffer and ONE tiled all_to_all delivers them at
    level end, replacing pc-1 latency-bound ppermutes (plus, in compact
    mode, pc-1 per-sub-step overflow pmaxes collapse to one).  The cseg
    rotation ppermute is hoisted to the TOP of the next sub-step —
    issued before the graph slicing and the Pallas scan — so an async
    permute can overlap the local work; its payload (the previous
    sub-step's completed|found bits) is unchanged.  Updates are applied
    in the same s-order after the exchange; the carried completed bitmap
    marks each vertex at its first discovery, so every vertex is
    discovered by at most one sub-step and parents are bit-identical to
    the per-sub-step exchange.

    With ``args.expand_chunks > 1`` the rotation is fully SOFTWARE-
    PIPELINED (the generalization of the hoist above): the carried
    bitmap splits into two chains so the permute no longer waits on the
    scan.  The **R chain** is a pure rotation of the PRE-LEVEL completed
    bitmap — its payload exists at sub-step start, so the ppermute has
    no data dependency on the local scan and overlaps it.  The **G
    chain** accumulates this level's finds (G after sub-step s =
    G_before | F_s, rotated alongside R) on a second ppermute whose
    result is consumed only AFTER the scan, as the exactness
    post-filter: the scan runs against the stale R-only bitmap
    (re-scanning rows discovered earlier this level), then
    ``found &= ~G`` masks those re-discoveries out.  Per-row scan
    results are independent of other rows' cvec, so the filtered result
    is bit-identical to the exact-bitmap scan; the instrumented edges
    counter is computed from the exact ``R | G`` union so counters
    match the classic schedule too.  Cost: 2(pc-1) ppermutes per level
    instead of pc-1 (``wire_rotate`` doubles; ``use_rotate`` — the
    semantic payload — does not), bought for the scan-latency overlap
    (``comm_model.level_collective_budget``)."""
    part = args.part
    pr, pc, chunk, nc, nr = part.pr, part.pc, part.chunk, part.nc, part.nr
    p = float(part.p)
    axes = (args.row_axis, args.col_axis)
    instr = args.instrument
    ctr = zero_counters() if instr else {}

    # --- Gather frontier (dense bitmap; per level) ------------------------
    f_words, wire = expand_bitmap(front, args.perm, axes)
    if instr:
        ctr["wire_transpose"] = jnp.float32(chunk / 64.0) * p
        ctr["wire_expand"] = wire * p - ctr["wire_transpose"]
        ctr["use_expand"] = jnp.float32(chunk / 64.0 * (1 + (pr - 1))) * p

    j = lax.axis_index(args.col_axis)
    cseg = pi != -1                       # completed = has parent (own chunk)

    rot_perm = [(q, (q + 1) % pc) for q in range(pc)]
    edges_use = jnp.float32(0)

    col_offset = (j * nc).astype(jnp.int32)
    pure = args.fold_mode.endswith("_pure")
    compact = args.compact_updates
    cap_u = args.cap_u or max(chunk // 8, 32)
    ops = _resolve_ops(args)

    # per-destination accumulation for the level-end batched exchange
    # (compact mode never holds sub-step 0: the self segment pays no
    # wire and must not be capacity-truncated — it rides the self slot)
    if compact:
        send_i = jnp.full((pc, cap_u), chunk, jnp.int32)
        send_v = jnp.full((pc, cap_u), INT_INF, jnp.int32)
    if (not compact) or (not pure):
        send_d = jnp.full((pc, chunk), INT_INF, jnp.int32)
    self_par = None
    max_found = jnp.int32(0)
    carry = None
    pipelined = args.expand_chunks > 1
    if pipelined:
        # R/G split ring: R (in ``carry``) rotates the pre-level
        # completed bitmap — a payload with no scan dependency — while
        # g_acc carries the accumulated this-level finds for the
        # post-scan filter.
        carry = pack_bits(cseg)
        g_acc = jnp.zeros((chunk // 32,), jnp.uint32)
    g_seen = None

    for s in range(pc):
        if s > 0:
            # hoisted rotation: issued ahead of this sub-step's slicing
            # and local scan so the async permute overlaps them
            if pipelined:
                # R is known since the PREVIOUS sub-step's start, so
                # this permute overlaps the previous scan as well; the
                # G permute's result is not consumed until after THIS
                # sub-step's scan — neither blocks the Pallas scan
                carry = lax.ppermute(carry, args.col_axis, rot_perm)
                g_in = lax.ppermute(g_acc, args.col_axis, rot_perm)
                cseg = unpack_bits(carry)
            else:
                cseg = unpack_bits(lax.ppermute(carry, args.col_axis,
                                                rot_perm))
            if instr:
                ctr["wire_rotate"] += jnp.float32(
                    (2 if pipelined else 1) * chunk / 64.0) * p
                ctr["use_rotate"] += jnp.float32(chunk / 64.0) * p
        elif pipelined:
            g_in = g_acc                  # no prior finds at sub-step 0
        seg_id = (j - s) % pc             # segment V_{i, j-s} this sub-step
        e0 = lax.dynamic_index_in_dim(g["seg_ptr"], seg_id, keepdims=False)
        e1 = lax.dynamic_index_in_dim(g["seg_ptr"], seg_id + 1, keepdims=False)
        rp_seg = (lax.dynamic_slice_in_dim(g["row_ptr"], seg_id * chunk,
                                           chunk + 1) - e0).astype(jnp.int32)
        ue = lax.dynamic_slice_in_dim(g["col_idx"], e0, args.cap_seg)
        n_edges = (e1 - e0).astype(jnp.int32)
        cvec = cseg.astype(jnp.int32)
        ve = (lax.dynamic_slice_in_dim(g["edge_dst"], e0, args.cap_seg)
              - seg_id * chunk) if args.use_edge_dst and "edge_dst" in g \
            else None
        seg_par = ops.bottomup(rp_seg, ue, f_words, cvec, col_offset,
                               n_edges, ve)
        found = seg_par != INT_INF
        if pipelined:
            # exactness post-filter: the scan above used the stale
            # R-only bitmap, so rows discovered by earlier sub-steps
            # (the G chain, arriving here — after the scan) may have
            # been re-found; mask them out.  Per-row results are
            # independent of other rows' cvec, so the surviving finds
            # are bit-identical to the exact-bitmap scan.
            g_seen = unpack_bits(g_in)
            found = found & ~g_seen
            seg_par = jnp.where(found, seg_par, INT_INF)
        row_lens = (rp_seg[1:] - rp_seg[:-1]).astype(jnp.float32)
        if instr:
            # scanned-row accounting uses the EXACT completed view (R|G
            # when pipelined) so counters match the classic schedule
            unknown = (cvec == 0) if not pipelined else ~(cseg | g_seen)
            edges_use += lax.psum(
                jnp.sum(jnp.where(unknown, row_lens, 0.0)), axes)

        # Accumulate the update segment for its layout-A owner (the
        # s=0 self segment never enters the buffers: it pays no wire
        # and lands in the self slot after the exchange)
        if s == 0:
            self_par = seg_par
        else:
            if compact:
                # beyond-paper: ship only discovered (child, parent)
                # pairs (static capacity; level-end fallback to the
                # dense segments)
                cidx = jnp.where(found, size=cap_u,
                                 fill_value=chunk)[0].astype(jnp.int32)
                cval = seg_par[jnp.minimum(cidx, chunk - 1)]
                send_i = lax.dynamic_update_slice(send_i, cidx[None],
                                                  (seg_id, jnp.int32(0)))
                send_v = lax.dynamic_update_slice(send_v, cval[None],
                                                  (seg_id, jnp.int32(0)))
                if not pure:
                    max_found = jnp.maximum(
                        max_found, jnp.sum(found, dtype=jnp.int32))
                if instr:
                    ctr["wire_updates"] += jnp.float32(2 * cap_u) * p
            if (not compact) or (not pure):
                send_d = lax.dynamic_update_slice(send_d, seg_par[None],
                                                  (seg_id, jnp.int32(0)))
            if instr and not compact:
                ctr["wire_updates"] += jnp.float32(chunk) * p
        if instr:
            n_upd = lax.psum(jnp.sum(found, dtype=jnp.float32), axes)
            ctr["use_updates"] += 2.0 * n_upd

        # Mark discoveries in the carried bitmap; the rotation itself is
        # issued at the top of the next sub-step (hoisted)
        if pipelined:
            g_acc = pack_bits(g_seen | found)   # R rides carry unchanged
        else:
            cseg = cseg | found
            if s != pc - 1:
                carry = pack_bits(cseg)

    # --- Batched update exchange (one tiled all_to_all) -------------------
    def _a2a(x):
        return lax.all_to_all(x, args.col_axis, split_axis=0, concat_axis=0)

    def _scatter_compact(si, sv):
        # idx+val ride one exchange; sentinel idx == chunk drops
        r = _a2a(jnp.concatenate([si, sv], axis=1))       # (pc, 2*cap_u)
        rows = jnp.arange(pc, dtype=jnp.int32)[:, None]
        return jnp.full((pc, chunk), INT_INF, jnp.int32).at[
            rows, r[:, :cap_u]].min(r[:, cap_u:], mode="drop")

    if compact and pure:
        recv = _scatter_compact(send_i, send_v)
    elif compact:
        # global predicate: any sub-step's discoveries overflowing cap_u
        # re-ships the whole level dense (the branch collectives are
        # whole-mesh ops, so the predicate must be globally consistent)
        over = lax.pmax(max_found, axes) > cap_u
        recv = lax.cond(over,
                        lambda b: _a2a(b[0]),
                        lambda b: _scatter_compact(b[1], b[2]),
                        (send_d, send_i, send_v))
    else:
        recv = _a2a(send_d)
    # the self slot always carries sub-step 0's dense segment
    recv = lax.dynamic_update_slice(recv, self_par[None], (j, jnp.int32(0)))

    # --- Apply updates in sub-step order (source q ran sub-step (q-j)%pc
    # for this chunk, so s-order application matches the old sequential
    # per-sub-step semantics exactly) ---------------------------------------
    new_front = jnp.zeros_like(front)
    new_pi = pi
    for s in range(pc):
        upd = lax.dynamic_slice_in_dim(recv, (j + s) % pc, 1, axis=0)[0]
        newly = (upd != INT_INF) & (new_pi == -1)
        new_pi = jnp.where(newly, upd, new_pi)
        new_front = new_front | newly

    if instr:
        ctr["edges_useful"] = edges_use
        ctr["edges_examined"] = edges_use
    return new_pi, new_front, ctr
