"""Frontier representations + the paper's vector-redistribution steps.

Runs *inside* shard_map.  Bitmaps are uint32 words (the paper packs 64
vertices/word; we use 32-bit lanes — the unit conversion is handled in the
comm counters, which report paper-units: 1 vertex id = 1 word, 1 vertex
bitmap bit = 1/64 word).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

INT_INF = jnp.int32(2**31 - 1)


def pack_bits(mask: jax.Array) -> jax.Array:
    """(X,) bool -> (X//32,) uint32.  X must be a multiple of 32."""
    b = mask.reshape(-1, 32).astype(jnp.uint32)
    return jnp.sum(b << jnp.arange(32, dtype=jnp.uint32), axis=1,
                   dtype=jnp.uint32)


def unpack_bits(words: jax.Array) -> jax.Array:
    """(W,) uint32 -> (W*32,) bool."""
    bits = (words[:, None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    return bits.reshape(-1).astype(bool)


def test_bits(words: jax.Array, idx: jax.Array) -> jax.Array:
    """Membership test idx -> bool against a packed bitmap (gather)."""
    w = words[idx >> 5]
    return ((w >> (idx.astype(jnp.uint32) & jnp.uint32(31))) & 1).astype(bool)


def pack_ids(mask: jax.Array, cap: int, offset, sentinel) -> jax.Array:
    """Sparse frontier compaction: the global ids of the set bits of a
    local (chunk,) bool mask, as a fixed-capacity (cap,) i32 buffer.
    Unused slots (and every slot past ``cap``, if the mask has more than
    ``cap`` bits — callers must detect that overflow themselves) hold
    ``sentinel``; set bits beyond ``cap`` are silently dropped, which is
    why the 1ds exchange guards this with a dense-bitmap fallback."""
    chunk = mask.shape[0]
    off = jnp.where(mask, size=cap, fill_value=chunk)[0]
    return jnp.where(off < chunk, offset + off, sentinel).astype(jnp.int32)


def unpack_ids(ids: jax.Array, n: int) -> jax.Array:
    """Scatter sparse global ids back into a packed n-bit bitmap
    (uint32 words).  Out-of-range ids — the ``pack_ids`` sentinel — are
    dropped."""
    mask = jnp.zeros((n,), bool).at[ids].set(True, mode="drop")
    return pack_bits(mask)


def transpose_vector(x: jax.Array, perm: Sequence[Tuple[int, int]],
                     axes: Tuple[str, str]) -> jax.Array:
    """The paper's TransposeVector: one collective-permute over the 2D grid
    moving each device's whole chunk from layout A to layout B (or back,
    with the inverse perm)."""
    return lax.ppermute(x, axes, perm)


def expand_bitmap(front_chunk: jax.Array, perm, axes) -> Tuple[jax.Array, jax.Array]:
    """Expand (Alg.3 l.5-6 / Alg.4 l.6-7): transpose to layout B, then
    allgather packed words along the processor column (mesh axis axes[0])
    to reconstruct the C_j frontier slice.

    Returns (f_cj_words  uint32[nc//32], wire_words_per_device f32 in
    paper 64-bit-word units for the transpose+gather)."""
    row_axis = axes[0]
    words = pack_bits(front_chunk)
    words_b = transpose_vector(words, perm, axes)
    gathered = lax.all_gather(words_b, row_axis, tiled=True)
    pr = lax.psum(1, row_axis)  # static axis size (lax.axis_size is newer jax)
    wire = jnp.float32(words.size) * (1.0 / 2.0) * (1 + (pr - 1))
    # 1/2: uint32 word = half a 64-bit paper word. transpose sends 1 copy,
    # allgather sends (pr-1) copies of each word.
    return gathered, wire
