"""Version shims for jax APIs that moved between releases.

``shard_map`` is the load-bearing one: newer jax exposes
``jax.shard_map(..., check_vma=...)`` while the pinned 0.4.x series only
has ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.  Every
shard_map call in this repo goes through :func:`shard_map` below so the
whole distributed layer runs on either API.

The ``check_vma`` kwarg (varying-manual-axes checking) is the renamed
successor of ``check_rep`` (replication checking); both switch the same
static verifier off, which this codebase needs because pallas_call
outputs carry no replication/vma annotation.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6: top-level export with the check_vma spelling
    _shard_map_new = jax.shard_map
except AttributeError:
    _shard_map_new = None

try:  # jax 0.4.x fallback: experimental module with check_rep
    from jax.experimental.shard_map import shard_map as _shard_map_old
except ImportError:  # pragma: no cover - one of the two always exists
    _shard_map_old = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Portable shard_map: translate ``check_vma`` to whatever the
    installed jax understands (dropped entirely when left as None)."""
    if _shard_map_new is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)
    if _shard_map_old is None:  # pragma: no cover
        raise ImportError("no shard_map implementation in this jax")
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
