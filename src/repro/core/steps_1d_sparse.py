"""Per-level BFS steps for the sparse-exchange 1D decomposition ("1ds"):
the paper's Alg. 1/2 baseline with the frontier exchanged as
owner-directed sparse vertex ids instead of a dense n-bit bitmap.

The dense ``"1d"`` expand (core/steps_1d.py) allgathers one n-bit bitmap
per level — (p-1)*n/64 words regardless of frontier size, which is
exactly the O(n*p) scaling the paper's §4/§6 analysis charges against 1D
on small frontiers.  Buluc & Madduri's sparse formulation ships only the
live frontier: each processor owns the newly discovered chunk of the
frontier (1D discoveries are always locally owned), so the owner packs
its frontier ids into a fixed-capacity send bucket and one tiled
allgather delivers it to every peer — n_f*(p-1) words on the wire, a win
while n_f < n/64.  (With the adjacency partitioned by destination, every
strip may hold out-edges of any frontier vertex, so the per-destination
buckets of a true alltoall would all be identical — the allgather is
that exchange without materializing p copies.)

Static shapes force a capacity: the per-destination buckets hold
``cap_x`` ids (``PlanStatics.cap_x``, planned from the graph degree
stats by ``comm_model.plan_cap_x``).  When ANY processor's frontier
overflows its buckets the level falls back to the dense bitmap
allgather — a per-level hybrid mirroring the paper's direction-
optimizing switch, with the same globally-consistent-predicate
``lax.cond`` discipline as the 2D bitmap fold (collectives in both
branches lower as whole-mesh ops).  Bottom-up levels always take the
dense bitmap: the heuristics only enter bottom-up when the frontier is
large, where the bitmap is the cheaper encoding anyway.

``wire_expand`` records the LIVE ids each level shipped — the alltoallv
volume of the sparse formulation, ``comm_model.sparse_expand_1d_words``
— or the fallback bitmap words (``comm_model.expand_1d_level_words``),
giving the closed form ``comm_model.topdown_1d_words`` its first
measured counterpart.  The static-shape allgather physically moves the
full cap_x-slot buckets, sentinels included
(``comm_model.sparse_expand_padded_words``); ids are i32, so at the
planned crossover capacity the padded buckets cost the same bytes as
the n-bit bitmap — the padding is a wash, and the id counter is the
figure the variable-length exchange of the papers would put on the
wire.  Local discovery is unchanged: the sparse exchange reconstructs
the same packed frontier bitmap, so every "1d" LocalOps entry (dense
edge-parallel, strip-CSR, strip-DCSC Pallas) plugs in as-is.

Two pre-wire reductions from the literature sit on top:

  * **Sieve** (arXiv 1208.5542): the owner masks already-visited
    vertices out of its send set BEFORE packing, so a vertex never hits
    the wire twice.  In this loop the frontier is freshly discovered
    (``newly``), so the sieve removes nothing and parents stay
    bit-identical — but the exchange no longer ASSUMES its input is
    fresh: the overflow predicate, the packed count words, and the
    dense-fallback bitmap all see the sieved set, so any future caller
    with a stale or speculative frontier pays for live vertices only.
  * **Codec** (arXiv 1704.00513 flavor): with ``codec="packed"`` the
    bucket carries count-prefixed BIT-PACKED LOCAL OFFSETS instead of
    raw i32 global ids — ``codec_bits(chunk)`` bits per id (~3x fewer
    bucket bytes at chunk=1024), rebased by the receiver from the
    bucket's position in the tiled allgather
    (``kernels/frontier_codec``: Pallas encode/decode with a jnp
    oracle).  ``wire_expand`` switches to the compressed closed form
    ``comm_model.compressed_expand_1d_words`` on sparse levels;
    ``use_expand`` stays in raw-id units so codecs are comparable.
    The cheaper per-id wire also moves the sparse/dense crossover from
    n_f ~ n/64 to n_f ~ n/bits, so ``plan_cap_x(bits=...)`` plans
    LARGER buckets and more levels stay sparse.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import comm_model
from repro.core.frontier import (INT_INF, pack_bits, pack_ids, unpack_bits,
                                 unpack_ids)
from repro.core.steps import zero_counters
from repro.core.steps_1d import (bottomup_level_1d, _resolve_ops,
                                 pipelined_expand_consume)

CODECS = ("none", "packed")


class LevelArgs1DS(NamedTuple):
    """Static/per-search context for the sparse-exchange 1D steps.  The
    field set is a superset of LevelArgs1D (same names), so the dense
    bottom-up step and the "1d" LocalOps closures run against it
    unchanged; ``cap_x`` and ``codec`` are the only additions."""
    part: "object"            # Partition1D (static)
    axis: str                 # the single mesh axis name
    cap_x: int                # sparse exchange: ids per send bucket
    use_edge_dst: bool = False  # bottom-up: read per-edge rows (no search)
    local_mode: str = "dense"  # "dense" | "kernel" (Pallas)
    storage: str = "csr"      # "csr" | "dcsc" (strip pointer compression)
    cap_f: int = 0            # kernel csr: frontier capacity (0 = n)
    maxdeg: int = 0           # kernel mode: max column-segment length
    ops: "object" = None      # LocalOps entry (None = look up from strings)
    instrument: bool = True   # False: compile out counters/level_stats
    codec: str = "none"       # sparse-bucket encoding: "none" | "packed"
    # software-pipelined expand: C sub-range bucket exchanges per level,
    # each consumed while the next is in flight (1 = classic schedule);
    # must divide chunk/32 and cap_x (plan_bfs validates)
    expand_chunks: int = 1


def sparse_exchange_1d(front: jax.Array, axis: str, cap_x: int, part,
                       over=None, instrument: bool = True,
                       visited=None, codec: str = "none",
                       use_kernel: bool = False):
    """Owner-directed sparse frontier exchange with dense fallback.

    Each processor compacts its owned frontier chunk into a
    fixed-capacity bucket (``pack_ids``) and broadcasts it with one
    tiled all_gather; receivers scatter the ids back into the full
    n-vertex packed bitmap (``unpack_ids``).  With the adjacency
    partitioned by DESTINATION, every strip can hold edges out of any
    frontier vertex, so a per-destination alltoall would carry p
    identical buckets — the allgather IS that exchange without
    materializing the copies (a genuinely filtered alltoall needs a
    source-partitioned format; see ROADMAP).  If any processor holds
    more than ``cap_x`` SEND vertices the WHOLE level reverts to the
    dense bitmap (the predicate is pmax-synced, so every device takes
    the same branch and the collectives stay aligned — ids are never
    silently truncated).

    ``visited`` (optional bool[chunk]) is the owner-side sieve: vertices
    already discovered are dropped from the send set before packing,
    before the overflow count, and before the fallback bitmap — the
    whole exchange operates on ``front & ~visited``.  Receivers union
    the result into their view as usual, so sieving visited vertices
    never changes discovery.

    ``codec="packed"`` bit-packs the bucket (count word + local offsets
    at ``codec_bits(chunk)`` bits each; ``kernels/frontier_codec``,
    Pallas when ``use_kernel`` else the jnp oracle).  Same single
    allgather — the count rides inside the buffer — so the collective
    budget is unchanged; only the bytes shrink.

    ``over`` may be passed in pre-computed: the instrument=False fast
    path folds the per-processor bucket-overflow indicator into the
    PREVIOUS level's fused reduction (``decomp._search_loop``), so the
    level itself spends no collective on the predicate.  When ``over``
    is None it is derived here with a pmax (the instrumented path —
    still globally consistent, the cond branches contain collectives).

    Returns (f_words uint32[n//32], wire, overflowed bool).  ``wire`` is
    the modeled f32 words this level shipped — compressed or raw sparse
    form per ``codec``, bitmap words on the dense path — or **None**
    when ``instrument=False``: an uninstrumented exchange reports no
    number at all rather than a fake 0 that would poison ``wire_expand``
    aggregates mixing instrumented and fast levels."""
    if codec not in CODECS:
        raise ValueError(f"unknown frontier codec {codec!r}; "
                         f"expected one of {CODECS}")
    p = part.p
    i = lax.axis_index(axis)
    send = front if visited is None else front & ~visited
    if over is None:
        n_local = jnp.sum(send, dtype=jnp.int32)
        # global predicate: the cond branches contain collectives
        over = lax.pmax(n_local, axis) > cap_x

    if codec == "packed":
        from repro.kernels.frontier_codec import ops as codec_ops
        from repro.kernels.frontier_codec import ref as codec_ref
        enc = codec_ops.encode_offsets if use_kernel \
            else codec_ref.encode_offsets
        dec = (lambda r: codec_ops.decode_buckets(
                   r, part.chunk, cap_x, part.n, p)) if use_kernel \
            else (lambda r: codec_ref.decode_buckets(
                      r, part.chunk, cap_x, part.n))

        def sparse(f):
            off = pack_ids(f, cap_x, 0, part.chunk)      # local offsets
            buf = enc(off, jnp.sum(f, dtype=jnp.int32), part.chunk)
            recv = lax.all_gather(buf, axis, tiled=True)  # (p*(1+W),)
            return unpack_ids(dec(recv), part.n)
    else:
        def sparse(f):
            ids = pack_ids(f, cap_x, i * part.chunk, part.n)
            recv = lax.all_gather(ids, axis, tiled=True)  # (p*cap_x,)
            return unpack_ids(recv, part.n)

    def dense(f):
        return lax.all_gather(pack_bits(f), axis, tiled=True)

    f_words = lax.cond(over, dense, sparse, send)
    wire = None
    if instrument:
        n_f = lax.psum(jnp.sum(send, dtype=jnp.float32), axis)
        sparse_words = comm_model.compressed_expand_1d_words(
            n_f, p, comm_model.codec_bits(part.chunk)) \
            if codec == "packed" \
            else comm_model.sparse_expand_1d_words(n_f, p)
        wire = jnp.where(
            over,
            jnp.float32(comm_model.expand_1d_level_words(part.n, p)),
            jnp.float32(sparse_words))
    return f_words, wire, over


def _pipelined_topdown_1ds(g, send: jax.Array, over, args: "LevelArgs1DS"):
    """Software-pipelined sparse top-down expand+discover
    (``expand_chunks = C > 1``): the owner's chunk splits into C
    contiguous sub-ranges of ``sub = chunk/C`` vertices, each exchanged
    as its own capacity-``cap_x/C`` bucket allgather and consumed by a
    partial SpMSV while the next exchange is in flight
    (``pipelined_expand_consume``).  Candidates min-combine across
    sub-chunks — exact under the (select-source, min) semiring — so
    parents are bit-identical to the unchunked schedule.

    The overflow predicate becomes "ANY processor's send set exceeds
    cap_x/C in ANY sub-range" — still one globally-consistent scalar
    (the fast path folds it into the previous level's fused reduction
    exactly as before), and the whole level falls back to the CHUNKED
    dense expand, keeping both cond branches at C collectives.  A level
    that fits unchunked can overflow chunked (skewed sub-ranges), which
    changes only which levels pay bitmap words — never parents or the
    direction-mode sequence.

    Every sub-exchange decodes into the same owner-major ``(p * w_sub,)``
    sub-chunk word layout the chunked dense gather produces: raw ids
    rebase ``owner*sub + local``; the packed codec decodes with
    ``chunk=sub, n=p*sub`` so its bucket-position rebase lands there
    natively (offsets narrow to ``codec_bits(sub)`` bits, one count word
    per sub-bucket — see ``comm_model.compressed_expand_1d_words``'s
    n_chunks term).

    Returns (cand, ex_local, wire, over); ``wire`` is None
    uninstrumented."""
    part = args.part
    C = args.expand_chunks
    p = part.p
    sub = part.chunk // C
    cap_c = args.cap_x // C
    axis = args.axis
    i = lax.axis_index(axis)
    use_kernel = args.local_mode == "kernel"

    if over is None:
        counts = jnp.sum(send.reshape(C, sub), axis=1, dtype=jnp.int32)
        # global predicate: the cond branches contain collectives
        over = lax.pmax(jnp.max(counts), axis) > cap_c

    if args.codec == "packed":
        from repro.kernels.frontier_codec import ops as codec_ops
        from repro.kernels.frontier_codec import ref as codec_ref
        enc = codec_ops.encode_offsets if use_kernel \
            else codec_ref.encode_offsets
        dec = (lambda r: codec_ops.decode_buckets(r, sub, cap_c,
                                                  p * sub, p)) \
            if use_kernel \
            else (lambda r: codec_ref.decode_buckets(r, sub, cap_c,
                                                     p * sub))

        def sub_bucket(m_k, k):
            off = pack_ids(m_k, cap_c, 0, sub)       # sub-range offsets
            buf = enc(off, jnp.sum(m_k, dtype=jnp.int32), sub)
            recv = lax.all_gather(buf, axis, tiled=True)
            return unpack_ids(dec(recv), p * sub)
    else:
        def sub_bucket(m_k, k):
            ids = pack_ids(m_k, cap_c, i * part.chunk + k * sub, part.n)
            recv = lax.all_gather(ids, axis, tiled=True)  # (p*cap_c,)
            owner = recv // part.chunk
            pos = owner * sub + (recv - owner * part.chunk - k * sub)
            return unpack_ids(jnp.where(recv < part.n, pos, p * sub),
                              p * sub)

    def sparse(s):
        subs_mask = s.reshape(C, sub)
        return pipelined_expand_consume(
            g, lambda k: sub_bucket(subs_mask[k], k), C, args)

    def dense(s):
        subs = pack_bits(s).reshape(C, sub // 32)
        return pipelined_expand_consume(
            g, lambda k: lax.all_gather(subs[k], axis, tiled=True), C, args)

    cand, ex = lax.cond(over, dense, sparse, send)

    wire = None
    if args.instrument:
        n_f = lax.psum(jnp.sum(send, dtype=jnp.float32), axis)
        sparse_words = comm_model.compressed_expand_1d_words(
            n_f, p, comm_model.codec_bits(sub), C) \
            if args.codec == "packed" \
            else comm_model.sparse_expand_1d_words(n_f, p)
        wire = jnp.where(
            over,
            jnp.float32(comm_model.chunked_expand_1d_level_words(
                part.n, p, C)),
            jnp.float32(sparse_words))
    return cand, ex, wire, over


def topdown_level_1ds(g: Dict[str, jax.Array], pi: jax.Array,
                      front: jax.Array, args: LevelArgs1DS, lv=None
                      ) -> Tuple[jax.Array, jax.Array, Dict]:
    """One sparse-exchange 1D top-down level: identical to the dense 1D
    level except the expand ships frontier ids (with bitmap fallback).
    ``lv`` (fast path only) carries the bucket-overflow predicate from
    the previous level's fused reduction, so the instrument=False level
    spends its collectives on the exchange alone.

    The sieve mask is ``(pi != -1) & ~front``: everything discovered on
    EARLIER levels.  The frontier itself is excluded — its vertices also
    have parents by now — so in-loop the sieve is the identity on
    ``front`` and parents are bit-identical with it on or off; the
    fast path's overflow count over ``front`` (decomp.reduce_state)
    matches the sieved count for the same reason."""
    part = args.part
    instr = args.instrument
    ctr = zero_counters() if instr else {}
    over = lv["over"] if lv is not None else None
    visited = (pi != -1) & ~front

    if args.expand_chunks > 1:
        # Software pipeline: C sub-range bucket exchanges, each consumed
        # by a partial SpMSV while the next is in flight.
        send = front & ~visited
        cand, ex_local, wire, _ = _pipelined_topdown_1ds(g, send, over,
                                                         args)
    else:
        # --- Expand: owner-directed sparse ids, dense bitmap on
        # overflow --
        f_words, wire, _ = sparse_exchange_1d(
            front, args.axis, args.cap_x, part, over=over,
            instrument=instr, visited=visited, codec=args.codec,
            use_kernel=(args.local_mode == "kernel"))
        f_all = unpack_bits(f_words)                 # (n,) bool
        # --- Local discovery: unchanged from "1d" (same LocalOps
        # entries) --
        cand, ex_local = _resolve_ops(args).topdown(g, f_words, f_all,
                                                    part.chunk,
                                                    jnp.int32(0), args)
    if instr:
        ctr["wire_expand"] = wire
        n_f = lax.psum(jnp.sum(front, dtype=jnp.float32), args.axis)
        ctr["use_expand"] = jnp.float32(
            comm_model.sparse_expand_1d_words(n_f, part.p))
        ctr["edges_examined"] = lax.psum(ex_local, args.axis)
        ctr["edges_useful"] = lax.psum(
            jnp.sum(jnp.where(front, g["deg_A"], 0), dtype=jnp.float32),
            args.axis)

    # --- Local update (children are owned; no fold) ----------------------
    newly = (pi == -1) & (cand != INT_INF)
    pi = jnp.where(newly, cand, pi)
    return pi, newly, ctr


def bottomup_level_1ds(g: Dict[str, jax.Array], pi: jax.Array,
                       front: jax.Array, args: LevelArgs1DS, lv=None
                       ) -> Tuple[jax.Array, jax.Array, Dict]:
    """Bottom-up levels always exchange the dense bitmap: the direction
    heuristic only enters bottom-up on large frontiers, where
    n_f*(p-1) id words would exceed the (p-1)*n/64 bitmap — reusing the
    "1d" step verbatim (the LevelArgs field names line up)."""
    return bottomup_level_1d(g, pi, front, args, lv)


__all__ = ["CODECS", "LevelArgs1DS", "sparse_exchange_1d",
           "topdown_level_1ds", "bottomup_level_1ds"]
