"""TEPS accounting, exactly as the paper (§7.2): input edges / runtime,
harmonic mean over 16-64 random roots."""
from __future__ import annotations

from typing import Sequence

import numpy as np


def teps(m_input_edges: int, seconds: float) -> float:
    return m_input_edges / max(seconds, 1e-12)


def harmonic_mean(xs: Sequence[float]) -> float:
    xs = np.asarray([x for x in xs if x > 0], dtype=np.float64)
    if xs.size == 0:
        return 0.0
    return float(xs.size / np.sum(1.0 / xs))
