"""BFSEngine: the plan → compile → run traversal session API.

The Graph500 methodology (paper §7) is "build the distributed graph
once, then run BFS from 16–64 roots" — so the engine splits the old
one-shot ``run_bfs`` into three stages:

  plan    ``plan_bfs(graph, cfg, mesh) -> BFSPlan``
          resolves the Decomposition entry (core/decomp.py) and the
          LocalOps entry (core/local_ops.py), pulls the static scalars
          (cap_seg / maxdeg_col / n_real_edges) from the graph, and
          validates arrays/partition/mesh/config coherence up front —
          every shape error surfaces here, before any device work.

  compile ``BFSPlan.compile() -> BFSEngine``
          ships the graph device arrays ONCE (one device_put per
          shipped key) and AOT-compiles the whole-search program ONCE
          (one jit trace); ``engine.ship_s`` / ``engine.compile_s``
          report the two costs separately.

  run     ``BFSEngine.run(root)`` / ``run_many(roots)`` reuse the
          shipped arrays and compiled executable across roots — per-root
          time is pure traversal, never smeared by recompiles.
          ``run_batch(roots, pod_axis=...)`` compiles the pod-parallel
          multi-source program (roots sharded over the pod axis, graph
          replicated, searches in lockstep) — available in EVERY
          registered decomposition, not just 2D.

``plan_for_part`` is the graph-less variant for abstract/dry-run
callers (launch/cells.py) that lower against ShapeDtypeStructs; it
skips the graph-array checks but performs all partition/mesh/config
validation.  The legacy ``make_*_bfs_fn`` builders and ``run_bfs``
(core/bfs.py) are thin wrappers over these two entry points.
"""
from __future__ import annotations

import functools
import re
import time
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import BFSConfig
from repro.core import comm_model
from repro.core.compat import shard_map
from repro.core.decomp import (Decomposition, PlanStatics,
                               get_decomposition)
from repro.core.local_ops import LocalOps, get_local_ops


@dataclass
class BFSResult:
    parents: np.ndarray          # (n_orig,)
    n_levels: int
    counters: Dict[str, float]   # whole-search totals (paper 64-bit words)
    level_stats: np.ndarray      # (MAX_LEVELS, 5): n_f, m_f, mode, used,
    #                              measured expand words that level
    validation: Optional[Any] = None  # ValidationReport when run(...,
    #                              validate=True); None otherwise


@dataclass
class BFSBatchResult:
    """Pod-batched multi-source searches (counters are not accumulated
    per root in the batched program; use ``run``/``run_many`` for the
    Eq. 2 accounting).  ``level_stats`` carries each root's OWN per-level
    frontier sizes and direction decisions — batched searches share a
    lockstep trip count, not frontier sizes.  Direction switching is per
    slice for entries with group-local collectives (1d/1ds); the 2d
    entry syncs the decision across pods (see decomp._search_loop)."""
    roots: np.ndarray            # (n_roots,)
    parents: np.ndarray          # (n_roots, n_orig)
    n_levels: np.ndarray         # (n_roots,)
    level_stats: np.ndarray      # (n_roots, MAX_LEVELS, 5), per BFSResult


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BFSPlan:
    """A frozen, validated description of one traversal session: which
    decomposition + local format run on which mesh axes with which
    static capacities.  Build programs with ``build_fn`` /
    ``build_batch_fn`` (abstract callers), or ``compile()`` into a
    BFSEngine when a concrete graph is attached."""
    part: Any                     # Partition1D | Partition2D
    cfg: BFSConfig
    mesh: Any
    entry: Decomposition
    ops: LocalOps
    axes: Tuple[str, ...]         # mesh axes the graph blocks shard over
    statics: PlanStatics
    graph: Any = None             # Blocked*Graph; None for abstract plans

    @property
    def keys(self) -> Tuple[str, ...]:
        """Graph device arrays this plan ships (from the LocalOps entry)."""
        return self.ops.keys

    def level_args(self):
        return self.entry.make_level_args(self.part, self.cfg, self.ops,
                                          self.axes, self.statics)

    # ---- program builders -------------------------------------------------

    def build_fn(self, sync_axis: Optional[str] = None, trace_hook=None):
        """The jitted single-root whole-search program:
        fn(graph_arrays_dict, root) -> (pi, level, ctr, stats).
        ``trace_hook`` (if given) is called once per jit trace — the
        engine uses it to assert compile-once behavior."""
        body = functools.partial(self.entry.body, part=self.part,
                                 args=self.level_args(), cfg=self.cfg,
                                 sync_axis=sync_axis)
        if trace_hook is not None:
            inner = body

            def body(g, root):
                trace_hook()
                return inner(g, root)

        gspec = {k: self.entry.graph_spec(self.axes) for k in self.keys}
        mapped = shard_map(
            body, mesh=self.mesh,
            in_specs=(gspec, P()),
            out_specs=self.entry.out_specs(self.axes,
                                           self.cfg.instrument),
            check_vma=False)   # pallas_call outputs carry no vma annotation
        return jax.jit(mapped)

    def build_batch_fn(self, pod_axis: str, trace_hook=None):
        """The jitted pod-batched multi-source program: independent
        whole searches scanned over each pod's local roots (the
        roots-per-pod count is fixed by the shape of the roots array the
        program is compiled against), pods embarrassingly parallel
        (graph replicated across pods, zero inter-pod traffic, level
        loops in lockstep via sync_axis).
        fn(graph_arrays_dict, roots) -> (pis, levels)."""
        if pod_axis not in self.mesh.shape:
            raise ValueError(f"mesh has no {pod_axis!r} axis for batched "
                             f"roots; axes are {tuple(self.mesh.shape)}")
        body1 = functools.partial(self.entry.body, part=self.part,
                                  args=self.level_args(), cfg=self.cfg,
                                  sync_axis=pod_axis)
        n_axes = self.entry.n_axes

        def multi_body(g, roots):
            if trace_hook is not None:
                trace_hook()

            # roots: (n_roots_local,) — scan full searches over local roots
            def one(carry, root):
                pi, level, ctr, stats = body1(g, root)
                return carry, (pi.reshape(pi.shape[-1]), level, stats)

            _, (pis, levels, stats) = lax.scan(one, jnp.int32(0),
                                               roots.reshape(-1))
            return pis.reshape((1,) * n_axes + pis.shape), levels, stats

        gspec = {k: self.entry.graph_spec(self.axes) for k in self.keys}
        mapped = shard_map(
            multi_body, mesh=self.mesh,
            in_specs=(gspec, P(pod_axis)),
            out_specs=self.entry.batch_out_specs(self.axes, pod_axis),
            check_vma=False)
        return jax.jit(mapped)

    # ---- static analysis --------------------------------------------------

    def lint(self, pod_axis: Optional[str] = None) -> List[Any]:
        """Run the SPMD collective-schedule linter (repro.analysis,
        rules R1–R3) on this plan's traced program and return the
        findings (empty = clean).  When ``pod_axis`` names an axis of
        the plan's mesh the pod-batched program is linted — that is
        where divergence hazards live (per-pod direction decisions
        around whole-mesh collectives); otherwise the single-root
        program.  Traces only; nothing is lowered, compiled, or run.
        Registry-wide sweeps (including the R4 budget check) live in
        ``python -m repro.analysis.lint``."""
        from repro.analysis.registry import lint_plan
        if pod_axis is None and "pod" in self.mesh.shape:
            pod_axis = "pod"
        return lint_plan(self, pod_axis=pod_axis)

    # ---- session ----------------------------------------------------------

    def compile(self, store=None, exec_key: str = "default") -> "BFSEngine":
        """Ship the graph and compile the search program (both once);
        the returned engine runs any number of roots against them.

        ``store`` (a ckpt.graph_store.GraphStore) short-circuits the XLA
        compile: a serialized executable saved under ``exec_key`` whose
        config hash + mesh shape match this plan is deserialized instead
        (``engine.exec_load_s`` / ``exec_from_store`` report it), and a
        fresh compile is persisted back so the next process loads."""
        return BFSEngine(self, store=store, exec_key=exec_key)


def plan_for_part(part, cfg: BFSConfig, mesh, *,
                  row_axis: str = "data", col_axis: str = "model",
                  local_mode: str = "dense", cap_seg: int = 0,
                  maxdeg: int = 0, cap_f: int = 0, cap_x: int = 0,
                  n_real_edges: float = 0.0) -> BFSPlan:
    """A graph-less plan from an explicit partition + static capacities
    (abstract lowering, compat builders).  Performs every validation
    that does not need concrete arrays."""
    entry = get_decomposition(cfg.decomposition)
    if not isinstance(part, entry.partition_cls):
        raise TypeError(
            f"decomposition={cfg.decomposition!r} needs a "
            f"{entry.partition_cls.__name__}, got {type(part).__name__}")
    axes = (row_axis, col_axis)[: entry.n_axes]
    for ax, want in zip(axes, entry.axis_sizes(part)):
        if ax not in mesh.shape:
            raise ValueError(
                f"mesh has no {ax!r} axis needed by decomposition="
                f"{cfg.decomposition!r}; axes are {tuple(mesh.shape)}")
        if mesh.shape[ax] != want:
            raise ValueError(
                f"mesh axis {ax!r} has size {mesh.shape[ax]} but the "
                f"partition needs {want} (grid "
                f"{tuple(entry.axis_sizes(part))})")
    from repro.core.steps_1d_sparse import CODECS
    if cfg.frontier_codec not in CODECS:
        raise ValueError(
            f"cfg.frontier_codec={cfg.frontier_codec!r} is not a "
            f"registered frontier codec; have {CODECS}")
    if cfg.expand_chunks < 1:
        raise ValueError(
            f"cfg.expand_chunks={cfg.expand_chunks} must be >= 1 "
            f"(1 = unpipelined expand)")
    ops = get_local_ops(cfg.decomposition, local_mode, cfg.storage)
    statics = PlanStatics(cap_seg=cap_seg, maxdeg=maxdeg, cap_f=cap_f,
                          cap_x=cap_x, n_real_edges=n_real_edges,
                          instrument=cfg.instrument,
                          expand_chunks=cfg.expand_chunks)
    entry.validate(part, statics)
    return BFSPlan(part=part, cfg=cfg, mesh=mesh, entry=entry, ops=ops,
                   axes=axes, statics=statics)


def plan_bfs(graph, cfg: BFSConfig, mesh, *,
             row_axis: str = "data", col_axis: str = "model",
             local_mode: str = "dense", cap_f: int = 0,
             cap_x: int = 0) -> BFSPlan:
    """Plan a traversal session over a concrete blocked graph.

    Resolves the decomposition + LocalOps entries, pulls the static
    scalars (cap_seg, maxdeg_col, n_real_edges) from the graph, and
    validates graph/partition/mesh/config coherence — including that
    the graph actually carries every array the chosen local format
    ships.  ``cap_x`` (the "1ds" sparse-exchange bucket capacity) is
    planned from the graph degree stats when not given —
    ``comm_model.plan_cap_x`` caps the buckets at the dense/sparse
    crossover so overflowing levels fall back to the bitmap."""
    entry = get_decomposition(cfg.decomposition)
    if not isinstance(graph, entry.graph_cls):
        raise TypeError(
            f"cfg.decomposition={cfg.decomposition!r} does not match "
            f"graph type {type(graph).__name__}")
    part = graph.part
    if cap_x <= 0:
        # bits-aware: the packed codec cheapens each shipped id, moving
        # the sparse/dense crossover out and admitting larger buckets
        bits = comm_model.codec_bits(part.chunk) \
            if cfg.frontier_codec == "packed" else 64
        cap_x = comm_model.plan_cap_x(part.n, part.p, int(graph.m),
                                      bits=bits)
    plan = plan_for_part(
        graph.part, cfg, mesh, row_axis=row_axis, col_axis=col_axis,
        local_mode=local_mode, cap_f=cap_f, cap_x=cap_x,
        cap_seg=getattr(graph, "cap_seg", 0), maxdeg=graph.maxdeg_col,
        n_real_edges=float(graph.m))
    arrays = graph.device_arrays()
    missing = [k for k in plan.keys if k not in arrays]
    if missing:
        raise ValueError(
            f"graph lacks arrays {missing} needed by local_mode="
            f"{local_mode!r}/storage={cfg.storage!r} (1d csr kernels need "
            f"build_blocked_1d(..., with_col_ptr=True))")
    return replace(plan, graph=graph)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

# one collective instruction, in compiled HLO (`%x = <shape> op(...)`,
# async collectives as op-start/op-done pairs — count the starts) or in
# lowered StableHLO (`stablehlo.op"?(`).  The HLO arm must not cross a
# quote while scanning from `=` to the op name: instruction lines carry
# metadata={op_name="..."} strings that can embed collective names
# followed by `(`, and matching inside them double-counts the op the
# string merely describes (tests/test_hlo_counts.py pins this).
_COLLECTIVE_OP_RE = re.compile(
    r"(?:=\s*[^=\n\"]*?\b(all-reduce|all-gather|all-to-all|reduce-scatter|"
    r"collective-permute)(?:-start)?\()"
    r"|(?:stablehlo\.(all_reduce|all_gather|all_to_all|reduce_scatter|"
    r"collective_permute)\b)")


def hlo_collective_counts(hlo: str) -> Dict[str, int]:
    """Collective-op instruction counts per kind (hyphenated HLO names)
    in an HLO or StableHLO text dump, plus a ``total``.  Used by the
    perf-guard test and the bench trajectory to pin the collective
    schedule of a program (counts are static program size, NOT dynamic
    executions — while-loop bodies appear once, and both branches of a
    conditional count even though one executes)."""
    counts: Dict[str, int] = {}
    for m in _COLLECTIVE_OP_RE.finditer(hlo):
        kind = (m.group(1) or m.group(2)).replace("_", "-")
        counts[kind] = counts.get(kind, 0) + 1
    counts["total"] = sum(counts.values())
    return counts


class BFSEngine:
    """A compiled traversal session: graph shipped once, program
    compiled once, traversed from many roots.

    Attributes:
      ship_s          seconds to device_put the graph arrays (once)
      compile_s       seconds to trace + XLA-compile the single-root
                      search (once, eagerly at compile())
      batch_compile_s cumulative seconds compiling pod-batched programs
                      (one per distinct roots-per-pod shape, lazily at
                      first run_batch)
      trace_count     jit traces taken so far (1 after compile;
                      run/run_many never add more — asserted by tests)
    """

    def __init__(self, plan: BFSPlan, store=None, exec_key: str = "default"):
        if plan.graph is None:
            raise ValueError("plan has no graph attached; build it with "
                             "plan_bfs(graph, cfg, mesh)")
        self.plan = plan
        self.trace_count = 0
        sh = NamedSharding(plan.mesh, P(*plan.axes))
        arrays = plan.graph.device_arrays()
        t0 = time.perf_counter()
        # born-sharded jax.Arrays (device builds, store loads) pass
        # through without a host round-trip — device_put on a correctly
        # sharded array is a no-op, on a mis-sharded one a reshard
        self._gdev = {k: jax.device_put(
            arrays[k] if isinstance(arrays[k], jax.Array)
            else np.asarray(arrays[k]), sh) for k in plan.keys}
        for v in self._gdev.values():
            v.block_until_ready()
        t1 = time.perf_counter()
        self.ship_s = t1 - t0
        self.exec_load_s = 0.0
        self.exec_from_store = False
        if store is not None:
            self._exec = store.load_executable(plan, exec_key)
            if self._exec is not None:
                self.exec_from_store = True
                self.exec_load_s = time.perf_counter() - t1
                self.compile_s = 0.0
                self.batch_compile_s = 0.0
                self._batch_cache: Dict[Tuple[str, int], Any] = {}
                return
        fn = plan.build_fn(trace_hook=self._count_trace)
        # AOT lower+compile: the trace happens here exactly once, and
        # run() calls the compiled executable directly — per-root time
        # can never include compilation.
        self._exec = fn.lower(self._gdev, jnp.int32(0)).compile()
        self.compile_s = time.perf_counter() - t1
        self.batch_compile_s = 0.0
        self._batch_cache: Dict[Tuple[str, int], Any] = {}
        if store is not None:
            store.save_executable(self, exec_key)

    def _count_trace(self):
        self.trace_count += 1

    @property
    def instrument(self) -> bool:
        """Whether the compiled search program carries the counter /
        level_stats bookkeeping (plan-level; see BFSConfig.instrument).
        False = the latency-lean fast path: one fused scalar reduction
        per level, zero counters in the results."""
        return self.plan.statics.instrument

    def collective_counts(self) -> Dict[str, int]:
        """Collective-op counts of the compiled single-root search (the
        static schedule the fast path exists to shrink)."""
        return hlo_collective_counts(self._exec.as_text())

    def _check_root(self, root) -> int:
        """Graphs are padded up to p*chunk vertices; a root in the padded
        ghost range has no edges, so the device program would silently
        return an all-empty parents array.  Validate at the engine
        boundary instead."""
        part = self.plan.part
        root = int(root)
        if not 0 <= root < part.n_orig:
            raise ValueError(
                f"root {root} out of range [0, {part.n_orig}): the graph "
                f"has {part.n_orig} vertices (padded to {part.n} — "
                f"traversing from a padded ghost vertex would return an "
                f"empty tree)")
        return root

    # ---- single-root ------------------------------------------------------

    def search(self, root: int):
        """Device-level search: (pi, level, ctr, stats) as device arrays,
        no host transfer.  Benchmark loops time this (+ a block on pi)
        so per-root numbers measure traversal, not result conversion."""
        return self._exec(self._gdev, jnp.int32(self._check_root(root)))

    def to_result(self, out) -> BFSResult:
        """Convert a ``search`` output to the layout-independent
        BFSResult (parents indexed by global vertex id, counters in the
        shared COUNTER_KEYS units) so 1D and 2D runs diff directly."""
        part = self.plan.part
        pi, level, ctr, stats = out
        pi = np.asarray(pi).reshape(part.n)[: part.n_orig]
        return BFSResult(
            parents=pi.astype(np.int64),
            n_levels=int(level),
            counters={k: float(v) for k, v in ctr.items()},
            level_stats=np.asarray(stats),
        )

    def run(self, root: int, validate: bool = False) -> BFSResult:
        """One whole search against the shipped graph, results on host.

        ``validate=True`` runs the sharded Graph500 parent-tree
        validator (core/validate.py) on the DEVICE parent array before
        it ever crosses to host: the report is attached as
        ``result.validation`` and a failing tree raises
        ``ValidationError`` (the result is recoverable from the
        exception's report plus ``validate_parents`` for forensics).
        The validator program is built and compiled lazily on the first
        validated run and reused after that.
        """
        out = self.search(root)
        res = self.to_result(out)
        if validate:
            from repro.core import validate as _validate
            rep = _validate.validate_device(self, self._check_root(root),
                                            out[0])
            res.validation = rep
            if not rep.ok:
                raise _validate.ValidationError(rep)
        return res

    def run_many(self, roots: Sequence[int], validate: bool = False,
                 monitor=None) -> List[BFSResult]:
        """The Graph500 loop: sequential searches from many roots, all
        against the one shipped graph + compiled program.

        ``monitor`` accepts a ``runtime.straggler.StragglerMonitor``:
        each root's wall time (search + host conversion + optional
        validation) is fed through ``monitor.observe(step, dt)`` so
        anomalously slow roots are recorded as events — reported by the
        caller's timing summary, never raised here.
        """
        results = []
        for step, r in enumerate(roots):
            t0 = time.perf_counter()
            results.append(self.run(int(r), validate=validate))
            if monitor is not None:
                monitor.observe(step, time.perf_counter() - t0)
        return results

    # ---- pod-batched multi-source -----------------------------------------

    def run_batch(self, roots: Sequence[int],
                  pod_axis: str = "pod") -> BFSBatchResult:
        """Multi-source BFS with roots sharded over ``pod_axis``: each
        pod scans its len(roots)/pods searches while the level loops
        stay in lockstep.  Works in every registered decomposition (the
        batched program is built from the same Decomposition entry as
        the single-root one).  The batched executable is compiled once
        per (pod_axis, roots-per-pod) shape and cached."""
        mesh = self.plan.mesh
        if pod_axis not in mesh.shape:
            raise ValueError(f"mesh has no {pod_axis!r} axis for batched "
                             f"roots; axes are {tuple(mesh.shape)}")
        pods = mesh.shape[pod_axis]
        roots = np.asarray(roots, dtype=np.int32).reshape(-1)
        if roots.size == 0 or roots.size % pods:
            raise ValueError(f"{roots.size} roots do not split evenly over "
                             f"{pods} pods")
        for r in roots:
            self._check_root(r)
        rdev = jax.device_put(roots, NamedSharding(mesh, P(pod_axis)))
        key = (pod_axis, roots.size // pods)
        if key not in self._batch_cache:
            fn = self.plan.build_batch_fn(pod_axis,
                                          trace_hook=self._count_trace)
            t0 = time.perf_counter()
            self._batch_cache[key] = fn.lower(self._gdev, rdev).compile()
            self.batch_compile_s += time.perf_counter() - t0
        pis, levels, stats = self._batch_cache[key](self._gdev, rdev)
        part, n_axes = self.plan.part, self.plan.entry.n_axes
        # (*block_dims, n_roots, chunk) -> (n_roots, n) in layout A
        pis = np.moveaxis(np.asarray(pis), n_axes, 0)
        pis = pis.reshape(roots.size, part.n)[:, : part.n_orig]
        return BFSBatchResult(
            roots=roots.astype(np.int64),
            parents=pis.astype(np.int64),
            n_levels=np.asarray(levels).astype(np.int64),
            level_stats=np.asarray(stats),
        )


# ---------------------------------------------------------------------------
# Self-healing session: bounded cap_x replan-retry
# ---------------------------------------------------------------------------


@dataclass
class HealedRun:
    """Result of ``run_bfs_healed``: the final (healthy) session plus
    the structured escalation log — one entry per plan attempt, empty
    detail when the first plan was already overflow-free."""
    result: BFSResult
    engine: BFSEngine
    plan: BFSPlan
    retry_log: List[Dict[str, Any]]


def _overflow_levels_1ds(plan: BFSPlan, stats) -> List[int]:
    """Levels whose sparse exchange fell back to the dense bitmap.

    The 1ds exchange NEVER raises on bucket overflow — it reverts the
    level to the dense bitmap (parents stay exact, wire cost jumps to
    the (p-1)*n/64 dense words).  The instrumented run records the
    measured wire per level (stats col 4), so a fallback is detectable
    host-side: a used top-down level whose wire matches the dense
    formula instead of the sparse/compressed words its frontier size
    (stats col 0) predicts.  The double check (== dense AND != sparse)
    keeps frontier sizes sitting exactly at the crossover — where both
    formulas agree and there is nothing to heal — out of the list."""
    part, cfg = plan.part, plan.cfg
    C = plan.statics.expand_chunks
    p = part.p
    stats = np.asarray(stats, dtype=np.float64)
    n_f = stats[:, 0]
    if cfg.frontier_codec == "packed":
        sub = part.chunk // C
        bits = comm_model.codec_bits(sub)
        exp = np.array([comm_model.compressed_expand_1d_words(
            f, p, bits, C) for f in n_f])
    else:
        exp = np.array([comm_model.sparse_expand_1d_words(f, p)
                        for f in n_f])
    dense = comm_model.chunked_expand_1d_level_words(part.n, p, C) \
        if C > 1 else comm_model.expand_1d_level_words(part.n, p)
    exp32 = np.float32(exp).astype(np.float64)
    dense32 = float(np.float32(dense))
    wire = stats[:, 4]
    over = ((stats[:, 3] > 0) & (stats[:, 2] == 0)
            & np.isclose(wire, dense32, rtol=1e-4)
            & ~np.isclose(wire, exp32, rtol=1e-4))
    return [int(i) for i in np.nonzero(over)[0]]


def run_bfs_healed(graph, cfg: BFSConfig, mesh, root: int, *,
                   max_attempts: int = 3, store=None,
                   exec_key: str = "healed", validate: bool = False,
                   **plan_kw) -> HealedRun:
    """Plan + compile + run with bounded ``cap_x`` replan-retry.

    For the "1ds" decomposition an undersized sparse-exchange bucket
    capacity does not corrupt anything — overflowing levels silently
    revert to the dense bitmap — but it forfeits exactly the wire
    savings the sparse exchange exists for.  This driver detects the
    fallback from an instrumented probe run, escalates ``cap_x``
    geometrically (x2 per attempt, clamped to the chunk size where
    overflow is impossible), replans + recompiles, and retries, at most
    ``max_attempts`` plan attempts.  Parents are bit-identical across
    every attempt (fallback levels are exact); the escalation history
    lands in ``HealedRun.retry_log``.  Exhausting the attempts raises
    ``CapacityOverflow`` carrying the full history.

    Non-1ds decompositions have no cap_x knob: single attempt, empty
    retry log.
    """
    from repro.runtime.retry import CapacityOverflow, RetryAttempt

    if cfg.decomposition != "1ds":
        plan = plan_bfs(graph, cfg, mesh, **plan_kw)
        engine = plan.compile(store=store, exec_key=exec_key)
        return HealedRun(result=engine.run(root, validate=validate),
                         engine=engine, plan=plan, retry_log=[])

    probe_cfg = cfg if cfg.instrument else replace(cfg, instrument=True)
    history: List[RetryAttempt] = []
    cap_x = int(plan_kw.pop("cap_x", 0))
    part = graph.part
    for attempt in range(1, max_attempts + 1):
        plan = plan_bfs(graph, probe_cfg, mesh, cap_x=cap_x, **plan_kw)
        cap_now = plan.statics.cap_x
        engine = plan.compile(store=store,
                              exec_key=f"{exec_key}-x{cap_now}")
        res = engine.run(root, validate=validate)
        levels = _overflow_levels_1ds(plan, res.level_stats)
        if not levels:
            history.append(RetryAttempt(
                attempt=attempt, cap_name="cap_x", cap_value=cap_now,
                outcome="ok", detail={}))
            if probe_cfg is not cfg:
                # caller wanted the fast program: rebuild it at the
                # healthy cap (parents bit-identical by construction)
                plan = plan_bfs(graph, cfg, mesh, cap_x=cap_now,
                                **plan_kw)
                engine = plan.compile(store=store,
                                      exec_key=f"{exec_key}-x{cap_now}")
                res = engine.run(root, validate=validate)
            log = [a.to_json() for a in history]
            # drop the no-op log when the FIRST plan was already clean
            if len(log) == 1 and log[0]["outcome"] == "ok":
                log = []
            return HealedRun(result=res, engine=engine, plan=plan,
                             retry_log=log)
        history.append(RetryAttempt(
            attempt=attempt, cap_name="cap_x", cap_value=cap_now,
            outcome="overflow", detail={"levels": levels}))
        nxt = min(cap_now * 2, part.chunk)
        if nxt <= cap_now:
            break
        cap_x = nxt
    raise CapacityOverflow(
        f"cap_x escalation exhausted after {len(history)} attempts "
        f"(levels still falling back to the dense bitmap)",
        cap_name="cap_x", cap_value=cap_now, history=history)
