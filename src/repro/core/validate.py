"""Sharded Graph500 parent-tree validation.

The Graph500 spec requires every timed BFS to be *validated*: the
returned parent array must (1) self-parent the root, (2) use only real
graph edges as tree edges, (3) place each child exactly one level below
its parent, and (4) mark a vertex reachable iff it is in the tree.
Direction-optimizing traversals (arXiv 1208.5542) make this an
end-to-end safety net, not a formality — a bottom-up level that
mis-anchors parents produces a plausible-looking tree only a validator
catches.

This module runs those checks *where the graph lives*: one shard_map
program per plan, reusing the engine's resident device shards (only the
``Decomposition.edge_keys`` fields), with a single (6,) int32 verdict
vector crossing back to host.  No edge list, parent array, or depth
array is ever materialized host-side.

Per-device work (same for all registered decompositions):

- replicate the candidate parent array to the full ``(n,)`` layout-A
  global order (``all_gather(tiled)`` per mesh axis — 1 gather for the
  strip entries, 2 for 2d);
- resolve every vertex's tree depth by pointer doubling over the parent
  array (7 rounds: 2^7 > MAX_LEVELS + 1), saturating at
  ``CAP = MAX_LEVELS + 1`` so cycles, chains through out-of-tree
  vertices, and out-of-range parents all read as "unanchored";
- check tree-edge existence against the LOCAL edge shard via the
  entry's ``local_edges`` hook: a scatter-max marks every vertex whose
  (parent -> vertex) edge is stored here, then one psum ORs the marks
  across the mesh (an edge exists iff SOME shard stores it);
- count violation sites per check over owned vertices / local edge
  slots, and psum the six counters.

Violation counters (``CHECKS`` order):

- ``root_self_parent``: root's stored parent != root.
- ``tree_edge_missing``: an in-tree non-root vertex whose claimed
  parent edge exists in no shard (covers phantom/bit-flipped parents).
- ``parent_chain_broken``: an in-tree vertex whose parent chain never
  reaches the root (cycle, chain through a -1 vertex, parent >= n).
- ``level_span``: a graph edge whose endpoints' tree depths differ by
  more than one — in a genuine BFS tree, depth equals BFS distance and
  every edge spans <= 1 level, so any skew here means some parent is
  not one level above its child.
- ``reach_mismatch``: a graph edge with exactly one endpoint in the
  tree — reachability must saturate, so a reachable out-of-tree vertex
  (or an in-tree vertex with an out-of-tree neighbor) trips this.

Edge-level counts are violation *sites* (each stored orientation of an
undirected edge counts once per shard that stores it); the report is
pass/fail plus per-check tallies, not a deduplicated edge list.

Padded ghost vertices (ids in [n_orig, n)) have no edges and parent
-1 in any legal run, so they can never contribute a violation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.core.decomp import MAX_LEVELS

CHECKS = ("root_self_parent", "tree_edge_missing", "parent_chain_broken",
          "level_span", "reach_mismatch")

# depth saturation: anything that fails to anchor at the root within
# MAX_LEVELS hops reads as CAP; 2**DOUBLING_ROUNDS must exceed CAP.
CAP = MAX_LEVELS + 1
DOUBLING_ROUNDS = 7


@dataclass(frozen=True)
class ValidationReport:
    """Host-side verdict for one (root, parents) pair."""
    root: int
    ok: bool
    violations: Dict[str, int]   # CHECKS -> violation-site count
    n_tree: int                  # vertices with parent >= 0

    def summary(self) -> str:
        if self.ok:
            return (f"valid parent tree: root={self.root}, "
                    f"{self.n_tree} vertices in tree")
        bad = ", ".join(f"{k}={v}" for k, v in self.violations.items()
                        if v)
        return (f"INVALID parent tree: root={self.root}, "
                f"{self.n_tree} vertices in tree; {bad}")

    def to_json(self) -> Dict:
        return {"root": self.root, "ok": self.ok,
                "violations": dict(self.violations),
                "n_tree": self.n_tree}


class ValidationError(RuntimeError):
    """Raised by ``BFSEngine.run(..., validate=True)`` on a bad tree."""

    def __init__(self, report: ValidationReport):
        super().__init__(report.summary())
        self.report = report


def report_from_counts(root: int, counts) -> ValidationReport:
    c = [int(x) for x in np.asarray(counts).reshape(-1)]
    viol = dict(zip(CHECKS, c[: len(CHECKS)]))
    return ValidationReport(root=int(root), ok=not any(viol.values()),
                            violations=viol, n_tree=c[len(CHECKS)])


def build_validate_fn(plan):
    """jit'd ``fn(gdev, parents_dev, root) -> (6,) int32`` for a plan.

    ``gdev`` maps the entry's ``edge_keys`` to mesh-sharded device
    arrays (block layout, P(*axes)); ``parents_dev`` is the
    block-sharded parent array exactly as ``BFSEngine.search`` returns
    it; ``root`` is a replicated int32 scalar.  Collective footprint is
    pinned by ``comm_model.validate_collective_budget`` and checked in
    ``tests/test_perf_guard.py``.
    """
    entry, part, axes = plan.entry, plan.part, plan.axes
    if entry.local_edges is None:
        raise ValueError(
            f"decomposition {entry.name!r} registers no local_edges hook; "
            "the device-side Graph500 validator requires one")
    n = part.n
    chunk = part.chunk
    n_axes = entry.n_axes
    squeeze = (0,) * n_axes

    def body(g, pi, root):
        g = {k: v[squeeze] for k, v in g.items()}
        pi_loc = pi[squeeze].astype(jnp.int32)
        root = root.astype(jnp.int32)

        # parents replicated to (n,) global layout-A order: innermost
        # axis first so each row-gather concatenates contiguous chunks
        pi_all = pi_loc
        for ax in reversed(axes):
            pi_all = lax.all_gather(pi_all, ax, tiled=True)

        idx = [lax.axis_index(ax) for ax in axes]
        blk = idx[0] if n_axes == 1 else idx[0] * part.pc + idx[1]
        base = (blk * chunk).astype(jnp.int32)
        gidx = base + jnp.arange(chunk, dtype=jnp.int32)

        vid = jnp.arange(n, dtype=jnp.int32)
        in_tree = pi_all >= 0
        ok_ref = in_tree & (pi_all < n)      # parent is a usable index
        is_root = vid == root
        # pointer doubling: hop[v] saturates at CAP unless v's chain
        # reaches the root through in-tree, in-range parents
        anc = jnp.where(ok_ref & ~is_root, pi_all, vid)
        hop = jnp.where(is_root, 0,
                        jnp.where(ok_ref, 1, CAP)).astype(jnp.int32)
        for _ in range(DOUBLING_ROUNDS):
            hop = jnp.minimum(hop + hop[anc], CAP)
            anc = anc[anc]
        depth = hop

        # local tree-edge existence: mark v if (parent[v] -> v) is a
        # stored edge slot here, then OR marks across every shard
        u, v, valid = entry.local_edges(g, part, axes)
        want = jnp.where(ok_ref, pi_all, n)  # n matches no stored u
        hit = valid & (u == want[v])
        found = jnp.zeros(n, jnp.int32).at[v].max(
            hit.astype(jnp.int32), mode="drop")
        found = lax.psum(found, axes)

        # edge-slot checks (local counts; summed at the end)
        du, dv = depth[u], depth[v]
        tu, tv = in_tree[u], in_tree[v]
        v_span = jnp.sum(valid & tu & tv & (jnp.abs(du - dv) > 1),
                         dtype=jnp.int32)
        v_reach = jnp.sum(valid & (tu != tv), dtype=jnp.int32)

        # owned-vertex checks on this block's chunk
        own_in = pi_loc >= 0
        not_root = gidx != root
        v_root = jnp.sum((gidx == root) & (pi_loc != root),
                         dtype=jnp.int32)
        depth_own = lax.dynamic_slice(depth, (base,), (chunk,))
        v_chain = jnp.sum(own_in & not_root & (depth_own >= CAP),
                          dtype=jnp.int32)
        found_own = lax.dynamic_slice(found, (base,), (chunk,)) > 0
        v_edge = jnp.sum(own_in & not_root & ~found_own,
                         dtype=jnp.int32)
        n_tree = jnp.sum(own_in, dtype=jnp.int32)

        counts = jnp.stack([v_root, v_edge, v_chain, v_span, v_reach,
                            n_tree])
        return lax.psum(counts, axes)

    gspec = {k: P(*axes) for k in entry.edge_keys}
    mapped = shard_map(body, mesh=plan.mesh,
                       in_specs=(gspec, P(*axes), P()),
                       out_specs=P(), check_vma=False)
    return jax.jit(mapped)


def _edge_arrays(engine):
    """The entry's edge_keys shards on device, reusing the engine's
    resident graph arrays where the keys overlap ``plan.keys``."""
    plan = engine.plan
    if getattr(engine, "_vdev", None) is None:
        arrays = plan.graph.device_arrays()
        sh = NamedSharding(plan.mesh, P(*plan.axes))
        vdev = {}
        for k in plan.entry.edge_keys:
            if k in engine._gdev:
                vdev[k] = engine._gdev[k]
            else:
                a = arrays[k]
                vdev[k] = a if isinstance(a, jax.Array) \
                    else jax.device_put(np.asarray(a), sh)
        engine._vdev = vdev
    return engine._vdev


def _validate_fn(engine):
    if getattr(engine, "_vfn", None) is None:
        engine._vfn = build_validate_fn(engine.plan)
    return engine._vfn


def validate_device(engine, root: int, pi_dev) -> ValidationReport:
    """Validate a block-sharded device parent array in place."""
    fn = _validate_fn(engine)
    counts = fn(_edge_arrays(engine), pi_dev, jnp.int32(root))
    return report_from_counts(root, np.asarray(counts))


def validate_parents(engine, root: int, parents) -> ValidationReport:
    """Validate a HOST parent array (``(n_orig,)`` or ``(n,)`` flat, or
    already block-shaped) against the engine's graph shards.

    This is the entry point for post-hoc validation — results restored
    from disk, batch outputs, fault-injection probes.  The array is
    padded with -1 ghosts to ``n``, reshaped to the plan's block
    layout, and shipped sharded; only the (6,) verdict returns.
    """
    plan = engine.plan
    part = plan.part
    root = engine._check_root(root)
    flat = np.asarray(parents).reshape(-1).astype(np.int64)
    if flat.shape[0] == part.n_orig:
        full = np.full(part.n, -1, np.int64)
        full[: part.n_orig] = flat
    elif flat.shape[0] == part.n:
        full = flat
    else:
        raise ValueError(
            f"parents has {flat.shape[0]} entries; expected n_orig="
            f"{part.n_orig} or padded n={part.n}")
    # device parents are int32; clamp so host int64 garbage (e.g. a
    # bit flip above bit 31) still reads as an out-of-range parent
    # instead of wrapping back into range
    full = np.clip(full, -1, np.iinfo(np.int32).max).astype(np.int32)
    if plan.entry.n_axes == 1:
        blocks = full.reshape(part.p, part.chunk)
    else:
        blocks = full.reshape(part.pr, part.pc, part.chunk)
    pi_dev = jax.device_put(
        blocks, NamedSharding(plan.mesh, P(*plan.axes)))
    return validate_device(engine, root, pi_dev)
