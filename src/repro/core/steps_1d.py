"""Per-level BFS steps for the 1D row decomposition (the paper's Alg. 1/2
distributed baseline, Buluc & Madduri): shard_map bodies over ONE mesh
axis of size p.

Schedule per level:

  expand : pack the owned frontier chunk into a bitmap and allgather it
           along the single axis -> every processor holds the full
           n-vertex frontier.  This replaces BOTH the 2D transpose and
           fold phases (there is no second axis to exchange along), so
           the entire wire volume of a 1D level is the allgather.
  local  : top-down — SpMSV over the strip T[V_i, :] (select-source,
           min semiring) through the LocalOps entry (core/local_ops.py):
           edge-parallel dense, strip-CSR Pallas gather, or the
           strip-DCSC Pallas kernel over non-empty global columns
           (kernels/spmsv/strip.py); bottom-up — in-neighbor scan of
           unvisited owned rows.  Discovered children are *always
           locally owned* (the strip holds every edge into V_i), so the
           parent update is local and fold-free.

Counters share COUNTER_KEYS with the 2D steps (core/steps.py) so the
driver, benchmarks, and Eq. 2 comparisons treat both decompositions
uniformly; 1D leaves wire_transpose / wire_fold / wire_rotate /
wire_updates at zero by construction.  wire_expand per level is
(p-1) * n/64 global 64-bit words (dense bitmap, every chunk replicated
to the other p-1 processors) — the closed form in
``core.comm_model.expand_1d_words``.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import comm_model
from repro.core.frontier import INT_INF, pack_bits, unpack_bits
from repro.core.steps import zero_counters


class LevelArgs1D(NamedTuple):
    """Static/per-search context threaded into 1D level steps.  Local
    discovery goes through the LocalOps entry (core/local_ops.py) —
    dense edge-parallel, strip-CSR kernel, or the strip-DCSC Pallas
    kernel all plug in behind the same two closures."""
    part: "object"            # Partition1D (static)
    axis: str                 # the single mesh axis name
    use_edge_dst: bool = False  # bottom-up: read per-edge rows (no search)
    local_mode: str = "dense"  # "dense" | "kernel" (Pallas)
    storage: str = "csr"      # "csr" | "dcsc" (strip pointer compression)
    cap_f: int = 0            # kernel csr: frontier capacity (0 = n)
    maxdeg: int = 0           # kernel mode: max column-segment length
    ops: "object" = None      # LocalOps entry (None = look up from strings)
    instrument: bool = True   # False: compile out counters/level_stats
    # software-pipelined expand: split the top-down allgather into this
    # many sub-chunk collectives, consuming sub-chunk k while k+1 is in
    # flight (1 = the classic single-gather schedule)
    expand_chunks: int = 1


def _resolve_ops(args: "LevelArgs1D"):
    if args.ops is not None:
        return args.ops
    from repro.core.local_ops import get_local_ops
    return get_local_ops("1d", args.local_mode, args.storage)


def expand_frontier_1d(front: jax.Array, axis: str):
    """Allgather the packed frontier chunk along the single axis.

    Returns (f_words uint32[n//32], ctr-updates dict with the global
    wire/use expand words in paper 64-bit units)."""
    words = pack_bits(front)                         # (chunk//32,) u32
    gathered = lax.all_gather(words, axis, tiled=True)
    p = lax.psum(1, axis)   # static axis size (lax.axis_size needs newer jax)
    # shared closed form (word-size conversion lives in comm_model, so
    # the measured counter and the model cannot drift): n = chunk * p
    wire = jnp.float32(comm_model.expand_1d_level_words(words.size * 32 * p, p))
    return gathered, wire


# ---------------------------------------------------------------------------
# Software-pipelined (chunked) expand
# ---------------------------------------------------------------------------
#
# With ``expand_chunks = C > 1`` the top-down expand splits each owner's
# packed strip words into C contiguous sub-chunks and runs C tiled
# allgathers, issuing sub-chunk k+1's gather BEFORE consuming sub-chunk
# k — the gathered sub-chunk feeds local discovery while the next
# collective is in flight (total bytes unchanged:
# ``comm_model.chunked_expand_1d_level_words``).  Exactness: every
# top-down closure resolves candidates by scatter-MIN of global source
# ids ((select-source, min) semiring), so per-sub-chunk partial SpMSV
# passes combine exactly via ``jnp.minimum``.  Bottom-up keeps the ONE
# dense allgather regardless of expand_chunks: its unvisited-row scan
# takes the FIRST frontier in-neighbor (not the min), so partial-bitmap
# passes would not combine exactly, and the heuristic only enters
# bottom-up on large frontiers where the single tiled gather is
# bandwidth- (not latency-) bound anyway.
#
# Gathered sub-chunk layout (both the dense gather and the 1ds sparse
# sub-bucket decode produce it): ``(p * w_sub,)`` u32 words, owner-major
# — owner i's words for LOCAL word range [k*w_sub, (k+1)*w_sub) sit at
# [i*w_sub, (i+1)*w_sub), i.e. sub-chunk k covers owner-local vertices
# [k*sub, (k+1)*sub) with sub = chunk/C.


def _consume_subchunk(g, g_k, k: int, n_chunks: int, args: "LevelArgs1D"):
    """Local discovery over ONE gathered sub-chunk -> (cand_k, ex_k).

    Entries with a chunk-aware kernel closure (``LocalOps.topdown_chunk``,
    e.g. the strip-DCSC Pallas kernel's per-chunk entry point) consume
    the raw owner-major sub-chunk words directly; everything else gets
    the sub-chunk scattered into a full-size partial frontier bitmap and
    goes through the ordinary ``topdown`` closure."""
    part = args.part
    ops = _resolve_ops(args)
    if getattr(ops, "topdown_chunk", None) is not None:
        return ops.topdown_chunk(g, g_k, k, n_chunks, part.chunk,
                                 jnp.int32(0), args)
    p = part.p
    w_sub = g_k.size // p
    fw_k = jnp.zeros((p, n_chunks, w_sub), jnp.uint32).at[:, k, :].set(
        g_k.reshape(p, w_sub)).reshape(-1)
    f_k = unpack_bits(fw_k)
    return ops.topdown(g, fw_k, f_k, part.chunk, jnp.int32(0), args)


def pipelined_expand_consume(g, sub_gather, n_chunks: int,
                             args: "LevelArgs1D"):
    """Run the C-step expand/discover software pipeline.

    ``sub_gather(k)`` issues the collective for sub-chunk k and returns
    the gathered owner-major words.  The gather for sub-chunk k+1 is
    issued before sub-chunk k is consumed, so the collective has no data
    dependency on the SpMSV below it and the two overlap.  Candidate
    parents min-combine across sub-chunks (exact under the
    (select-source, min) semiring); edges-examined sums."""
    cand = jnp.full((args.part.chunk,), INT_INF, jnp.int32)
    ex = jnp.float32(0.0)
    nxt = sub_gather(0)
    for k in range(n_chunks):
        cur = nxt
        if k + 1 < n_chunks:
            nxt = sub_gather(k + 1)     # in flight during the consume below
        c_k, e_k = _consume_subchunk(g, cur, k, n_chunks, args)
        cand = jnp.minimum(cand, c_k)
        ex = ex + e_k
    return cand, ex


def _pipelined_topdown_expand_1d(g, front: jax.Array, args: "LevelArgs1D"):
    """Chunked dense expand: C sub-chunk allgathers overlapped with the
    per-sub-chunk SpMSV.  Returns (cand, ex_local, wire)."""
    part = args.part
    C = args.expand_chunks
    words = pack_bits(front)                         # (chunk//32,) u32
    subs = words.reshape(C, words.size // C)
    cand, ex = pipelined_expand_consume(
        g, lambda k: lax.all_gather(subs[k], args.axis, tiled=True), C, args)
    wire = jnp.float32(
        comm_model.chunked_expand_1d_level_words(part.n, part.p, C))
    return cand, ex, wire


def topdown_level_1d(g: Dict[str, jax.Array], pi: jax.Array,
                     front: jax.Array, args: LevelArgs1D, lv=None
                     ) -> Tuple[jax.Array, jax.Array, Dict]:
    """One 1D top-down level. g holds the strip arrays (squeezed).
    ``lv`` is the fast-path per-level context (unused here); with
    ``args.instrument`` False the level is ONE collective — the bitmap
    allgather — and ``ctr`` comes back empty."""
    part = args.part
    instr = args.instrument
    ctr = zero_counters() if instr else {}

    if args.expand_chunks > 1:
        # Software pipeline: C sub-chunk allgathers, each consumed by a
        # partial SpMSV while the next is in flight (same total bytes).
        cand, ex_local, wire = _pipelined_topdown_expand_1d(g, front, args)
    else:
        # --- Expand: allgather the frontier bitmap along the axis --------
        f_words, wire = expand_frontier_1d(front, args.axis)
        f_all = unpack_bits(f_words)                 # (n,) bool
        # --- Local discovery: SpMSV over the strip (global source ids, so
        # col_offset = 0; format-specific work lives in the LocalOps
        # entry) --
        cand, ex_local = _resolve_ops(args).topdown(g, f_words, f_all,
                                                    part.chunk, jnp.int32(0),
                                                    args)
    if instr:
        ctr["wire_expand"] = wire
        n_f = lax.psum(jnp.sum(front, dtype=jnp.float32), args.axis)
        ctr["use_expand"] = n_f * (part.p - 1)       # sparse-id equivalent
        ctr["edges_examined"] = lax.psum(ex_local, args.axis)
        ctr["edges_useful"] = lax.psum(
            jnp.sum(jnp.where(front, g["deg_A"], 0), dtype=jnp.float32),
            args.axis)

    # --- Local update (children are owned; no fold) ----------------------
    newly = (pi == -1) & (cand != INT_INF)
    pi = jnp.where(newly, cand, pi)
    return pi, newly, ctr


def bottomup_level_1d(g: Dict[str, jax.Array], pi: jax.Array,
                      front: jax.Array, args: LevelArgs1D, lv=None
                      ) -> Tuple[jax.Array, jax.Array, Dict]:
    """One 1D bottom-up level: after the same frontier allgather, each
    processor scans its *unvisited* owned rows for an in-neighbor in the
    frontier — one sub-step, no rotation (the strip already holds every
    potential parent edge)."""
    part = args.part
    instr = args.instrument
    ctr = zero_counters() if instr else {}

    f_words, wire = expand_frontier_1d(front, args.axis)
    if instr:
        ctr["wire_expand"] = wire
        ctr["use_expand"] = jnp.float32(
            comm_model.expand_1d_level_words(part.n, part.p))

    cvec = (pi != -1).astype(jnp.int32)
    ve = g["edge_dst"] if args.use_edge_dst and "edge_dst" in g else None
    seg_par = _resolve_ops(args).bottomup(g["row_ptr"], g["col_idx"],
                                          f_words, cvec, jnp.int32(0),
                                          g["nnz"], ve)
    newly = (pi == -1) & (seg_par != INT_INF)
    pi = jnp.where(newly, seg_par, pi)

    if instr:
        row_lens = (g["row_ptr"][1:] - g["row_ptr"][:-1]).astype(jnp.float32)
        edges_use = lax.psum(
            jnp.sum(jnp.where(cvec == 0, row_lens, 0.0)), args.axis)
        ctr["edges_examined"] = edges_use
        ctr["edges_useful"] = edges_use
        # parent updates are local in 1D: use_updates counts discoveries
        # for Eq. 2 comparability, wire_updates stays 0
        ctr["use_updates"] = 2.0 * lax.psum(
            jnp.sum(newly, dtype=jnp.float32), args.axis)
    return pi, newly, ctr
