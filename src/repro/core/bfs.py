"""Public distributed-BFS API: direction-optimizing BFS in either the 1D
row decomposition (paper Alg. 1/2 distributed baseline) or the 2D
checkerboard (paper §4.4), selected by ``BFSConfig.decomposition``
("1d" | "2d").

The whole search (level loop + direction switching + both step kinds) is
a single shard_map'd, jitted program — over mesh axes (row, col) =
(pr, pc) for 2D, over the single row axis of size p for 1D.  Direction
switching uses the Beamer heuristics the paper cites (§4.4): top-down ->
bottom-up when m_f > m_u/alpha, back when n_f < n/beta; the level loop,
heuristics, per-level stats, and COUNTER_KEYS accounting are shared
between the decompositions (``_search_loop``), so 1D-vs-2D wire-volume
comparisons (the paper's Eq. 2) read identical counter dicts out of
``BFSResult.counters``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import BFSConfig
from repro.core import steps
from repro.core.compat import shard_map
from repro.core.local_ops import get_local_ops
from repro.core.partition import Partition1D, Partition2D
from repro.core.steps import LevelArgs, bottomup_level, topdown_level, zero_counters
from repro.core.steps_1d import (LevelArgs1D, bottomup_level_1d,
                                 topdown_level_1d)
from repro.graph.formats import Blocked1DGraph, BlockedGraph

MAX_LEVELS = 64

# Which graph arrays a given (decomposition, local_mode, storage) combo
# ships is declared by its LocalOps registry entry (core/local_ops.py);
# the old _DENSE_KEYS/_KERNEL_KEYS tuples live there as entry.keys.


@dataclass
class BFSResult:
    parents: np.ndarray          # (n_orig,)
    n_levels: int
    counters: Dict[str, float]   # whole-search totals (paper 64-bit words)
    level_stats: np.ndarray      # (MAX_LEVELS, 4): n_f, m_f, mode, used


def _search_loop(g, gidx, root, *, n_total: float, cfg: BFSConfig, axes,
                 sync, td_level, bu_level):
    """The decomposition-agnostic whole-search level loop: frontier-size /
    edge-mass heuristics, per-level stats, counter accumulation.
    ``td_level`` / ``bu_level`` are (pi, front) -> (pi, front, ctr) step
    closures over the local graph ``g`` (already squeezed)."""
    pi0 = jnp.where(gidx == root, root, jnp.int32(-1))
    front0 = gidx == root
    stats0 = jnp.zeros((MAX_LEVELS, 4), jnp.float32)

    def cond(st):
        pi, front, mode, level, n_f, ctr, stats = st
        return (level < MAX_LEVELS) & (n_f > 0)

    def body(st):
        pi, front, mode, level, n_f, ctr, stats = st
        m_f = lax.psum(jnp.sum(jnp.where(front, g["deg_A"], 0),
                               dtype=jnp.float32), axes)
        m_u = lax.psum(jnp.sum(jnp.where(pi == -1, g["deg_A"], 0),
                               dtype=jnp.float32), axes)
        if cfg.direction_optimizing:
            go_bu = (mode == 0) & (m_f > m_u / cfg.alpha)
            go_td = (mode == 1) & (n_f < n_total / cfg.beta)
            new_mode = jnp.where(go_bu, 1, jnp.where(go_td, 0, mode))
        else:
            new_mode = mode
        stats = stats.at[level].set(
            jnp.stack([n_f, m_f, new_mode.astype(jnp.float32),
                       jnp.float32(1)]))

        pi2, front2, c2 = lax.cond(
            new_mode == 1,
            lambda pf: bu_level(pf[0], pf[1]),
            lambda pf: td_level(pf[0], pf[1]),
            (pi, front))
        ctr = {k: ctr[k] + c2[k] for k in ctr}
        n_f2 = lax.psum(jnp.sum(front2, dtype=jnp.float32), axes)
        # cond feeds on the cross-slice max so batched searches stay in
        # lockstep (heuristics above use the per-slice n_f)
        n_sync = lax.pmax(n_f2, sync) if sync != axes else n_f2
        return (pi2, front2, new_mode, level + 1, n_sync, ctr, stats)

    st = (pi0, front0, jnp.int32(0), jnp.int32(0), jnp.float32(1.0),
          zero_counters(), stats0)
    pi, front, mode, level, n_f, ctr, stats = lax.while_loop(cond, body, st)
    return pi, level, ctr, stats


def _bfs_body(g, root, *, part: Partition2D, args: LevelArgs, cfg: BFSConfig,
              n_real_edges: float, sync_axis: Optional[str] = None):
    """sync_axis: when searches run batched across an outer axis (pods),
    the level loop must take the same trip count on every slice — the
    loop continues while ANY slice has a live frontier (idle slices run
    empty levels; collectives stay aligned)."""
    pc, chunk = part.pc, part.chunk
    axes = (args.row_axis, args.col_axis)
    sync = axes + ((sync_axis,) if sync_axis else ())
    i = lax.axis_index(args.row_axis)
    j = lax.axis_index(args.col_axis)
    g = {k: v[0, 0] for k, v in g.items()}

    gidx = ((i * pc + j) * chunk + jnp.arange(chunk)).astype(jnp.int32)
    pi, level, ctr, stats = _search_loop(
        g, gidx, root, n_total=part.n, cfg=cfg, axes=axes, sync=sync,
        td_level=lambda pi, f: topdown_level(g, pi, f, args),
        bu_level=lambda pi, f: bottomup_level(g, pi, f, args))
    return pi[None, None], level, ctr, stats


def _bfs_body_1d(g, root, *, part: Partition1D, args: LevelArgs1D,
                 cfg: BFSConfig, sync_axis: Optional[str] = None):
    """1D row-decomposition whole-search body over the single mesh axis."""
    axes = (args.axis,)
    sync = axes + ((sync_axis,) if sync_axis else ())
    i = lax.axis_index(args.axis)
    g = {k: v[0] for k, v in g.items()}

    gidx = (i * part.chunk + jnp.arange(part.chunk)).astype(jnp.int32)
    pi, level, ctr, stats = _search_loop(
        g, gidx, root, n_total=part.n, cfg=cfg, axes=axes, sync=sync,
        td_level=lambda pi, f: topdown_level_1d(g, pi, f, args),
        bu_level=lambda pi, f: bottomup_level_1d(g, pi, f, args))
    return pi[None], level, ctr, stats


def make_bfs_fn_1d(mesh, part: Partition1D, cfg: BFSConfig,
                   axis: str = "data", local_mode: str = "dense",
                   maxdeg: int = 0, cap_f: int = 0):
    """Build the jitted whole-search 1D BFS function.  The LocalOps
    registry supplies the strip's local-discovery closures and shipping
    keys for ``(local_mode, cfg.storage)`` — dense edge-parallel,
    strip-CSR gather, or the strip-DCSC Pallas kernel.  Returns
    fn(graph_arrays_dict, root) -> (pi, level, ctr, stats)."""
    ops = get_local_ops("1d", local_mode, cfg.storage)
    args = LevelArgs1D(part=part, axis=axis,
                       use_edge_dst=cfg.use_edge_dst,
                       local_mode=local_mode, storage=cfg.storage,
                       cap_f=cap_f, maxdeg=maxdeg, ops=ops)
    body = functools.partial(_bfs_body_1d, part=part, args=args, cfg=cfg)
    gspec = {k: P(axis) for k in ops.keys}
    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(gspec, P()),
        out_specs=(P(axis), P(), {k: P() for k in steps.COUNTER_KEYS}, P()),
        check_vma=False)
    return jax.jit(mapped), ops.keys


def make_bfs_fn(mesh, part, cfg: BFSConfig, cap_seg: int = 0,
                row_axis: str = "data", col_axis: str = "model",
                local_mode: str = "dense", n_real_edges: float = 0.0,
                maxdeg: int = 0, cap_f: int = 0):
    """Build the jitted whole-search BFS function for a given mesh/graph
    geometry, dispatching on ``cfg.decomposition`` ("1d" | "2d"; the 1D
    path uses ``row_axis`` as its single mesh axis and ignores the fold/
    transpose knobs).  Returns fn(graph_arrays_dict, root) ->
    (pi, level, ctr, stats)."""
    if getattr(cfg, "decomposition", "2d") == "1d":
        if not isinstance(part, Partition1D):
            raise TypeError(f"decomposition='1d' needs a Partition1D, "
                            f"got {type(part).__name__}")
        return make_bfs_fn_1d(mesh, part, cfg, axis=row_axis,
                              local_mode=local_mode, maxdeg=maxdeg,
                              cap_f=cap_f)
    if cap_seg <= 0:
        # the bottom-up branch always compiles (lax.cond), and a zero
        # edge window would silently discover nothing
        raise ValueError("2d decomposition needs cap_seg > 0 "
                         "(pass graph.cap_seg)")
    ops = get_local_ops("2d", local_mode, cfg.storage)
    args = LevelArgs(part=part, row_axis=row_axis, col_axis=col_axis,
                     fold_mode=cfg.fold_mode,
                     perm=tuple(part.transpose_perm()), cap_seg=cap_seg,
                     local_mode=local_mode, storage=cfg.storage,
                     cap_f=cap_f, maxdeg=maxdeg,
                     use_edge_dst=cfg.use_edge_dst,
                     compact_updates=cfg.compact_updates, ops=ops)
    body = functools.partial(_bfs_body, part=part, args=args, cfg=cfg,
                             n_real_edges=n_real_edges)
    gspec = {k: P(row_axis, col_axis) for k in ops.keys}
    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(gspec, P()),
        out_specs=(P(row_axis, col_axis), P(), {
            k: P() for k in steps.COUNTER_KEYS}, P()),
        check_vma=False,   # pallas_call outputs carry no vma annotation
    )
    return jax.jit(mapped), ops.keys


def make_multiroot_bfs_fn(mesh, part: Partition2D, cfg: BFSConfig,
                          cap_seg: int, n_roots: int,
                          pod_axis: str = "pod", row_axis: str = "data",
                          col_axis: str = "model", maxdeg: int = 0,
                          local_mode: str = "dense", cap_f: int = 0,
                          n_real_edges: float = 0.0):
    """Batched independent BFS roots sharded over the pod axis — the
    multi-pod Graph500 pattern (16-64 roots per benchmark run, pods are
    embarrassingly parallel across roots; graph blocks replicated across
    pods, zero inter-pod traffic).  Routed through the same LocalOps
    registry as the single-root builders, so ``local_mode``/``cap_f``
    select the kernel paths here too instead of always shipping the
    dense key set."""
    ops = get_local_ops("2d", local_mode, cfg.storage)
    args = LevelArgs(part=part, row_axis=row_axis, col_axis=col_axis,
                     fold_mode=cfg.fold_mode,
                     perm=tuple(part.transpose_perm()), cap_seg=cap_seg,
                     local_mode=local_mode, storage=cfg.storage,
                     cap_f=cap_f, maxdeg=maxdeg,
                     use_edge_dst=cfg.use_edge_dst,
                     compact_updates=cfg.compact_updates, ops=ops)
    body1 = functools.partial(_bfs_body, part=part, args=args, cfg=cfg,
                              n_real_edges=n_real_edges,
                              sync_axis=pod_axis)

    def multi_body(g, roots):
        # roots: (n_roots_local,) — scan full searches over local roots
        def one(carry, root):
            pi, level, ctr, stats = body1(g, root)
            return carry, (pi[0, 0], level)
        _, (pis, levels) = lax.scan(one, jnp.int32(0), roots.reshape(-1))
        return pis[None, None], levels

    gspec = {k: P(row_axis, col_axis) for k in ops.keys}
    mapped = shard_map(
        multi_body, mesh=mesh,
        in_specs=(gspec, P(pod_axis)),
        out_specs=(P(row_axis, col_axis, pod_axis, None), P(pod_axis)),
        check_vma=False)
    return jax.jit(mapped), ops.keys


def run_bfs(graph, root: int, cfg: BFSConfig, mesh,
            row_axis: str = "data", col_axis: str = "model",
            local_mode: str = "dense", cap_f: int = 0) -> BFSResult:
    """End-to-end convenience wrapper: ship blocks, run, validate shapes.

    ``graph`` is a BlockedGraph (2D) or Blocked1DGraph (1D); which one
    must match ``cfg.decomposition``.  The returned BFSResult is
    layout-independent (parents indexed by global vertex id, counters in
    the shared COUNTER_KEYS units), so callers can diff 1D vs 2D runs
    directly."""
    part = graph.part
    one_d = getattr(cfg, "decomposition", "2d") == "1d"
    if one_d != isinstance(graph, Blocked1DGraph):
        raise TypeError(
            f"cfg.decomposition={cfg.decomposition!r} does not match "
            f"graph type {type(graph).__name__}")
    if one_d:
        fn, keys = make_bfs_fn(mesh, part, cfg, row_axis=row_axis,
                               local_mode=local_mode,
                               maxdeg=graph.maxdeg_col, cap_f=cap_f)
        sh = NamedSharding(mesh, P(row_axis))
    else:
        fn, keys = make_bfs_fn(mesh, part, cfg, graph.cap_seg, row_axis,
                               col_axis, local_mode, n_real_edges=graph.m,
                               maxdeg=graph.maxdeg_col, cap_f=cap_f)
        sh = NamedSharding(mesh, P(row_axis, col_axis))
    arrays = graph.device_arrays()
    missing = [k for k in keys if k not in arrays]
    if missing:
        raise ValueError(
            f"graph lacks arrays {missing} needed by local_mode="
            f"{local_mode!r}/storage={cfg.storage!r} (1d csr kernels need "
            f"build_blocked_1d(..., with_col_ptr=True))")
    gdev = {k: jax.device_put(np.asarray(arrays[k]), sh) for k in keys}
    pi, level, ctr, stats = fn(gdev, jnp.int32(root))
    pi = np.asarray(pi).reshape(part.n)[: part.n_orig]
    return BFSResult(
        parents=pi.astype(np.int64),
        n_levels=int(level),
        counters={k: float(v) for k, v in ctr.items()},
        level_stats=np.asarray(stats),
    )
