"""Legacy one-shot BFS API, kept as thin wrappers over the session API.

The real machinery lives in two places now:

  core/decomp.py — the decomposition registry ("1d" row strips | "2d"
                   checkerboard): partition/graph types, mesh-axis
                   layout, LevelArgs builders, whole-search bodies.
  core/engine.py — plan_bfs -> BFSPlan -> compile() -> BFSEngine, the
                   compile-once / traverse-many session the Graph500
                   drivers use.

These wrappers preserve the pre-engine call signatures: the
``make_*_bfs_fn`` builders return a jitted ``fn(graph_arrays, root)``
plus the shipping keys, and ``run_bfs`` plans + compiles + runs a single
root end-to-end (paying the per-call compile the engine exists to
avoid — prefer ``plan_bfs(...).compile()`` for anything that traverses
more than once).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import BFSConfig
from repro.core.decomp import MAX_LEVELS  # noqa: F401  (re-export)
from repro.core.engine import (BFSBatchResult, BFSResult,  # noqa: F401
                               plan_bfs, plan_for_part)
from repro.core.partition import Partition1D, Partition2D


def make_bfs_fn_1d(mesh, part: Partition1D, cfg: BFSConfig,
                   axis: str = "data", local_mode: str = "dense",
                   maxdeg: int = 0, cap_f: int = 0, cap_x: int = 0):
    """Build the jitted whole-search 1D BFS function.  Returns
    fn(graph_arrays_dict, root) -> (pi, level, ctr, stats)."""
    if cfg.decomposition not in ("1d", "1ds"):
        cfg = dataclasses.replace(cfg, decomposition="1d")
    plan = plan_for_part(part, cfg, mesh, row_axis=axis,
                         local_mode=local_mode, maxdeg=maxdeg, cap_f=cap_f,
                         cap_x=cap_x)
    return plan.build_fn(), plan.keys


def make_bfs_fn(mesh, part, cfg: BFSConfig, cap_seg: int = 0,
                row_axis: str = "data", col_axis: str = "model",
                local_mode: str = "dense", n_real_edges: float = 0.0,
                maxdeg: int = 0, cap_f: int = 0, cap_x: int = 0):
    """Build the jitted whole-search BFS function for a given mesh/graph
    geometry, dispatching on ``cfg.decomposition`` through the
    decomposition registry.  Returns fn(graph_arrays_dict, root) ->
    (pi, level, ctr, stats)."""
    plan = plan_for_part(part, cfg, mesh, row_axis=row_axis,
                         col_axis=col_axis, local_mode=local_mode,
                         cap_seg=cap_seg, maxdeg=maxdeg, cap_f=cap_f,
                         cap_x=cap_x, n_real_edges=n_real_edges)
    return plan.build_fn(), plan.keys


def make_multiroot_bfs_fn(mesh, part: Partition2D, cfg: BFSConfig,
                          cap_seg: int, n_roots: int,
                          pod_axis: str = "pod", row_axis: str = "data",
                          col_axis: str = "model", maxdeg: int = 0,
                          local_mode: str = "dense", cap_f: int = 0,
                          cap_x: int = 0, n_real_edges: float = 0.0):
    """Batched independent BFS roots sharded over the pod axis — the
    multi-pod Graph500 pattern (16-64 roots per benchmark run, pods are
    embarrassingly parallel across roots; graph blocks replicated across
    pods, zero inter-pod traffic).  Works in any registered
    decomposition; prefer ``BFSEngine.run_batch`` for new code.
    ``n_roots`` is documentation only — the roots-per-pod count is fixed
    by the shape of the roots array the program is compiled against."""
    del n_roots
    plan = plan_for_part(part, cfg, mesh, row_axis=row_axis,
                         col_axis=col_axis, local_mode=local_mode,
                         cap_seg=cap_seg, maxdeg=maxdeg, cap_f=cap_f,
                         cap_x=cap_x, n_real_edges=n_real_edges)
    return plan.build_batch_fn(pod_axis), plan.keys


def run_bfs(graph, root: int, cfg: BFSConfig, mesh,
            row_axis: str = "data", col_axis: str = "model",
            local_mode: str = "dense", cap_f: int = 0,
            cap_x: int = 0) -> BFSResult:
    """One-shot convenience wrapper: plan, compile, run a single root.

    ``graph`` is a BlockedGraph (2D) or Blocked1DGraph (1D/1Ds); which
    one must match ``cfg.decomposition``.  Ships + compiles on EVERY
    call — use ``plan_bfs(graph, cfg, mesh).compile()`` and run the
    engine when traversing from more than one root.  ``cap_x`` overrides
    the planned "1ds" sparse-exchange bucket capacity."""
    plan = plan_bfs(graph, cfg, mesh, row_axis=row_axis, col_axis=col_axis,
                    local_mode=local_mode, cap_f=cap_f, cap_x=cap_x)
    return plan.compile().run(root)
