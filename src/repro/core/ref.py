"""Sequential oracles: Algorithm 1 (top-down), Algorithm 2 (bottom-up),
and a BFS-tree validity checker.  Pure numpy — the ground truth every
distributed / kernel implementation is validated against.

Parent choice in BFS is nondeterministic (any depth-(d-1) in-neighbor is
legal), so validation checks *tree validity + depth equality*, not
parent-array equality.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _csr(n: int, src: np.ndarray, dst: np.ndarray):
    order = np.lexsort((dst, src))
    s, d = src[order], dst[order]
    ptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(ptr, s + 1, 1)
    np.cumsum(ptr, out=ptr)
    return ptr, d


def bfs_topdown(n: int, src: np.ndarray, dst: np.ndarray, root: int) -> np.ndarray:
    """Algorithm 1. Returns parent[n] (root's parent = root; -1 unreachable)."""
    ptr, adj = _csr(n, src, dst)
    parent = np.full(n, -1, dtype=np.int64)
    parent[root] = root
    frontier = np.array([root], dtype=np.int64)
    while frontier.size:
        nxt = []
        for u in frontier:
            for v in adj[ptr[u]:ptr[u + 1]]:
                if parent[v] == -1:
                    parent[v] = u
                    nxt.append(v)
        frontier = np.array(nxt, dtype=np.int64)
    return parent


def bfs_bottomup(n: int, src: np.ndarray, dst: np.ndarray, root: int) -> np.ndarray:
    """Algorithm 2 (in-neighbor scan with early exit)."""
    # in-neighbors of v = sources u of edges u->v
    ptr, radj = _csr(n, dst, src)
    parent = np.full(n, -1, dtype=np.int64)
    parent[root] = root
    frontier = np.zeros(n, dtype=bool)
    frontier[root] = True
    while frontier.any():
        nxt = np.zeros(n, dtype=bool)
        for u in range(n):
            if parent[u] == -1:
                for v in radj[ptr[u]:ptr[u + 1]]:
                    if frontier[v]:
                        parent[u] = v
                        nxt[u] = True
                        break
        frontier = nxt
    return parent


def bfs_depths(n: int, src: np.ndarray, dst: np.ndarray, root: int) -> np.ndarray:
    """Level-synchronous depths (vectorized; oracle for big tests)."""
    ptr, adj = _csr(n, src, dst)
    depth = np.full(n, -1, dtype=np.int64)
    depth[root] = 0
    frontier = np.array([root], dtype=np.int64)
    d = 0
    while frontier.size:
        # all neighbors of the frontier
        counts = ptr[frontier + 1] - ptr[frontier]
        total = int(counts.sum())
        if total == 0:
            break
        out = np.empty(total, dtype=np.int64)
        pos = 0
        for u, c in zip(frontier, counts):
            out[pos:pos + c] = adj[ptr[u]:ptr[u] + c]
            pos += c
        nbrs = np.unique(out)
        new = nbrs[depth[nbrs] == -1]
        depth[new] = d + 1
        frontier = new
        d += 1
    return depth


def depths_from_parents(n: int, parent: np.ndarray, root: int) -> np.ndarray:
    """Derive depths by iterating parent chains.  Parent choice in BFS is
    nondeterministic but depths are unique, so this is the comparison key
    for cross-implementation (e.g. 1D vs 2D) equality checks."""
    parent = np.asarray(parent, dtype=np.int64)
    depth = np.full(n, -1, np.int64)
    depth[root] = 0
    for _ in range(n):
        upd = (depth == -1) & (parent >= 0) & (depth[parent] >= 0)
        if not upd.any():
            break
        depth[upd] = depth[parent[upd]] + 1
    return depth


def validate_parents(n: int, src: np.ndarray, dst: np.ndarray, root: int,
                     parent: np.ndarray) -> Tuple[bool, str]:
    """BFS-tree validity: (1) root self-parent, (2) every tree edge exists,
    (3) parent depth = child depth - 1, (4) reachable set matches oracle."""
    depth = bfs_depths(n, src, dst, root)
    parent = np.asarray(parent, dtype=np.int64)
    if parent[root] != root:
        return False, "root parent mismatch"
    reach_ref = depth >= 0
    reach_got = parent >= 0
    if not np.array_equal(reach_ref, reach_got):
        miss = int(np.sum(reach_ref != reach_got))
        return False, f"reachable-set mismatch on {miss} vertices"
    vs = np.flatnonzero(reach_got)
    vs = vs[vs != root]
    ps = parent[vs]
    # tree-edge existence: each (parent[v], v) must be an input edge
    key_edges = set((src * np.int64(n) + dst).tolist())
    bad_edges = [(int(p), int(v)) for p, v in zip(ps, vs)
                 if int(p) * n + int(v) not in key_edges]
    if bad_edges:
        return False, f"{len(bad_edges)} tree edges not in graph, e.g. {bad_edges[:3]}"
    if not np.array_equal(depth[vs], depth[ps] + 1):
        bad = int(np.sum(depth[vs] != depth[ps] + 1))
        return False, f"{bad} vertices with parent depth != depth-1"
    return True, "ok"
