"""2D-partitioned SpMM: the paper's BFS machinery generalized to feature
aggregation (sum semiring, d-wide payloads) — the distributed primitive
behind full-graph GNN training (GIN/GAT/products cells).

Identical schedule to top-down BFS (Alg. 3):
  expand : TransposeVector (collective-permute) + allgather along the
           processor column  -> sender-feature slice X[C_j]  (nc, d)
  local  : edge-parallel gather + segment-sum into the row strip (nr, d)
  fold   : **psum_scatter** along the processor row — a true in-network
           combining reduce-scatter (the sum semiring allows what the
           min semiring of BFS could not), bandwidth-optimal on the ICI
           torus.  This is the beyond-paper optimization the roofline
           rewards: fold wire volume drops from (pc-1)*nr to the
           reduce-scatter optimum with zero extra latency terms.

Out-degree normalization etc. are callers' business (they own vertex-wise
scaling in layout A).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.partition import Partition2D
from repro.graph.formats import BlockedGraph


def _spmm_body(g: Dict[str, jax.Array], x: jax.Array, *, part: Partition2D,
               perm, row_axis: str, col_axis: str):
    pr, pc, chunk, nc, nr = part.pr, part.pc, part.chunk, part.nc, part.nr
    g = {k: v[0, 0] for k, v in g.items()}
    x = x[0, 0]                                   # (chunk, d) layout A
    # expand: A -> B layout, then allgather C_j slice along the column
    xb = lax.ppermute(x, (row_axis, col_axis), perm)
    x_cj = lax.all_gather(xb, row_axis, tiled=True)        # (nc, d)
    # local: edge-parallel segment-sum into the row strip
    e_mask = (jnp.arange(g["edge_src"].shape[0]) < g["nnz"])[:, None]
    contrib = x_cj[g["edge_src"]] * e_mask.astype(x.dtype)
    partial = jax.ops.segment_sum(contrib, g["row_idx"], num_segments=nr)
    # fold: combining reduce-scatter along the row
    out = lax.psum_scatter(partial, col_axis, scatter_dimension=0,
                           tiled=True)                      # (chunk, d)
    return out[None, None]


def make_spmm_fn(mesh, part: Partition2D, row_axis: str = "data",
                 col_axis: str = "model"):
    """jitted fn(graph_blocks, x_blocks (pr,pc,chunk,d)) -> y_blocks."""
    body = functools.partial(_spmm_body, part=part,
                             perm=tuple(part.transpose_perm()),
                             row_axis=row_axis, col_axis=col_axis)
    spec = P(row_axis, col_axis)
    mapped = shard_map(
        body, mesh=mesh,
        in_specs=({k: spec for k in ("edge_src", "row_idx", "nnz")}, spec),
        out_specs=spec, check_vma=False)
    return jax.jit(mapped)


def spmm_2d(graph: BlockedGraph, x: np.ndarray, mesh,
            row_axis: str = "data", col_axis: str = "model") -> np.ndarray:
    """Convenience wrapper: x (n_orig, d) -> sum-aggregated (n_orig, d)."""
    part = graph.part
    fn = make_spmm_fn(mesh, part, row_axis, col_axis)
    sh = NamedSharding(mesh, P(row_axis, col_axis))
    g = {k: jax.device_put(np.asarray(getattr(graph, k)), sh)
         for k in ("edge_src", "row_idx", "nnz")}
    xp = np.zeros((part.n, x.shape[1]), x.dtype)
    xp[: part.n_orig] = x
    xb = jax.device_put(
        xp.reshape(part.pr, part.pc, part.chunk, x.shape[1]), sh)
    y = fn(g, xb)
    return np.asarray(y).reshape(part.n, x.shape[1])[: part.n_orig]
