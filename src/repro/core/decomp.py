"""Decomposition registry: the pluggable graph-partitioning axis of the
traversal engine.

PR 2 made *local* discovery pluggable (core/local_ops.py: CSR vs DCSC x
dense vs Pallas kernels); this module does the same for the
*decomposition* — how the adjacency matrix and the vertex vectors are
split over mesh axes.  A ``Decomposition`` entry, registered under the
``BFSConfig.decomposition`` string, declares everything the session API
(core/engine.py) needs to build a search program:

  * ``partition_cls`` / ``graph_cls`` — which partition and blocked
    graph format the entry operates on (plan validation)
  * ``n_axes`` + ``axis_sizes``      — its mesh-axis layout: how many
    mesh axes the graph spans and what size each must have
  * ``make_level_args``              — the LevelArgs builder (statics
    like cap_seg/maxdeg/cap_f threaded from the plan, not ad-hoc kwargs)
  * ``body``                         — the whole-search shard_map body
  * ``validate``                     — entry-specific plan checks

plus in/out PartitionSpec helpers (``graph_spec`` / ``out_specs`` /
``batch_out_specs``) shared by the single-root and pod-batched
programs.  Registered entries:

  "2d" — the paper's checkerboard (§4.4): axes (row, col) = (pr, pc),
         expand = transpose + allgather, fold along the processor row,
         systolic bottom-up rotation.
  "1d" — row strips (Alg. 1/2 baseline): one axis of size p, expand =
         one allgather, no fold/transpose/rotation.

A future 1D-column or 1.5D decomposition is a new entry here (its own
steps module + LevelArgs + body), not an edit to the engine — see the
"adding a decomposition" guide in README.md.

The decomposition-agnostic pieces also live here: ``_search_loop`` (the
level loop + Beamer direction heuristics + COUNTER_KEYS accounting
shared by every entry) and the two registered bodies.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import BFSConfig
from repro.core.partition import Partition1D, Partition2D
from repro.core.steps import (COUNTER_KEYS, LevelArgs, bottomup_level,
                              topdown_level, zero_counters)
from repro.core.steps_1d import (LevelArgs1D, bottomup_level_1d,
                                 topdown_level_1d)
from repro.graph.formats import Blocked1DGraph, BlockedGraph

MAX_LEVELS = 64


@dataclass(frozen=True)
class PlanStatics:
    """Static (compile-time) scalars a plan resolves once from the graph
    and config instead of threading them as per-call kwargs."""
    cap_seg: int = 0          # 2D bottom-up sub-step edge window
    maxdeg: int = 0           # kernel mode: max column-segment length
    cap_f: int = 0            # kernel mode: frontier capacity (0 = nc)
    n_real_edges: float = 0.0  # unpadded edge count (TEPS/metadata)


@dataclass(frozen=True)
class Decomposition:
    """One registered decomposition (see module docstring)."""
    name: str                 # registry key, = BFSConfig.decomposition
    partition_cls: type       # Partition1D | Partition2D
    graph_cls: type           # Blocked1DGraph | BlockedGraph
    n_axes: int               # mesh axes the graph blocks shard over
    axis_sizes: Callable      # (part) -> required mesh-axis sizes
    make_level_args: Callable  # (part, cfg, ops, axes, statics) -> LevelArgs*
    body: Callable            # (g, root, *, part, args, cfg, sync_axis)
    validate: Callable        # (part, statics) -> None (raises on bad plan)

    # ---- PartitionSpec layout (shared by single-root + batch programs) ----

    def graph_spec(self, axes: Tuple[str, ...]) -> P:
        return P(*axes)

    def out_specs(self, axes: Tuple[str, ...]):
        """(parents, level, counters, level_stats) specs."""
        return (P(*axes), P(), {k: P() for k in COUNTER_KEYS}, P())

    def batch_out_specs(self, axes: Tuple[str, ...], pod_axis: str):
        """(parents-per-root, levels) specs for the pod-batched program."""
        return (P(*(axes + (pod_axis, None))), P(pod_axis))


_REGISTRY: Dict[str, Decomposition] = {}


def register_decomposition(entry: Decomposition) -> Decomposition:
    if entry.name in _REGISTRY:
        raise ValueError(f"duplicate decomposition {entry.name!r}")
    _REGISTRY[entry.name] = entry
    return entry


def get_decomposition(name: str) -> Decomposition:
    if name not in _REGISTRY:
        raise ValueError(f"no decomposition registered for {name!r}; "
                         f"have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def registered_decompositions() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# The decomposition-agnostic whole-search level loop
# ---------------------------------------------------------------------------


def _search_loop(g, gidx, root, *, n_total: float, cfg: BFSConfig, axes,
                 sync, td_level, bu_level):
    """Frontier-size / edge-mass direction heuristics, per-level stats,
    counter accumulation.  ``td_level`` / ``bu_level`` are
    (pi, front) -> (pi, front, ctr) step closures over the local graph
    ``g`` (already squeezed)."""
    pi0 = jnp.where(gidx == root, root, jnp.int32(-1))
    front0 = gidx == root
    stats0 = jnp.zeros((MAX_LEVELS, 4), jnp.float32)

    def cond(st):
        pi, front, mode, level, n_f, ctr, stats = st
        return (level < MAX_LEVELS) & (n_f > 0)

    def body(st):
        pi, front, mode, level, n_f, ctr, stats = st
        m_f = lax.psum(jnp.sum(jnp.where(front, g["deg_A"], 0),
                               dtype=jnp.float32), axes)
        m_u = lax.psum(jnp.sum(jnp.where(pi == -1, g["deg_A"], 0),
                               dtype=jnp.float32), axes)
        if cfg.direction_optimizing:
            go_bu = (mode == 0) & (m_f > m_u / cfg.alpha)
            go_td = (mode == 1) & (n_f < n_total / cfg.beta)
            new_mode = jnp.where(go_bu, 1, jnp.where(go_td, 0, mode))
        else:
            new_mode = mode
        stats = stats.at[level].set(
            jnp.stack([n_f, m_f, new_mode.astype(jnp.float32),
                       jnp.float32(1)]))

        pi2, front2, c2 = lax.cond(
            new_mode == 1,
            lambda pf: bu_level(pf[0], pf[1]),
            lambda pf: td_level(pf[0], pf[1]),
            (pi, front))
        ctr = {k: ctr[k] + c2[k] for k in ctr}
        n_f2 = lax.psum(jnp.sum(front2, dtype=jnp.float32), axes)
        # cond feeds on the cross-slice max so batched searches stay in
        # lockstep (heuristics above use the per-slice n_f)
        n_sync = lax.pmax(n_f2, sync) if sync != axes else n_f2
        return (pi2, front2, new_mode, level + 1, n_sync, ctr, stats)

    st = (pi0, front0, jnp.int32(0), jnp.int32(0), jnp.float32(1.0),
          zero_counters(), stats0)
    pi, front, mode, level, n_f, ctr, stats = lax.while_loop(cond, body, st)
    return pi, level, ctr, stats


# ---------------------------------------------------------------------------
# 2D checkerboard entry
# ---------------------------------------------------------------------------


def _bfs_body_2d(g, root, *, part: Partition2D, args: LevelArgs,
                 cfg: BFSConfig, sync_axis: Optional[str] = None):
    """sync_axis: when searches run batched across an outer axis (pods),
    the level loop must take the same trip count on every slice — the
    loop continues while ANY slice has a live frontier (idle slices run
    empty levels; collectives stay aligned)."""
    pc, chunk = part.pc, part.chunk
    axes = (args.row_axis, args.col_axis)
    sync = axes + ((sync_axis,) if sync_axis else ())
    i = lax.axis_index(args.row_axis)
    j = lax.axis_index(args.col_axis)
    g = {k: v[0, 0] for k, v in g.items()}

    gidx = ((i * pc + j) * chunk + jnp.arange(chunk)).astype(jnp.int32)
    pi, level, ctr, stats = _search_loop(
        g, gidx, root, n_total=part.n, cfg=cfg, axes=axes, sync=sync,
        td_level=lambda pi, f: topdown_level(g, pi, f, args),
        bu_level=lambda pi, f: bottomup_level(g, pi, f, args))
    return pi[None, None], level, ctr, stats


def _make_args_2d(part, cfg, ops, axes, statics: PlanStatics) -> LevelArgs:
    row_axis, col_axis = axes
    return LevelArgs(part=part, row_axis=row_axis, col_axis=col_axis,
                     fold_mode=cfg.fold_mode,
                     perm=tuple(part.transpose_perm()),
                     cap_seg=statics.cap_seg,
                     local_mode=ops.local_mode, storage=cfg.storage,
                     cap_f=statics.cap_f, maxdeg=statics.maxdeg,
                     use_edge_dst=cfg.use_edge_dst,
                     compact_updates=cfg.compact_updates, ops=ops)


def _validate_2d(part, statics: PlanStatics) -> None:
    if statics.cap_seg <= 0:
        # the bottom-up branch always compiles (lax.cond), and a zero
        # edge window would silently discover nothing
        raise ValueError("2d decomposition needs cap_seg > 0 "
                         "(pass graph.cap_seg)")


register_decomposition(Decomposition(
    name="2d", partition_cls=Partition2D, graph_cls=BlockedGraph,
    n_axes=2, axis_sizes=lambda part: (part.pr, part.pc),
    make_level_args=_make_args_2d, body=_bfs_body_2d,
    validate=_validate_2d))


# ---------------------------------------------------------------------------
# 1D row-strip entry
# ---------------------------------------------------------------------------


def _bfs_body_1d(g, root, *, part: Partition1D, args: LevelArgs1D,
                 cfg: BFSConfig, sync_axis: Optional[str] = None):
    """1D row-decomposition whole-search body over the single mesh axis."""
    axes = (args.axis,)
    sync = axes + ((sync_axis,) if sync_axis else ())
    i = lax.axis_index(args.axis)
    g = {k: v[0] for k, v in g.items()}

    gidx = (i * part.chunk + jnp.arange(part.chunk)).astype(jnp.int32)
    pi, level, ctr, stats = _search_loop(
        g, gidx, root, n_total=part.n, cfg=cfg, axes=axes, sync=sync,
        td_level=lambda pi, f: topdown_level_1d(g, pi, f, args),
        bu_level=lambda pi, f: bottomup_level_1d(g, pi, f, args))
    return pi[None], level, ctr, stats


def _make_args_1d(part, cfg, ops, axes, statics: PlanStatics) -> LevelArgs1D:
    return LevelArgs1D(part=part, axis=axes[0],
                       use_edge_dst=cfg.use_edge_dst,
                       local_mode=ops.local_mode, storage=cfg.storage,
                       cap_f=statics.cap_f, maxdeg=statics.maxdeg, ops=ops)


register_decomposition(Decomposition(
    name="1d", partition_cls=Partition1D, graph_cls=Blocked1DGraph,
    n_axes=1, axis_sizes=lambda part: (part.p,),
    make_level_args=_make_args_1d, body=_bfs_body_1d,
    validate=lambda part, statics: None))
