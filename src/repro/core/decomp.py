"""Decomposition registry: the pluggable graph-partitioning axis of the
traversal engine.

PR 2 made *local* discovery pluggable (core/local_ops.py: CSR vs DCSC x
dense vs Pallas kernels); this module does the same for the
*decomposition* — how the adjacency matrix and the vertex vectors are
split over mesh axes.  A ``Decomposition`` entry, registered under the
``BFSConfig.decomposition`` string, declares everything the session API
(core/engine.py) needs to build a search program:

  * ``partition_cls`` / ``graph_cls`` — which partition and blocked
    graph format the entry operates on (plan validation)
  * ``n_axes`` + ``axis_sizes``      — its mesh-axis layout: how many
    mesh axes the graph spans and what size each must have
  * ``make_level_args``              — the LevelArgs builder (statics
    like cap_seg/maxdeg/cap_f threaded from the plan, not ad-hoc kwargs)
  * ``body``                         — the whole-search shard_map body
  * ``validate``                     — entry-specific plan checks

plus in/out PartitionSpec helpers (``graph_spec`` / ``out_specs`` /
``batch_out_specs``) shared by the single-root and pod-batched
programs.  Registered entries:

  "2d"  — the paper's checkerboard (§4.4): axes (row, col) = (pr, pc),
          expand = transpose + allgather, fold along the processor row,
          systolic bottom-up rotation.
  "1d"  — row strips (Alg. 1/2 baseline): one axis of size p, expand =
          one dense-bitmap allgather, no fold/transpose/rotation.
  "1ds" — row strips with the SPARSE owner-directed frontier exchange
          (Buluc & Madduri's formulation): expand = fixed-capacity id
          buckets (``PlanStatics.cap_x``) broadcast with one tiled
          allgather, falling back to the dense bitmap when a bucket
          overflows (core/steps_1d_sparse.py).  Same partition/graph/
          LocalOps as "1d" — the registry's first entry added without
          engine edits.

A future 1D-column or 1.5D decomposition is a new entry here (its own
steps module + LevelArgs + body), not an edit to the engine — see the
"adding a decomposition" guide in README.md (rewritten against the
actual "1ds" diff).

The decomposition-agnostic pieces also live here: ``_search_loop`` (the
level loop + Beamer direction heuristics + COUNTER_KEYS accounting
shared by every entry) and the two registered bodies.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import BFSConfig
from repro.core.partition import Partition1D, Partition2D
from repro.core.steps import (COUNTER_KEYS, LevelArgs, bottomup_level,
                              topdown_level, zero_counters)
from repro.core.steps_1d import (LevelArgs1D, bottomup_level_1d,
                                 topdown_level_1d)
from repro.core.steps_1d_sparse import (LevelArgs1DS, bottomup_level_1ds,
                                        topdown_level_1ds)
from repro.graph.formats import Blocked1DGraph, BlockedGraph

MAX_LEVELS = 64


@dataclass(frozen=True)
class PlanStatics:
    """Static (compile-time) scalars a plan resolves once from the graph
    and config instead of threading them as per-call kwargs."""
    cap_seg: int = 0          # 2D bottom-up sub-step edge window
    maxdeg: int = 0           # kernel mode: max column-segment length
    cap_f: int = 0            # kernel mode: frontier capacity (0 = nc)
    cap_x: int = 0            # 1ds sparse exchange: ids per send bucket
    n_real_edges: float = 0.0  # unpadded edge count (TEPS/metadata)
    expand_chunks: int = 1    # software-pipelined expand: 1d/1ds chunk
    #                           their top-down gather into this many
    #                           overlapped steps; 2d pipelines the
    #                           bottom-up ring (core/steps.py R/G split)
    instrument: bool = True   # False: compile counters/level_stats OUT
    #                           of the search program (the latency-lean
    #                           fast path; parents identical)


@dataclass(frozen=True)
class Decomposition:
    """One registered decomposition (see module docstring)."""
    name: str                 # registry key, = BFSConfig.decomposition
    partition_cls: type       # Partition1D | Partition2D
    graph_cls: type           # Blocked1DGraph | BlockedGraph
    n_axes: int               # mesh axes the graph blocks shard over
    axis_sizes: Callable      # (part) -> required mesh-axis sizes
    make_level_args: Callable  # (part, cfg, ops, axes, statics) -> LevelArgs*
    body: Callable            # (g, root, *, part, args, cfg, sync_axis)
    validate: Callable        # (part, statics) -> None (raises on bad plan)

    # ---- SPMD collective contract (checked by repro.analysis) -------------
    #
    # ``rendezvous_axes(axes, mesh_axes)`` declares the mesh axes this
    # entry's level schedule rendezvouses on: the axes every cond/while
    # predicate guarding one of its collectives must be provably uniform
    # over before divergent slices are safe.  Strip entries (1d/1ds) are
    # group-local — their all_gathers/all_to_alls lower with
    # replica_groups along the strip axis, so per-pod-divergent td/bu
    # decisions are safe and they declare just ``axes``.  The 2d entry
    # ppermutes (transpose / ring fold / systolic rotation), and XLA
    # lowers collective-permute as a single whole-program rendezvous
    # regardless of source_target_pairs — so it declares the WHOLE mesh
    # (pod axis included): a pod taking the other branch would wait on a
    # permute its peers never issue (the PR 4 deadlock class).  The
    # default (None) is the conservative whole-mesh claim.  The linter
    # does not *trust* this: it recomputes per-op rendezvous from the
    # jaxpr (rule R1) and flags entries whose declaration under-claims
    # what their program actually issues (rule R3).
    rendezvous_axes: Optional[Callable] = None
    # ``schedule_dims`` lists the BFSConfig fields that change this
    # entry's per-level collective schedule; the analyzer's R4 rule (and
    # tests/test_perf_guard.py through it) enumerates their cross
    # product against ``comm_model.level_collective_budget`` instead of
    # keeping a hand-written case table — a new entry registers its dims
    # and is budget-checked automatically.
    schedule_dims: Tuple[str, ...] = ("expand_chunks",)
    # ``level_steps`` = (topdown, bottomup) per-level step functions
    # (signature ``step(g, pi, front, args, lv)``), the same closures
    # ``body`` drives through _search_loop — exposed so the analyzer can
    # lower ONE level body in isolation for the R4 budget check.
    level_steps: Optional[Tuple[Callable, Callable]] = None

    # ---- edge-membership hook (Graph500 parent-tree validator) ------------
    #
    # ``local_edges(g, part, axes) -> (u, v, valid)`` enumerates this
    # shard's edge slots in GLOBAL layout-A vertex ids: ``u[k] -> v[k]``
    # is a directed edge stored locally iff ``valid[k]``; padded
    # capacity slots must still yield in-range (u, v) so downstream
    # gathers stay safe.  ``edge_keys`` names the graph device-array
    # fields the hook reads, so the validator ships only those to the
    # mesh.  Entries without a hook (None) cannot be validated
    # device-side — ``core/validate.py`` raises a clear error for them.
    edge_keys: Tuple[str, ...] = ()
    local_edges: Optional[Callable] = None

    # ---- PartitionSpec layout (shared by single-root + batch programs) ----

    def graph_spec(self, axes: Tuple[str, ...]) -> P:
        return P(*axes)

    def out_specs(self, axes: Tuple[str, ...], instrument: bool = True):
        """(parents, level, counters, level_stats) specs.  The fast path
        carries NO counters at all ({} — matching _search_loop_fast):
        uninstrumented runs must not emit zero-valued counters that read
        as measurements in aggregates mixing modes."""
        ctr = {k: P() for k in COUNTER_KEYS} if instrument else {}
        return (P(*axes), P(), ctr, P())

    def batch_out_specs(self, axes: Tuple[str, ...], pod_axis: str):
        """(parents-per-root, levels, level_stats-per-root) specs for the
        pod-batched program."""
        return (P(*(axes + (pod_axis, None))), P(pod_axis), P(pod_axis))


_REGISTRY: Dict[str, Decomposition] = {}


def register_decomposition(entry: Decomposition) -> Decomposition:
    if entry.name in _REGISTRY:
        raise ValueError(f"duplicate decomposition {entry.name!r}")
    _REGISTRY[entry.name] = entry
    return entry


def get_decomposition(name: str) -> Decomposition:
    if name not in _REGISTRY:
        raise ValueError(f"no decomposition registered for {name!r}; "
                         f"have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def registered_decompositions() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def unregister_decomposition(name: str) -> None:
    """Remove an entry — for scoped test/fixture registrations only
    (repro.analysis.fixtures registers a deliberately-broken entry,
    lints it, and must leave the registry exactly as it found it)."""
    if name not in _REGISTRY:
        raise ValueError(f"no decomposition registered for {name!r}")
    del _REGISTRY[name]


# ---------------------------------------------------------------------------
# The decomposition-agnostic whole-search level loop
# ---------------------------------------------------------------------------


def _search_loop(g, gidx, root, *, n_total: float, cfg: BFSConfig, axes,
                 sync, td_level, bu_level, sync_modes: bool = False,
                 over_cap: int = 0, expand_chunks: int = 1):
    """Frontier-size / edge-mass direction heuristics, per-level stats,
    counter accumulation.  ``td_level`` / ``bu_level`` are
    (pi, front, lv=None) -> (pi, front, ctr) step closures over the
    local graph ``g`` (already squeezed); ``lv`` is the fast-path
    per-level context (see ``_search_loop_fast``).

    The loop state carries TWO frontier sizes: the per-slice ``n_f``
    (this search's own frontier — what the direction heuristics and the
    level stats must read) and the cross-slice ``n_sync`` (the pmax over
    the sync axes that keeps pod-batched searches in lockstep — what the
    loop predicate reads).  Conflating them made every batched search
    switch modes on the LARGEST pod's frontier instead of its own.

    ``sync_modes``: a step body whose collectives span the WHOLE mesh
    (2D: the ppermute transpose / ring fold / systolic rotation
    rendezvous with every device) cannot let pod slices take different
    td/bu branches — divergent slices would wait on different collective
    ops forever.  Such entries set sync_modes=True and the *decision* is
    made uniform over ``sync``: any slice wanting bottom-up switches all
    of them, and top-down resumes only when every slice wants it.
    Entries whose collectives are group-local per slice (1d/1ds:
    all_gather / all_to_all along the strip axis only) keep sync_modes
    False and genuinely switch per slice.

    ``over_cap``: the "1ds" sparse-exchange bucket capacity; when > 0
    the fast path carries the per-processor overflow indicator in its
    fused reduction so the exchange step needs no predicate collective.
    With ``expand_chunks`` > 1 the chunked exchange sends per-sub-range
    buckets of capacity over_cap/expand_chunks, so the indicator tests
    the per-sub-range counts instead of the whole-strip count.

    With ``cfg.instrument`` False the loop dispatches to
    ``_search_loop_fast``: one fused vector psum per level (plus one
    fused pmax when pod-batched) instead of the 6–11 scalar all-reduces
    the instrumented program spends on counters and stats."""
    pi0 = jnp.where(gidx == root, root, jnp.int32(-1))
    front0 = gidx == root
    if not cfg.instrument:
        return _search_loop_fast(
            g, pi0, front0, n_total=n_total, cfg=cfg, axes=axes, sync=sync,
            td_level=td_level, bu_level=bu_level, sync_modes=sync_modes,
            over_cap=over_cap, expand_chunks=expand_chunks)
    stats0 = jnp.zeros((MAX_LEVELS, 5), jnp.float32)

    def cond(st):
        pi, front, mode, level, n_f, n_sync, ctr, stats = st
        return (level < MAX_LEVELS) & (n_sync > 0)

    def body(st):
        pi, front, mode, level, n_f, n_sync, ctr, stats = st
        m_f = lax.psum(jnp.sum(jnp.where(front, g["deg_A"], 0),
                               dtype=jnp.float32), axes)
        m_u = lax.psum(jnp.sum(jnp.where(pi == -1, g["deg_A"], 0),
                               dtype=jnp.float32), axes)
        if cfg.direction_optimizing:
            # per-slice n_f: each batched search switches on its OWN
            # frontier size, never a lockstep partner's
            go_bu = (mode == 0) & (m_f > m_u / cfg.alpha)
            go_td = (mode == 1) & (n_f < n_total / cfg.beta)
            if sync_modes and sync != axes:
                go_bu = lax.pmax(go_bu.astype(jnp.int32), sync) > 0
                go_td = lax.pmin(go_td.astype(jnp.int32), sync) > 0
            new_mode = jnp.where(go_bu, 1, jnp.where(go_td, 0, mode))
        else:
            new_mode = mode

        pi2, front2, c2 = lax.cond(
            new_mode == 1,
            lambda pf: bu_level(pf[0], pf[1]),
            lambda pf: td_level(pf[0], pf[1]),
            (pi, front))
        ctr = {k: ctr[k] + c2[k] for k in ctr}
        # stats row: n_f, m_f, mode, used, measured expand words this
        # level (the dense-vs-sparse crossover is read off column 4)
        stats = stats.at[level].set(
            jnp.stack([n_f, m_f, new_mode.astype(jnp.float32),
                       jnp.float32(1), c2["wire_expand"]]))
        n_f2 = lax.psum(jnp.sum(front2, dtype=jnp.float32), axes)
        # the predicate feeds on the cross-slice max so batched searches
        # stay in lockstep; heuristics keep the per-slice n_f2
        n_sync2 = lax.pmax(n_f2, sync) if sync != axes else n_f2
        return (pi2, front2, new_mode, level + 1, n_f2, n_sync2, ctr, stats)

    st = (pi0, front0, jnp.int32(0), jnp.int32(0), jnp.float32(1.0),
          jnp.float32(1.0), zero_counters(), stats0)
    pi, front, mode, level, n_f, n_sync, ctr, stats = lax.while_loop(
        cond, body, st)
    return pi, level, ctr, stats


def _search_loop_fast(g, pi0, front0, *, n_total: float, cfg: BFSConfig,
                      axes, sync, td_level, bu_level, sync_modes: bool,
                      over_cap: int, expand_chunks: int = 1):
    """The ``instrument=False`` level loop: the whole-search program
    spends exactly ONE fused vector psum per level — frontier size,
    frontier edge mass, unvisited edge mass, and (for the "1ds" hybrid)
    the bucket-overflow indicator, stacked and reduced together — plus
    one fused vector pmax when searches are pod-batched (lockstep
    ``n_sync`` and, for sync_modes entries, the direction decision).

    The direction heuristics read the PREVIOUS level's fused reduction:
    the decision for level L+1 is computed at the tail of level L from
    the post-level (pi, front) — the same values the instrumented loop
    recomputes with separate psums at the top of L+1 — so the mode
    sequence and the parents are bit-identical to the instrumented
    program.  Counters and level_stats are compiled out; the returned
    ctr is EMPTY (a fast run has no measurements — zeros here would
    masquerade as measured wire volumes downstream) and stats are
    constant zeros."""
    deg = g["deg_A"]

    def reduce_state(pi, front):
        """(n_f, m_f, m_u, over) from one stacked psum over the slice."""
        n_loc = jnp.sum(front, dtype=jnp.float32)
        if over_cap and expand_chunks > 1:
            # chunked exchange: each of the expand_chunks contiguous
            # sub-ranges gets its own over_cap/expand_chunks bucket, so
            # ANY sub-range overflowing forces the dense fallback
            cnts = jnp.sum(front.reshape(expand_chunks, -1), axis=1,
                           dtype=jnp.float32)
            over_loc = (jnp.max(cnts)
                        > (over_cap // expand_chunks)).astype(jnp.float32)
        elif over_cap:
            over_loc = (n_loc > over_cap).astype(jnp.float32)
        else:
            over_loc = jnp.float32(0)
        red = lax.psum(jnp.stack([
            n_loc,
            jnp.sum(jnp.where(front, deg, 0), dtype=jnp.float32),
            jnp.sum(jnp.where(pi == -1, deg, 0), dtype=jnp.float32),
            over_loc]), axes)
        return red[0], red[1], red[2], red[3] > 0

    def decide_and_sync(mode, n_f, m_f, m_u):
        """Next level's direction decision + the lockstep pmax, fused:
        pmin(go_td) rides the same pmax as 1 - go_td."""
        go_bu = (mode == 0) & (m_f > m_u / cfg.alpha)
        go_td = (mode == 1) & (n_f < n_total / cfg.beta)
        if sync == axes:
            return n_f, go_bu, go_td
        if sync_modes and cfg.direction_optimizing:
            pm = lax.pmax(jnp.stack([
                n_f, go_bu.astype(jnp.float32),
                1.0 - go_td.astype(jnp.float32)]), sync)
            return pm[0], pm[1] > 0, pm[2] < 1
        return lax.pmax(n_f, sync), go_bu, go_td

    n_f0, m_f0, m_u0, ov0 = reduce_state(pi0, front0)
    n_sync0, gb0, gt0 = decide_and_sync(jnp.int32(0), n_f0, m_f0, m_u0)

    def cond(st):
        pi, front, mode, level, n_sync, gb, gt, ov = st
        return (level < MAX_LEVELS) & (n_sync > 0)

    def body(st):
        pi, front, mode, level, n_sync, gb, gt, ov = st
        if cfg.direction_optimizing:
            new_mode = jnp.where(gb, 1, jnp.where(gt, 0, mode))
        else:
            new_mode = mode
        pi2, front2, _ = lax.cond(
            new_mode == 1,
            lambda op: bu_level(op[0], op[1], {"over": op[2]}),
            lambda op: td_level(op[0], op[1], {"over": op[2]}),
            (pi, front, ov))
        n_f2, m_f2, m_u2, ov2 = reduce_state(pi2, front2)
        n_sync2, gb2, gt2 = decide_and_sync(new_mode, n_f2, m_f2, m_u2)
        return (pi2, front2, new_mode, level + 1, n_sync2, gb2, gt2, ov2)

    st = (pi0, front0, jnp.int32(0), jnp.int32(0), n_sync0, gb0, gt0, ov0)
    pi, front, mode, level, n_sync, gb, gt, ov = lax.while_loop(
        cond, body, st)
    return pi, level, {}, jnp.zeros((MAX_LEVELS, 5), jnp.float32)


# ---------------------------------------------------------------------------
# 2D checkerboard entry
# ---------------------------------------------------------------------------


def _bfs_body_2d(g, root, *, part: Partition2D, args: LevelArgs,
                 cfg: BFSConfig, sync_axis: Optional[str] = None):
    """sync_axis: when searches run batched across an outer axis (pods),
    the level loop must take the same trip count on every slice — the
    loop continues while ANY slice has a live frontier (idle slices run
    empty levels; collectives stay aligned)."""
    pc, chunk = part.pc, part.chunk
    axes = (args.row_axis, args.col_axis)
    sync = axes + ((sync_axis,) if sync_axis else ())
    i = lax.axis_index(args.row_axis)
    j = lax.axis_index(args.col_axis)
    g = {k: v[0, 0] for k, v in g.items()}

    gidx = ((i * pc + j) * chunk + jnp.arange(chunk)).astype(jnp.int32)
    pi, level, ctr, stats = _search_loop(
        g, gidx, root, n_total=part.n, cfg=cfg, axes=axes, sync=sync,
        td_level=lambda pi, f, lv=None: topdown_level(g, pi, f, args, lv),
        bu_level=lambda pi, f, lv=None: bottomup_level(g, pi, f, args, lv),
        # 2D steps ppermute (transpose / ring fold / rotation): the
        # whole mesh must take one td/bu branch per level
        sync_modes=True)
    return pi[None, None], level, ctr, stats


def _make_args_2d(part, cfg, ops, axes, statics: PlanStatics) -> LevelArgs:
    row_axis, col_axis = axes
    return LevelArgs(part=part, row_axis=row_axis, col_axis=col_axis,
                     fold_mode=cfg.fold_mode,
                     perm=tuple(part.transpose_perm()),
                     cap_seg=statics.cap_seg,
                     local_mode=ops.local_mode, storage=cfg.storage,
                     cap_f=statics.cap_f, maxdeg=statics.maxdeg,
                     use_edge_dst=cfg.use_edge_dst,
                     compact_updates=cfg.compact_updates, ops=ops,
                     instrument=statics.instrument,
                     expand_chunks=statics.expand_chunks)


def _validate_2d(part, statics: PlanStatics) -> None:
    if statics.cap_seg <= 0:
        # the bottom-up branch always compiles (lax.cond), and a zero
        # edge window would silently discover nothing
        raise ValueError("2d decomposition needs cap_seg > 0 "
                         "(pass graph.cap_seg)")


def _local_edges_2d(g, part, axes):
    """(u, v, valid) for one (i, j) block in global layout-A ids: CSC
    ``edge_src`` is the block-local source (column j owns sources
    [j*nc, (j+1)*nc)), ``row_idx`` the block-local dest (row i owns
    dests [i*nr, (i+1)*nr)); padded slots hold 0 so the rebased ids
    stay in range."""
    i = lax.axis_index(axes[0])
    j = lax.axis_index(axes[1])
    u = (j * part.nc + g["edge_src"]).astype(jnp.int32)
    v = (i * part.nr + g["row_idx"]).astype(jnp.int32)
    valid = jnp.arange(u.shape[0], dtype=jnp.int32) < g["nnz"]
    return u, v, valid


def _local_edges_1d(g, part, axes):
    """(u, v, valid) for one strip: CSR ``col_idx`` is already the
    GLOBAL source id, ``edge_dst`` the strip-local dest (strip i owns
    [i*chunk, (i+1)*chunk)); padded slots hold 0."""
    i = lax.axis_index(axes[0])
    u = g["col_idx"].astype(jnp.int32)
    v = (i * part.chunk + g["edge_dst"]).astype(jnp.int32)
    valid = jnp.arange(u.shape[0], dtype=jnp.int32) < g["nnz"]
    return u, v, valid


register_decomposition(Decomposition(
    name="2d", partition_cls=Partition2D, graph_cls=BlockedGraph,
    n_axes=2, axis_sizes=lambda part: (part.pr, part.pc),
    make_level_args=_make_args_2d, body=_bfs_body_2d,
    validate=_validate_2d,
    # ppermutes rendezvous with EVERY device (whole-mesh XLA
    # collective-permute) — hence sync_modes=True above
    rendezvous_axes=lambda axes, mesh_axes: tuple(mesh_axes),
    schedule_dims=("fold_mode", "compact_updates", "expand_chunks"),
    level_steps=(topdown_level, bottomup_level),
    edge_keys=("edge_src", "row_idx", "nnz"),
    local_edges=_local_edges_2d))


# ---------------------------------------------------------------------------
# 1D row-strip entries ("1d" dense expand, "1ds" sparse expand)
# ---------------------------------------------------------------------------


def _make_strip_body(td_step, bu_step):
    """Whole-search body over a single strip axis, shared by every 1D
    entry: squeeze the strip arrays, build global vertex ids, run the
    shared search loop with the given per-level step closures.  A new
    strip-family decomposition supplies its two steps here instead of
    copy-pasting the body (their collectives are group-local along the
    strip axis, so per-slice direction switching is safe —
    sync_modes stays False)."""

    def body(g, root, *, part: Partition1D, args, cfg: BFSConfig,
             sync_axis: Optional[str] = None):
        axes = (args.axis,)
        sync = axes + ((sync_axis,) if sync_axis else ())
        i = lax.axis_index(args.axis)
        g = {k: v[0] for k, v in g.items()}

        gidx = (i * part.chunk + jnp.arange(part.chunk)).astype(jnp.int32)
        pi, level, ctr, stats = _search_loop(
            g, gidx, root, n_total=part.n, cfg=cfg, axes=axes, sync=sync,
            td_level=lambda pi, f, lv=None: td_step(g, pi, f, args, lv),
            bu_level=lambda pi, f, lv=None: bu_step(g, pi, f, args, lv),
            # "1ds": the fast path carries the bucket-overflow indicator
            # in its fused reduction (0 disables it for plain "1d");
            # expand_chunks switches it to per-sub-range bucket counts
            over_cap=getattr(args, "cap_x", 0),
            expand_chunks=getattr(args, "expand_chunks", 1))
        return pi[None], level, ctr, stats

    return body


_bfs_body_1d = _make_strip_body(topdown_level_1d, bottomup_level_1d)


def _make_args_1d(part, cfg, ops, axes, statics: PlanStatics) -> LevelArgs1D:
    return LevelArgs1D(part=part, axis=axes[0],
                       use_edge_dst=cfg.use_edge_dst,
                       local_mode=ops.local_mode, storage=cfg.storage,
                       cap_f=statics.cap_f, maxdeg=statics.maxdeg, ops=ops,
                       instrument=statics.instrument,
                       expand_chunks=statics.expand_chunks)


def _validate_strip_chunks(part, statics: PlanStatics) -> None:
    """Shared 1d/1ds check: the chunked expand splits the owner's packed
    bitmap words (chunk/32 of them) into expand_chunks equal sub-chunks,
    so the word count must divide evenly — a ragged last sub-chunk would
    silently mis-align the owner-major gather layout."""
    c = statics.expand_chunks
    words = part.chunk // 32
    if c > 1 and words % c != 0:
        raise ValueError(
            f"expand_chunks={c} does not divide the per-device strip's "
            f"packed word count ({words} = chunk {part.chunk} / 32); "
            f"pick a divisor of {words}")


def _validate_1d(part, statics: PlanStatics) -> None:
    _validate_strip_chunks(part, statics)


register_decomposition(Decomposition(
    name="1d", partition_cls=Partition1D, graph_cls=Blocked1DGraph,
    n_axes=1, axis_sizes=lambda part: (part.p,),
    make_level_args=_make_args_1d, body=_bfs_body_1d,
    validate=_validate_1d,
    # group-local along the strip axis: per-slice direction switching
    # is safe, so pods never enter the rendezvous
    rendezvous_axes=lambda axes, mesh_axes: tuple(axes),
    schedule_dims=("expand_chunks",),
    level_steps=(topdown_level_1d, bottomup_level_1d),
    edge_keys=("col_idx", "edge_dst", "nnz"),
    local_edges=_local_edges_1d))


# ---------------------------------------------------------------------------
# 1D sparse-exchange entry ("1ds"): same strips, owner-directed expand
# ---------------------------------------------------------------------------

_bfs_body_1ds = _make_strip_body(topdown_level_1ds, bottomup_level_1ds)


def _make_args_1ds(part, cfg, ops, axes,
                   statics: PlanStatics) -> LevelArgs1DS:
    return LevelArgs1DS(part=part, axis=axes[0], cap_x=statics.cap_x,
                        use_edge_dst=cfg.use_edge_dst,
                        local_mode=ops.local_mode, storage=cfg.storage,
                        cap_f=statics.cap_f, maxdeg=statics.maxdeg, ops=ops,
                        instrument=statics.instrument,
                        codec=cfg.frontier_codec,
                        expand_chunks=statics.expand_chunks)


def _validate_1ds(part, statics: PlanStatics) -> None:
    if statics.cap_x <= 0:
        # zero-capacity buckets would force the dense fallback on every
        # level — the caller asked for the sparse exchange and got "1d"
        raise ValueError(
            "1ds decomposition needs cap_x > 0 (plan_bfs derives it from "
            "the graph via comm_model.plan_cap_x; graph-less plans must "
            "pass cap_x explicitly)")
    if statics.cap_x > part.chunk:
        raise ValueError(
            f"cap_x={statics.cap_x} exceeds the owned chunk "
            f"({part.chunk}) — a bucket can never hold more frontier "
            f"ids than a processor owns")
    _validate_strip_chunks(part, statics)
    c = statics.expand_chunks
    if c > 1 and statics.cap_x % c != 0:
        raise ValueError(
            f"expand_chunks={c} does not divide cap_x={statics.cap_x}; "
            f"the chunked sparse exchange splits the send bucket into "
            f"expand_chunks equal sub-buckets")


register_decomposition(Decomposition(
    name="1ds", partition_cls=Partition1D, graph_cls=Blocked1DGraph,
    n_axes=1, axis_sizes=lambda part: (part.p,),
    make_level_args=_make_args_1ds, body=_bfs_body_1ds,
    validate=_validate_1ds,
    rendezvous_axes=lambda axes, mesh_axes: tuple(axes),
    schedule_dims=("frontier_codec", "expand_chunks"),
    level_steps=(topdown_level_1ds, bottomup_level_1ds),
    edge_keys=("col_idx", "edge_dst", "nnz"),
    local_edges=_local_edges_1d))
