"""Deterministic fault injection for the robustness layer.

Every injector is keyed by an integer seed: the exact fault schedule —
which vertex's parent gets bit-flipped, which store shard byte gets
corrupted, how small a capacity gets squeezed — is a pure function of
(seed, graph), so CI replays the identical faults on every run and a
failure is reproducible from its seed alone.

Four fault families, matching what the robustness stack must catch:

* **parent-array corruption** (``inject_parents``): bit-flipped
  parents, phantom (non-edge) parents, off-by-one level skews, orphaned
  reachable vertices, dropped sub-bucket ranges.  Each injector
  GUARANTEES the mutated array is invalid (it searches seeded candidate
  order for a mutation the Graph500 conditions reject, consulting the
  host oracle's edge set + true depths) — so "validator flags 100% of
  injected corruption" is a meaningful kill matrix, not luck.
* **store corruption** (``corrupt_shard``): flip a byte or truncate a
  GraphStore shard file; the store's CRC check must quarantine +
  regenerate it.
* **undersized capacities** (``undersize_cap``): squeeze cap_x /
  route_slack so the replan-retry escalation paths exercise.
* the CLI (``python -m repro.runtime.faultinject``) replays the full
  seeded matrix on forced host devices and writes a JSON report — the
  CI ``faults`` lane artifact (mirrors analysis/lint.py's lane).

Injectors never import the engine; they mutate host arrays/files only.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

PARENT_FAULTS = ("flip_bit", "phantom_parent", "level_skew",
                 "orphan_leaf", "drop_subrange")


class InjectionError(RuntimeError):
    """The graph admits no invalid mutation of the requested class
    (degenerate inputs — e.g. a star graph has no same-level edges)."""


# ---------------------------------------------------------------------------
# parent-array injectors
# ---------------------------------------------------------------------------


class _Oracle:
    """Host adjacency + true-depth context the injectors consult to
    guarantee their mutation violates a Graph500 condition."""

    def __init__(self, n: int, src, dst, root: int, parents):
        from repro.core import ref
        self.n = int(n)
        self.root = int(root)
        self.parents = np.asarray(parents).astype(np.int64)
        self.depth = ref.bfs_depths(n, src, dst, root)
        self.adj = set(zip(np.asarray(src).tolist(),
                           np.asarray(dst).tolist()))
        self.src, self.dst = np.asarray(src), np.asarray(dst)
        self.in_tree = np.nonzero(self.parents >= 0)[0]

    def is_edge(self, u: int, v: int) -> bool:
        return (u, v) in self.adj or (v, u) in self.adj

    def valid_parent(self, v: int, p: int) -> bool:
        """Would ``parent[v] = p`` still satisfy every per-vertex
        Graph500 condition?  (Any true-BFS parent is acceptable — the
        spec admits every valid tree, not one canonical tree.)"""
        if v == self.root:
            return p == self.root
        if p < 0 or p >= self.n:
            return False
        return self.is_edge(p, v) and self.depth[p] == self.depth[v] - 1


def inject_parents(kind: str, parents, root: int, seed: int, *, n: int,
                   src, dst, chunk: Optional[int] = None,
                   expand_chunks: int = 1
                   ) -> Tuple[np.ndarray, Dict]:
    """Return (mutated_parents, info) for one seeded parent fault.

    ``parents`` is a correct (n_orig,) parent array from a real run;
    the mutation is guaranteed invalid (see module docstring).
    ``chunk``/``expand_chunks`` parameterize ``drop_subrange`` — the
    1ds sub-bucket geometry whose loss the fault simulates."""
    if kind not in PARENT_FAULTS:
        raise ValueError(f"unknown parent fault {kind!r}; "
                         f"have {PARENT_FAULTS}")
    rng = np.random.default_rng(seed)
    out = np.asarray(parents).astype(np.int64).copy()
    orc = _Oracle(n, src, dst, root, out)
    cands = [int(v) for v in orc.in_tree if v != root]
    if not cands:
        raise InjectionError("tree has no non-root vertices to corrupt")
    rng.shuffle(cands)

    if kind == "flip_bit":
        bits = list(range(33))          # value bits 0..31 + sign bit 32
        for v in cands:
            order = rng.permutation(bits)
            for b in order:
                newp = int(out[v]) ^ (1 << int(b)) if b < 32 \
                    else -int(out[v]) - 1           # flip two's-compl sign
                if newp != out[v] and not orc.valid_parent(v, newp):
                    info = {"kind": kind, "vertex": v, "bit": int(b),
                            "old": int(out[v]), "new": int(newp)}
                    out[v] = newp
                    return out, info
        raise InjectionError("no invalidating bit flip found")

    if kind == "phantom_parent":
        intree = set(cands) | {root}
        for v in cands:
            pool = rng.permutation(list(intree - {v}))
            for u in pool[:256]:
                u = int(u)
                if not orc.is_edge(u, v):
                    info = {"kind": kind, "vertex": v,
                            "old": int(out[v]), "new": u}
                    out[v] = u
                    return out, info
        raise InjectionError("no non-adjacent in-tree pair found")

    if kind == "level_skew":
        # a REAL edge whose endpoints sit on the same level (or worse):
        # the tree edge exists and anchors, only the level arithmetic
        # breaks — the subtlest class, invisible to every check except
        # the +-1 level condition
        depth = orc.depth
        for want_gap in (0, 1):          # same level, then child-as-parent
            for v in cands:
                nbrs = np.concatenate([orc.dst[orc.src == v],
                                       orc.src[orc.dst == v]])
                nbrs = rng.permutation(np.unique(nbrs))
                for w in nbrs:
                    w = int(w)
                    if w == out[v] or w == v or out[w] < 0:
                        continue
                    if depth[w] == depth[v] + want_gap:
                        info = {"kind": kind, "vertex": v,
                                "old": int(out[v]), "new": w,
                                "gap": int(want_gap)}
                        out[v] = w
                        return out, info
        raise InjectionError("no same-level edge found")

    if kind == "orphan_leaf":
        is_parent = set(out[out >= 0].tolist())
        for v in cands:
            if v not in is_parent:
                info = {"kind": kind, "vertex": v, "old": int(out[v])}
                out[v] = -1
                return out, info
        raise InjectionError("tree has no leaf")

    # drop_subrange: lose one 1ds sub-bucket — a contiguous [k*chunk +
    # s*sub, +sub) slice of discovered vertices reads as never-arrived
    if chunk is None:
        raise ValueError("drop_subrange needs the strip chunk size")
    sub = max(1, chunk // max(1, expand_chunks))
    n_orig = out.shape[0]
    starts = [s for s in range(0, n_orig, sub)]
    rng.shuffle(starts)
    for s in starts:
        sel = np.zeros(n_orig, bool)
        sel[s: s + sub] = True
        sel &= (out >= 0) & (np.arange(n_orig) != root)
        if sel.any():
            info = {"kind": kind, "start": int(s), "sub": int(sub),
                    "dropped": int(sel.sum())}
            out[sel] = -1
            return out, info
    raise InjectionError("no sub-range holds in-tree vertices")


# ---------------------------------------------------------------------------
# store + capacity injectors
# ---------------------------------------------------------------------------


def corrupt_shard(store, name: str, seed: int, mode: str = "flip",
                  shard: Optional[int] = None,
                  step: Optional[int] = None) -> str:
    """Corrupt one shard file of a stored graph in place (seeded shard
    + byte choice).  ``mode``: "flip" XORs one payload byte,
    "truncate" cuts the file to a seeded fraction.  Returns the path."""
    from repro.ckpt import checkpoint
    rng = np.random.default_rng(seed)
    gdir = os.path.join(store.root, "graphs", name)
    if step is None:
        step = checkpoint.latest_step(gdir)
        if step is None:
            raise FileNotFoundError(f"no graph steps under {gdir}")
    shards = sorted(glob.glob(os.path.join(
        gdir, f"step_{step:010d}", "shard_*.npz")))
    if not shards:
        raise FileNotFoundError(f"no shard files under {gdir}")
    path = shards[int(rng.integers(len(shards))) if shard is None
                  else shard]
    with open(path, "rb") as f:
        data = bytearray(f.read())
    if mode == "flip":
        pos = int(rng.integers(len(data) // 2, len(data)))
        data[pos] ^= int(rng.integers(1, 256))
        payload = bytes(data)
    elif mode == "truncate":
        cut = int(len(data) * float(rng.uniform(0.2, 0.7)))
        payload = bytes(data[:cut])
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    with open(path, "wb") as f:
        f.write(payload)
    return path


def undersize_cap(cap: int, seed: int, align: int = 32) -> int:
    """A seeded, deliberately-too-small capacity: cap / 2^k (k in 2..4),
    floored to ``align`` — small enough to overflow realistic runs,
    aligned enough to plan."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, 5))
    return max(align, (cap >> k) // align * align)


def undersize_route_slack(seed: int) -> float:
    """A seeded route_slack in [0.2, 0.45) — overflows R-MAT skew at
    small p, heals within <=3 doublings."""
    rng = np.random.default_rng(seed)
    return float(rng.uniform(0.2, 0.45))


# ---------------------------------------------------------------------------
# the seeded fault matrix (CLI + CI lane)
# ---------------------------------------------------------------------------


def _grid_for(devices: int) -> Tuple[int, int]:
    grids = {1: (1, 1), 2: (1, 2), 4: (2, 2), 8: (2, 4), 16: (4, 4)}
    if devices not in grids:
        raise ValueError(f"fault matrix supports devices in "
                         f"{sorted(grids)}, got {devices}")
    return grids[devices]


def run_fault_matrix(seed: int = 0, scale: int = 8, edge_factor: int = 8,
                     devices: int = 1) -> Dict:
    """Replay the whole seeded fault schedule and report per-case
    verdicts.  Covers: clean-run validation per decomposition, the
    parent-fault kill matrix, cap_x + route_slack healing (parents /
    arrays bit-identical to unfaulted runs), and store shard
    corruption -> quarantine + regeneration."""
    import tempfile

    import jax

    from repro.ckpt.graph_store import GraphStore
    from repro.configs.base import BFSConfig
    from repro.core import validate as V
    from repro.core.engine import plan_bfs, run_bfs_healed
    from repro.graph.dist_build import BuildSpec, dist_build
    from repro.graph.rmat import rmat_graph
    from repro.launch.mesh import make_local_mesh, make_local_mesh_1d

    if len(jax.devices()) < devices:
        raise RuntimeError(f"need {devices} devices, have "
                           f"{len(jax.devices())}")
    pr, pc = _grid_for(devices)
    spec = BuildSpec(scale=scale, edge_factor=edge_factor, seed=3)
    edges = rmat_graph(scale, edge_factor, seed=3, generator="counter")
    mesh1 = make_local_mesh_1d(devices)
    mesh2 = make_local_mesh(pr, pc)
    root = 5
    cases: List[Dict] = []

    def case(name: str, fn):
        try:
            detail = fn() or {}
            cases.append({"name": name, "ok": True, "detail": detail})
        except Exception as e:                # noqa: BLE001 — report it
            cases.append({"name": name, "ok": False,
                          "detail": {"error": f"{type(e).__name__}: {e}"}})

    engines = {}
    results = {}
    for decomp in ("1d", "1ds", "2d"):
        mesh = mesh2 if decomp == "2d" else mesh1
        grid = (pr, pc) if decomp == "2d" else devices
        graph, _ = dist_build(spec, decomp, mesh, grid, align=32,
                              cap_pad=32)
        cfg = BFSConfig(decomposition=decomp, instrument=False)
        eng = plan_bfs(graph, cfg, mesh).compile()
        engines[decomp] = eng

        def clean(eng=eng):
            res = eng.run(root, validate=True)
            results[eng.plan.cfg.decomposition] = res
            return res.validation.to_json()
        case(f"clean/{decomp}", clean)

        for kind in PARENT_FAULTS:
            def kill(eng=eng, kind=kind, decomp=decomp):
                res = results[decomp]
                bad, info = inject_parents(
                    kind, res.parents, root, seed, n=edges.n,
                    src=edges.src, dst=edges.dst,
                    chunk=eng.plan.part.chunk)
                rep = V.validate_parents(eng, root, bad)
                if rep.ok:
                    raise AssertionError(
                        f"validator MISSED injected {kind}: {info}")
                return {"fault": info,
                        "violations": rep.violations}
            case(f"kill/{decomp}/{kind}", kill)

    def heal_cap_x():
        cfg = BFSConfig(decomposition="1ds", instrument=True,
                        direction_optimizing=False)
        base = engines["1ds"].plan
        good = plan_bfs(base.graph, cfg, mesh1).compile().run(root)
        squeezed = undersize_cap(base.part.chunk, seed)
        h = run_bfs_healed(base.graph, cfg, mesh1, root,
                           cap_x=squeezed, validate=True)
        if not np.array_equal(h.result.parents, good.parents):
            raise AssertionError("healed parents differ from unfaulted")
        return {"cap_x0": squeezed, "retry_log": h.retry_log}
    case("heal/cap_x", heal_cap_x)

    def heal_route():
        slack = undersize_route_slack(seed)
        g, info = dist_build(spec, "1ds", mesh1, devices, align=32,
                             cap_pad=32, route_slack=slack)
        ref_arrays = engines["1ds"].plan.graph.device_arrays()
        for k, v in g.device_arrays().items():
            if not np.array_equal(np.asarray(v),
                                  np.asarray(ref_arrays[k])):
                raise AssertionError(f"healed build differs at {k}")
        return {"route_slack0": slack, "retry_log": info["retry_log"]}
    case("heal/route_slack", heal_route)

    tmp = tempfile.mkdtemp(prefix="faultstore_")
    store = GraphStore(tmp)
    for decomp, mode in (("1ds", "flip"), ("2d", "truncate")):
        def repair(decomp=decomp, mode=mode):
            g = engines[decomp].plan.graph
            name = f"g_{decomp}"
            store.save_graph(name, g, spec=spec)
            path = corrupt_shard(store, name, seed, mode=mode)
            loaded = store.load_graph(name, expect_spec=spec)
            rep = store.last_load_report
            if not rep["repaired"]:
                raise AssertionError(f"corruption of {path} undetected")
            for k, v in g.device_arrays().items():
                if not np.array_equal(np.asarray(v),
                                      np.asarray(loaded.device_arrays()[k])):
                    raise AssertionError(f"regen differs at {k}")
            return {"corrupted": os.path.basename(path), "mode": mode,
                    "repaired": rep["repaired"]}
        case(f"store/{decomp}/{mode}", repair)

    return {"seed": seed, "scale": scale, "edge_factor": edge_factor,
            "devices": devices, "cases": cases,
            "ok": all(c["ok"] for c in cases)}


# ---------------------------------------------------------------------------
# CLI (the CI `faults` lane)
# ---------------------------------------------------------------------------


def _force_devices(n: int) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = \
        f"{flags} --xla_force_host_platform_device_count={n}"


def main(argv=None) -> int:
    import argparse
    parser = argparse.ArgumentParser(
        description="Replay the seeded fault-injection matrix "
                    "(validator kill matrix, capacity healing, store "
                    "shard regeneration) and report JSON verdicts.")
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument("--scale", type=int, default=8)
    parser.add_argument("--edge-factor", type=int, default=8)
    parser.add_argument("--devices", type=int, default=16,
                        help="forced host device count (set before jax "
                             "import)")
    parser.add_argument("--json", type=str, default=None,
                        help="write the report to this path")
    args = parser.parse_args(argv)

    _force_devices(args.devices)
    report = run_fault_matrix(seed=args.seed, scale=args.scale,
                              edge_factor=args.edge_factor,
                              devices=args.devices)
    for c in report["cases"]:
        status = "ok  " if c["ok"] else "FAIL"
        print(f"  [{status}] {c['name']}")
        if not c["ok"]:
            print(f"         {c['detail']}")
    print(f"fault matrix: {sum(c['ok'] for c in report['cases'])}/"
          f"{len(report['cases'])} cases ok (seed={report['seed']})")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"report -> {args.json}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
