"""Fault-tolerant training loop: checkpoint/restart with step-indexed
deterministic data, optional gradient compression, straggler monitoring.

On a real fleet each host runs this loop under the cluster launcher; a
node failure kills the job and the relauncher calls ``Trainer.run`` again
— auto-resume picks up at the latest published checkpoint with
bit-identical data order (see data/pipeline.py)."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.runtime.straggler import StragglerMonitor


@dataclasses.dataclass
class Trainer:
    step_fn: Callable          # (state, batch) -> (state, metrics)
    make_batch: Callable       # step -> batch
    ckpt_dir: str
    ckpt_every: int = 50
    meta: Optional[Dict] = None
    straggler: Optional[StragglerMonitor] = None

    def run(self, state: Any, n_steps: int, resume: bool = True):
        start = 0
        last = ckpt.latest_step(self.ckpt_dir) if resume else None
        if last is not None:
            state, _ = ckpt.restore(self.ckpt_dir, last, state,
                                    expect_meta=self.meta)
            start = last
        metrics_log = []
        for step in range(start, n_steps):
            batch = self.make_batch(step)
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(jax.tree.leaves(state)[0])
            dt = time.perf_counter() - t0
            if self.straggler is not None:
                self.straggler.observe(step, dt)
            metrics_log.append({k: float(v) for k, v in metrics.items()})
            nxt = step + 1
            if nxt % self.ckpt_every == 0 or nxt == n_steps:
                ckpt.save(self.ckpt_dir, nxt,
                          jax.tree.map(np.asarray, state), meta=self.meta)
        return state, metrics_log
