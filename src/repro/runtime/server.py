"""Batched serving runtime: dynamic request batching over a prefill/decode
step pair (continuous-batching-lite).

Requests queue up; the server packs up to ``max_batch`` prompts (padded to
a shared length bucket), prefills once, then decodes round-robin until
every request hits its token budget.  Single-process synchronous version —
the multi-pod layout shards the batch over ("pod","data") and the serve
steps are the same jitted fns the dry-run lowers."""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int = 8
    out: Optional[np.ndarray] = None


@dataclasses.dataclass
class Server:
    prefill_fn: Callable          # (tokens (B,S)) -> (cache, logits)
    decode_fn: Callable           # (cache, tok (B,1), pos) -> (cache, logits)
    max_batch: int = 8
    bucket: int = 64

    def serve(self, requests: Sequence[Request]) -> List[Request]:
        reqs = list(requests)
        for i in range(0, len(reqs), self.max_batch):
            self._serve_batch(reqs[i:i + self.max_batch])
        return reqs

    def _serve_batch(self, batch: List[Request]):
        B = len(batch)
        lens = [len(r.prompt) for r in batch]
        S = self.bucket * ((max(lens) + self.bucket - 1) // self.bucket)
        toks = np.zeros((self.max_batch, S), np.int32)
        for i, r in enumerate(batch):
            toks[i, S - lens[i]:] = r.prompt       # left-pad to align ends
        cache, logits = self.prefill_fn(jnp.asarray(toks))
        outs = [[] for _ in batch]
        n_new = max(r.max_new_tokens for r in batch)
        pos = S
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for step in range(n_new):
            for i in range(B):
                outs[i].append(int(tok[i, 0]))
            cache, logits = self.decode_fn(cache, tok, jnp.int32(pos))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            pos += 1
        for i, r in enumerate(batch):
            r.out = np.asarray(outs[i][: r.max_new_tokens], np.int32)
