"""Bounded capacity-escalation retry: shared types for self-healing
plan/build loops.

Both recovery paths in this repo follow the same shape: a capacity knob
(``cap_x`` for the 1ds expand buckets, ``route_slack`` for the
distributed-build all-to-all routes) was sized from a model, the run
overflowed it, and instead of aborting we escalate the knob
geometrically (x2 per attempt, bounded attempts), recompile, and retry.
This module holds the exception and the structured per-attempt log
entries those loops share, so `graph/dist_build.py` and
`core/engine.py::run_bfs_healed` report recovery identically.

Nothing here imports jax — the retry layer is pure host bookkeeping.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class RetryAttempt:
    """One attempt in an escalation loop.

    ``cap_name``/``cap_value`` identify the knob as it was for this
    attempt; ``outcome`` is ``"ok"``, ``"overflow"``, or ``"error"``;
    ``detail`` carries knob-specific context (overflowing levels, route
    counts, ...).
    """
    attempt: int
    cap_name: str
    cap_value: Any
    outcome: str
    detail: Dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return {"attempt": self.attempt, "cap_name": self.cap_name,
                "cap_value": self.cap_value, "outcome": self.outcome,
                "detail": dict(self.detail)}


class CapacityOverflow(RuntimeError):
    """A capacity knob overflowed and (if retried) escalation ran dry.

    Subclasses RuntimeError so existing ``pytest.raises(RuntimeError,
    match="route_slack")`` style call sites keep working.  Carries the
    knob identity and the full escalation history so a final failure
    reports every attempt, not just the last.
    """

    def __init__(self, message: str, *, cap_name: str = "",
                 cap_value: Any = None,
                 history: Optional[List[RetryAttempt]] = None):
        if history:
            trail = "; ".join(
                f"attempt {a.attempt}: {a.cap_name}={a.cap_value} -> "
                f"{a.outcome}" for a in history)
            message = f"{message} [escalation history: {trail}]"
        super().__init__(message)
        self.cap_name = cap_name
        self.cap_value = cap_value
        self.history: Tuple[RetryAttempt, ...] = tuple(history or ())

    def history_json(self) -> List[Dict[str, Any]]:
        return [a.to_json() for a in self.history]


def escalate(value, *, factor: int = 2, ceiling=None):
    """Next knob value: geometric growth, optionally clamped."""
    nxt = value * factor
    if ceiling is not None:
        nxt = min(nxt, ceiling)
    return nxt
