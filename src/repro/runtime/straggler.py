"""Deadline-based straggler mitigation.

Policy (designed for 1000+-node synchronous data parallelism, simulated
here): track a trailing p50/p95 of step wall-times; a step breaching
``factor * p95`` raises a straggler event.  On a real fleet the event
triggers (a) re-dispatch of the step's work onto the hot-spare pod slice
and (b) exclusion of the slow host from the next re-mesh (see
ckpt/elastic.py).  The detection path — the part exercisable on CPU — is
implemented and tested; the re-dispatch hook is injectable."""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    window: int = 50
    factor: float = 3.0
    min_samples: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def __post_init__(self):
        self._times: Deque[float] = deque(maxlen=self.window)
        self.events: List[Tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        if len(self._times) >= self.min_samples:
            p95 = float(np.percentile(self._times, 95))
            if dt > self.factor * p95:
                self.events.append((step, dt, p95))
                if self.on_straggler is not None:
                    self.on_straggler(step, dt, p95)
                self._times.append(dt)
                return True
        self._times.append(dt)
        return False

    @property
    def deadline(self) -> Optional[float]:
        if len(self._times) < self.min_samples:
            return None
        return self.factor * float(np.percentile(self._times, 95))
