"""Registry-wide enumeration for the collective-schedule linter.

Two enumerations, both driven by the Decomposition registry so a new
entry is covered the day it registers:

  * ``lint_combos()`` — every decomposition × (local_mode, storage)
    LocalOps combo × instrument on/off × expand_chunks {1, 2} × the
    entry's other ``schedule_dims`` values (codec for 1ds, fold/compact
    for 2d).  ``lint_registry()`` traces each combo's pod-batched
    program (pods = 2 — the mesh shape where divergence hazards live)
    plus one single-mesh program per entry, and runs rules R1–R3 on
    the closed jaxpr.

  * ``budget_cases()`` — the cross product of each entry's
    ``schedule_dims`` domains, each case carrying its
    ``comm_model.level_budgets_for`` budgets.  ``collect_counts()``
    lowers every case's td/bu level bodies and whole-search program
    (instrument on and off, lowering only — no XLA compile) and is the
    ONE source of truth behind both the R4 rule and
    tests/test_perf_guard.py (which keeps the previously pinned values
    as explicit regression assertions on top).

Everything here lowers against ShapeDtypeStructs on forced host
devices; nothing executes.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core import comm_model

# value domains of the BFSConfig fields entries may list in
# schedule_dims (first value = the canonical default for that sweep)
SCHEDULE_DOMAINS: Dict[str, Tuple] = {
    "fold_mode": ("alltoall", "reduce", "bitmap"),
    "compact_updates": (False, True),
    "frontier_codec": ("packed", "none"),
    "expand_chunks": (1, 2),
}

# the graph/mesh family every enumeration lowers against: the scale-9
# R-MAT from the original perf guard, on p=8 strips / a 2x4 grid
# (pods = 2 for the batched lint programs -> 16 forced host devices)
SCALE, EDGE_FACTOR, SEED = 9, 8, 3
GRID_PR, GRID_PC, STRIP_P, PODS = 2, 4, 8, 2


def _short(dim: str, val) -> str:
    if dim == "fold_mode":
        return f"fold={val}"
    if dim == "compact_updates":
        return f"compact={int(val)}"
    if dim == "frontier_codec":
        return f"codec={val}"
    if dim == "expand_chunks":
        return f"c={val}"
    return f"{dim}={val}"


def case_name(decomposition: str, overrides: Dict[str, Any]) -> str:
    """Canonical name of one schedule case, e.g.
    ``2d[fold=alltoall,compact=0,c=1]`` — dims in the entry's declared
    order, every dim spelled even at its default so names are stable."""
    from repro.core.decomp import get_decomposition
    entry = get_decomposition(decomposition)
    toks = []
    for dim in entry.schedule_dims:
        val = overrides.get(dim, SCHEDULE_DOMAINS[dim][0])
        toks.append(_short(dim, val))
    return f"{decomposition}[{','.join(toks)}]" if toks else decomposition


@dataclass(frozen=True)
class BudgetCase:
    """One schedule point of one entry, with its comm-model budgets."""
    name: str
    decomposition: str
    overrides: Dict[str, Any] = field(hash=False)

    def budgets(self, pc: int, p: int) -> Dict[str, int]:
        return comm_model.level_budgets_for(
            self.decomposition, pc=pc, p=p, **self.overrides)


def budget_cases() -> Tuple[BudgetCase, ...]:
    """Cross product of every registered entry's schedule_dims — the
    R4 enumeration.  No hand-written case table: registering an entry
    (with its dims) is what adds its budget coverage."""
    from repro.core.decomp import (get_decomposition,
                                   registered_decompositions)
    cases = []
    for name in registered_decompositions():
        entry = get_decomposition(name)
        dims = entry.schedule_dims
        for vals in itertools.product(*(SCHEDULE_DOMAINS[d] for d in dims)):
            ov = dict(zip(dims, vals))
            cases.append(BudgetCase(case_name(name, ov), name, ov))
    return tuple(cases)


# ---------------------------------------------------------------------------
# Lowering helpers (shared with tests/_perf_guard_main.py)
# ---------------------------------------------------------------------------


def _sds(a):
    import jax
    import numpy as np
    a = np.asarray(a)
    return jax.ShapeDtypeStruct(a.shape, a.dtype)


def _graph_sds(plan):
    return {k: _sds(v) for k, v in plan.graph.device_arrays().items()
            if k in plan.keys}


def search_counts(plan) -> Dict[str, int]:
    """Collective counts of the lowered whole-search program."""
    import jax.numpy as jnp
    from repro.core.engine import hlo_collective_counts
    txt = plan.build_fn().lower(_graph_sds(plan), jnp.int32(0)).as_text()
    return hlo_collective_counts(txt)


def level_counts(plan, which: str) -> Dict[str, int]:
    """Collective counts of ONE lowered level step body (td or bu) —
    the per-level schedule minus the loop's fused reduction.  The
    fast-path ``lv`` context is threaded as a replicated input; the
    instrumented step gets lv=None, exactly as _search_loop calls it.
    The steps come from the entry's ``level_steps`` declaration."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import steps
    from repro.core.compat import shard_map
    from repro.core.engine import hlo_collective_counts

    if plan.entry.level_steps is None:
        raise ValueError(
            f"decomposition {plan.entry.name!r} declares no level_steps; "
            f"the R4 budget lowering needs them")
    args = plan.level_args()
    nax = plan.entry.n_axes
    td, bu = plan.entry.level_steps
    step = td if which == "td" else bu
    sq = (0,) * nax

    ctr_keys = steps.COUNTER_KEYS if args.instrument else ()

    def fn(garr, pi, front, over):
        gl = {k: v[sq] for k, v in garr.items()}
        lv = None if args.instrument else {"over": over}
        pi2, f2, ctr = step(gl, pi[sq], front[sq], args, lv)
        # ctr must stay a live output or the counter psums get DCE'd —
        # the whole point is counting what the instrumented level pays
        return pi2.reshape((1,) * nax + pi2.shape), dict(ctr)

    spec = P(*plan.axes)
    gspec = {k: spec for k in plan.keys}
    mapped = shard_map(fn, mesh=plan.mesh,
                       in_specs=(gspec, spec, spec, P()),
                       out_specs=(spec, {k: P() for k in ctr_keys}),
                       check_vma=False)
    arrs = _graph_sds(plan)
    pi = jax.ShapeDtypeStruct(arrs["deg_A"].shape, np.int32)
    fr = jax.ShapeDtypeStruct(arrs["deg_A"].shape, np.bool_)
    txt = jax.jit(mapped).lower(arrs, pi, fr,
                                jnp.zeros((), bool)).as_text()
    return hlo_collective_counts(txt)


def _inputs(family: str, batched: bool):
    """The shared scale-9 graph + mesh for one decomposition family.
    Graphs and meshes are cached; the pod meshes (16 devices) are only
    created when a batched program asks for them, so the budget-only
    sweep runs on 8 forced host devices."""
    if "graphs" not in _CACHE:
        from repro.graph.formats import build_blocked, build_blocked_1d
        from repro.graph.rmat import rmat_graph
        e = rmat_graph(SCALE, edge_factor=EDGE_FACTOR, seed=SEED)
        _CACHE["graphs"] = {
            "2d": build_blocked(e, GRID_PR, GRID_PC, align=32, cap_pad=32),
            # with_col_ptr: the kernel/csr combos ship the uncompressed
            # column pointers — the sweep covers every LocalOps combo
            "1d": build_blocked_1d(e, STRIP_P, align=32, cap_pad=32,
                                   with_col_ptr=True),
        }
    key = (family, batched)
    if key not in _CACHE:
        from repro.launch.mesh import make_local_mesh, make_local_mesh_1d
        pods = PODS if batched else 0
        _CACHE[key] = (make_local_mesh(GRID_PR, GRID_PC, pods=pods)
                       if family == "2d"
                       else make_local_mesh_1d(STRIP_P, pods=pods))
    return _CACHE["graphs"][family], _CACHE[key]


_CACHE: Dict = {}


def _family(decomposition: str) -> str:
    from repro.core.decomp import get_decomposition
    from repro.core.partition import Partition2D
    entry = get_decomposition(decomposition)
    return "2d" if entry.partition_cls is Partition2D else "1d"


def plan_case(decomposition: str, overrides: Dict[str, Any], *,
              instrument: bool, local_mode: str = "dense",
              storage: str = "csr", batched: bool = False):
    """A concrete plan for one enumerated case on the shared inputs."""
    from repro.configs.base import BFSConfig
    from repro.core.engine import plan_bfs
    graph, mesh = _inputs(_family(decomposition), batched)
    cfg = BFSConfig(decomposition=decomposition, instrument=instrument,
                    storage=storage, **overrides)
    return plan_bfs(graph, cfg, mesh, local_mode=local_mode)


def validator_counts(decomposition: str) -> Dict[str, int]:
    """Collective counts of the lowered Graph500 parent-tree validator
    for one registered decomposition (lowering only).  The validator is
    schedule-dim-independent — one program per decomposition — and its
    footprint is pinned against ``comm_model.validate_collective_budget``
    in tests/test_perf_guard.py."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.engine import hlo_collective_counts
    from repro.core.validate import build_validate_fn

    plan = plan_case(decomposition, {}, instrument=False)
    fn = build_validate_fn(plan)
    arrays = plan.graph.device_arrays()
    gsds = {k: _sds(arrays[k]) for k in plan.entry.edge_keys}
    pi = jax.ShapeDtypeStruct(np.asarray(arrays["deg_A"]).shape,
                              np.int32)
    txt = fn.lower(gsds, pi, jnp.int32(0)).as_text()
    return hlo_collective_counts(txt)


def collect_counts() -> Dict[str, Any]:
    """The perf-guard payload: lowered collective counts of every
    ``budget_cases()`` case (td/bu level bodies + whole search,
    instrument on and off), keyed by canonical case name — plus the
    parent-tree validators under ``"validators"``."""
    from repro.core.decomp import registered_decompositions

    out: Dict[str, Any] = {"pc": GRID_PC, "p": STRIP_P}
    for case in budget_cases():
        row = {}
        for label, instr in (("fast", False), ("instrumented", True)):
            plan = plan_case(case.decomposition, case.overrides,
                             instrument=instr)
            row[label] = {
                "search": search_counts(plan),
                "td": level_counts(plan, "td"),
                "bu": level_counts(plan, "bu"),
            }
        out[case.name] = row
    out["validators"] = {name: validator_counts(name)
                         for name in registered_decompositions()}
    return out


def budget_findings(counts: Optional[Dict[str, Any]] = None) -> List:
    """R4 over the full enumeration: every case's instrument-off level
    bodies vs its comm-model budgets."""
    from repro.analysis.rules import check_budget
    counts = counts if counts is not None else collect_counts()
    pc, p = counts["pc"], counts["p"]
    findings = []
    for case in budget_cases():
        budgets = case.budgets(pc, p)
        fast = counts[case.name]["fast"]
        for mode in ("td", "bu"):
            findings.extend(check_budget(
                fast[mode], budgets[mode], combo=case.name, mode=mode))
    return findings


# ---------------------------------------------------------------------------
# Jaxpr lint (rules R1-R3) over plans and the registry
# ---------------------------------------------------------------------------


def lint_plan(plan, *, pod_axis: Optional[str] = None,
              combo: Optional[str] = None) -> List:
    """Run rules R1–R3 on one plan's traced program (the pod-batched
    one when ``pod_axis`` names an axis of the plan's mesh — that is
    where divergence hazards live).  Needs a concrete graph attached
    (shapes for the trace); nothing is lowered or compiled."""
    import jax
    import numpy as np

    from repro.analysis.rules import (check_axis_layout,
                                      check_branch_schedules,
                                      check_divergent_collectives)
    from repro.analysis.uniformity import analyze_jaxpr

    if plan.graph is None:
        raise ValueError("lint needs a plan with a graph attached "
                         "(plan_bfs, not plan_for_part)")
    combo = combo or f"{plan.entry.name}/{plan.ops.local_mode}/" \
                     f"{plan.cfg.storage}"
    arrs = _graph_sds(plan)
    mesh_axes = tuple(plan.mesh.shape)
    if pod_axis is not None:
        pods = plan.mesh.shape[pod_axis]
        roots = jax.ShapeDtypeStruct((pods,), np.int32)
        cj = jax.make_jaxpr(plan.build_batch_fn(pod_axis))(arrs, roots)
        sync = (pod_axis,)
    else:
        root = jax.ShapeDtypeStruct((), np.int32)
        cj = jax.make_jaxpr(plan.build_fn())(arrs, root)
        sync = ()
    an = analyze_jaxpr(cj, mesh_axes)
    entry = plan.entry
    declared = (tuple(entry.rendezvous_axes(plan.axes, mesh_axes))
                if entry.rendezvous_axes is not None else tuple(mesh_axes))
    findings = check_divergent_collectives(an, combo)
    findings += check_branch_schedules(an, combo)
    findings += check_axis_layout(
        an, combo, entry_name=entry.name, graph_axes=plan.axes,
        sync_axes=sync, declared_rendezvous=declared)
    return findings


@dataclass(frozen=True)
class LintCombo:
    decomposition: str
    local_mode: str
    storage: str
    instrument: bool
    overrides: Dict[str, Any] = field(hash=False)

    @property
    def name(self) -> str:
        instr = "instr" if self.instrument else "fast"
        return (f"{case_name(self.decomposition, self.overrides)}/"
                f"{self.local_mode}/{self.storage}/{instr}")


def lint_combos(quick: bool = False) -> Tuple[LintCombo, ...]:
    """The registry-wide R1–R3 sweep:

    * every (local_mode, storage) LocalOps combo of every entry ×
      instrument on/off × expand_chunks {1, 2} × codec (entries that
      declare it), at the entry's other schedule defaults;
    * plus the full schedule_dims cross product × instrument at
      dense/csr (fold modes and compact updates change the 2d branch
      bodies, so they get their own jaxprs).

    ``quick`` keeps one representative per entry (dense/csr, both
    instrument modes, chunks 1) for fast tests."""
    from repro.core import local_ops
    from repro.core.decomp import (get_decomposition,
                                   registered_decompositions)
    combos: List[LintCombo] = []
    seen = set()

    def add(decomp, lm, st, instr, ov):
        key = (decomp, lm, st, instr, tuple(sorted(ov.items())))
        if key not in seen:
            seen.add(key)
            combos.append(LintCombo(decomp, lm, st, instr, dict(ov)))

    for decomp in registered_decompositions():
        entry = get_decomposition(decomp)
        lm_st = [(lm, st) for d, lm, st in local_ops.registered_combos()
                 if d == decomp] or [("dense", "csr")]
        codecs = (SCHEDULE_DOMAINS["frontier_codec"]
                  if "frontier_codec" in entry.schedule_dims else (None,))
        if quick:
            for instr in (False, True):
                add(decomp, "dense", "csr", instr, {})
            continue
        for (lm, st), instr, chunks, codec in itertools.product(
                lm_st, (False, True), SCHEDULE_DOMAINS["expand_chunks"],
                codecs):
            ov = {"expand_chunks": chunks}
            if codec is not None:
                ov["frontier_codec"] = codec
            add(decomp, lm, st, instr, ov)
        # the full schedule sweep at the default local format
        for vals in itertools.product(
                *(SCHEDULE_DOMAINS[d] for d in entry.schedule_dims)):
            ov = dict(zip(entry.schedule_dims, vals))
            for instr in (False, True):
                add(decomp, "dense", "csr", instr, ov)
    return tuple(combos)


def lint_registry(quick: bool = False,
                  with_budgets: bool = True) -> Dict[str, Any]:
    """The full registry lint: R1–R3 on every combo's pod-batched
    program (plus one single-mesh program per entry), R4 over the
    budget enumeration.  Returns the JSON-ready report."""
    report: Dict[str, Any] = {"combos": [], "findings": []}
    for combo in lint_combos(quick=quick):
        plan = plan_case(combo.decomposition, combo.overrides,
                         instrument=combo.instrument,
                         local_mode=combo.local_mode,
                         storage=combo.storage, batched=True)
        fs = lint_plan(plan, pod_axis="pod", combo=combo.name)
        report["combos"].append({"name": combo.name,
                                 "findings": len(fs)})
        report["findings"].extend(f.to_json() for f in fs)
    # one single-mesh program per entry (no pod axis: trivially uniform
    # predicates — a cheap sanity pass over the non-batched trace path)
    from repro.core.decomp import registered_decompositions
    for decomp in registered_decompositions():
        plan = plan_case(decomp, {}, instrument=True)
        fs = lint_plan(plan, combo=f"{decomp}/single")
        report["combos"].append({"name": f"{decomp}/single",
                                 "findings": len(fs)})
        report["findings"].extend(f.to_json() for f in fs)
    if with_budgets:
        counts = collect_counts()
        fs = budget_findings(counts)
        report["budget_cases"] = [c.name for c in budget_cases()]
        report["findings"].extend(f.to_json() for f in fs)
    report["n_findings"] = len(report["findings"])
    report["clean"] = not report["findings"]
    return report
