"""Lint rules over a uniformity analysis (R1–R3) and lowered-HLO
collective counts (R4).  Each rule returns ``Finding``s — structured,
JSON-serializable, and specific enough to act on (the offending
collective, the non-uniform predicate and its provenance, the axes
that can diverge).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.analysis.uniformity import MISMATCH, Analysis


@dataclass(frozen=True)
class Finding:
    rule: str                 # "R1" | "R2" | "R3" | "R4"
    combo: str                # which registry combo / program tripped it
    message: str              # one-line human statement of the defect
    detail: Dict = field(default_factory=dict)

    def to_json(self) -> Dict:
        return asdict(self)


def _fmt_axes(axes) -> str:
    return "(" + ", ".join(repr(a) for a in sorted(axes)) + ")"


# ---------------------------------------------------------------------------
# R1: divergent-collective (the PR 4 deadlock class)
# ---------------------------------------------------------------------------


def check_divergent_collectives(an: Analysis, combo: str) -> List[Finding]:
    """A collective under a cond/while predicate must have that
    predicate provably uniform over every axis the op rendezvouses on;
    otherwise some devices enter the rendezvous while others took the
    other branch (or left the loop) — they wait forever."""
    findings = []
    for site in an.sites:
        rendezvous = set(site.rendezvous(an.mesh_axes))
        for pred in site.preds:
            missing = rendezvous - pred.unif
            if not missing:
                continue
            findings.append(Finding(
                rule="R1", combo=combo,
                message=(
                    f"{site.kind} over {site.axes!r} rendezvouses on "
                    f"{_fmt_axes(rendezvous)} but is guarded by a "
                    f"{pred.kind} predicate only uniform over "
                    f"{_fmt_axes(pred.unif)} — devices may diverge over "
                    f"{_fmt_axes(missing)} and deadlock"),
                detail={
                    "collective": site.kind,
                    "op_axes": list(site.axes),
                    "rendezvous_axes": sorted(rendezvous),
                    "predicate": pred.desc,
                    "predicate_kind": pred.kind,
                    "predicate_uniform_over": sorted(pred.unif),
                    "divergent_axes": sorted(missing),
                    "path": site.path,
                }))
    return findings


# ---------------------------------------------------------------------------
# R2: branch-schedule-mismatch
# ---------------------------------------------------------------------------


def _seq_rendezvous(seq, mesh_axes) -> set:
    axes = set()
    for kind, op_axes in seq:
        if (kind, op_axes) == MISMATCH:
            axes |= set(mesh_axes)   # unknown nested schedule: assume worst
        elif kind == "ppermute":
            axes |= set(mesh_axes)
        else:
            axes |= set(op_axes)
    return axes


def check_branch_schedules(an: Analysis, combo: str) -> List[Finding]:
    """Cond branches that issue different (kind, axes) collective
    sequences are fine while the predicate is uniform over every axis
    those collectives rendezvous on (all devices take the same branch)
    — and a deadlock/mismatched-rendezvous hazard the moment it can
    diverge over one of them."""
    findings = []
    for rec in an.conds:
        seqs = set(rec.branch_seqs)
        if len(seqs) == 1 and MISMATCH not in rec.branch_seqs[0]:
            continue   # identical schedules: divergence is harmless
        divergent = set(an.mesh_axes) - rec.pred.unif
        if not divergent:
            continue   # uniform predicate: lockstep branch choice
        rendezvous = set()
        for seq in rec.branch_seqs:
            rendezvous |= _seq_rendezvous(seq, an.mesh_axes)
        hazard = rendezvous & divergent
        if not hazard:
            continue   # branches differ but all ops stay local to
            #            axes the predicate is uniform over
        findings.append(Finding(
            rule="R2", combo=combo,
            message=(
                f"cond branches issue different collective sequences "
                f"{[list(s) for s in rec.branch_seqs]!r} under a "
                f"predicate ({rec.pred.desc}) divergent over "
                f"{_fmt_axes(hazard)}"),
            detail={
                "branch_sequences": [
                    [[k, list(a)] for k, a in seq]
                    for seq in rec.branch_seqs],
                "predicate": rec.pred.desc,
                "predicate_uniform_over": sorted(rec.pred.unif),
                "divergent_axes": sorted(hazard),
                "path": rec.path,
            }))
    return findings


# ---------------------------------------------------------------------------
# R3: unknown-axis / pod-leak / under-declared rendezvous contract
# ---------------------------------------------------------------------------


def check_axis_layout(an: Analysis, combo: str, *,
                      entry_name: str,
                      graph_axes: Sequence[str],
                      sync_axes: Sequence[str] = (),
                      declared_rendezvous: Optional[Sequence[str]] = None
                      ) -> List[Finding]:
    """Three layout checks:

    * a collective over an axis outside the entry's declared graph
      axes (+ the sync/pod axis for scalar reductions) is reaching a
      mesh dimension the decomposition never declared — in a pod mesh
      that is graph data leaking across embarrassingly-parallel pods;
    * data-moving collectives (gather/to-all/permute) must stay on the
      graph axes entirely: pods replicate the graph, they never
      exchange it;
    * the entry's ``rendezvous_axes`` declaration must cover what its
      program actually issues (an entry that ppermutes but claims
      strip-local rendezvous would let a future per-slice heuristic
      slip past review — the declaration is checked, not trusted)."""
    findings = []
    graph_axes = set(graph_axes)
    sync_axes = set(sync_axes)
    actual_rendezvous = set()
    for site in an.sites:
        rv = set(site.rendezvous(an.mesh_axes))
        if site.kind in ("psum", "pmax", "pmin"):
            # scalar reductions over the sync/pod axis are the engine's
            # lockstep machinery (_search_loop), issued for every entry
            # — they are not part of the entry's declared schedule
            rv -= sync_axes
        actual_rendezvous |= rv
        op_axes = set(site.axes)
        allowed = graph_axes | (sync_axes if site.kind in
                                ("psum", "pmax", "pmin") else set())
        stray = op_axes - allowed
        if not stray:
            continue
        leak = stray & sync_axes
        findings.append(Finding(
            rule="R3", combo=combo,
            message=(
                f"{site.kind} over {site.axes!r} reaches "
                f"{'the pod axis ' + _fmt_axes(leak) if leak else 'undeclared axes ' + _fmt_axes(stray)} "
                f"outside decomposition {entry_name!r}'s layout "
                f"{_fmt_axes(graph_axes)}"),
            detail={
                "collective": site.kind,
                "op_axes": list(site.axes),
                "allowed_axes": sorted(allowed),
                "stray_axes": sorted(stray),
                "pod_leak": bool(leak),
                "path": site.path,
            }))
    if declared_rendezvous is not None:
        under = actual_rendezvous - set(declared_rendezvous)
        if under:
            findings.append(Finding(
                rule="R3", combo=combo,
                message=(
                    f"decomposition {entry_name!r} declares "
                    f"rendezvous_axes={_fmt_axes(declared_rendezvous)} but "
                    f"its program rendezvouses on "
                    f"{_fmt_axes(actual_rendezvous)} — the declaration "
                    f"under-claims {_fmt_axes(under)}"),
                detail={
                    "declared": sorted(declared_rendezvous),
                    "actual": sorted(actual_rendezvous),
                    "under_declared": sorted(under),
                }))
    return findings


# ---------------------------------------------------------------------------
# R4: budget-drift (lowered-HLO counts vs the comm model)
# ---------------------------------------------------------------------------


def check_budget(counts: Dict[str, int], budget: int, *, combo: str,
                 mode: str) -> List[Finding]:
    """One lowered level body vs its published collective budget."""
    total = counts.get("total", 0)
    if total <= budget:
        return []
    return [Finding(
        rule="R4", combo=combo,
        message=(
            f"{mode} level body lowers to {total} collective ops, over "
            f"the comm_model budget of {budget}"),
        detail={"mode": mode, "counts": dict(counts), "budget": budget})]
