"""The deliberately-broken decomposition the linter must catch.

PR 4 fixed a latent deadlock: the 2d entry's direction heuristic made
its td/bu decision from a psum over the GRAPH axes only, so in a
pod-batched mesh each pod could pick a different branch — and the 2d
step bodies ppermute, which XLA lowers as a whole-mesh rendezvous, so
a divergent pod waits forever on a collective its peers never issue.
The fix (``sync_modes=True`` in core/decomp.py) pmax/pmins the
decision over the sync axes.

This module reintroduces that bug under a test-only registry name:
``_bfs_body_2d`` with ``sync_modes=False`` — per-slice decisions
driving whole-mesh ppermutes.  ``divergent_2d_fixture()`` registers it
(plus a mirrored LocalOps entry) for the duration of a with-block and
restores the registry on exit, so ``registered_decompositions()``
stays exactly ("1d", "1ds", "2d") for every other test.  The linter's
R1 rule must flag it; tests/test_analysis_lint.py and the CLI's
``--expect-fixture`` self-check both assert that it does — proof the
linter can catch the bug class it exists for.
"""
from __future__ import annotations

import dataclasses
from contextlib import contextmanager
from typing import Optional

from jax import lax
import jax.numpy as jnp

FIXTURE_NAME = "2d-divergent-fixture"


def _divergent_body_2d(g, root, *, part, args, cfg,
                       sync_axis: Optional[str] = None):
    """_bfs_body_2d with the pre-PR-4 bug: sync_modes=False lets each
    pod slice switch direction on its own psum — divergent branches
    around whole-mesh ppermutes."""
    from repro.core.decomp import _search_loop
    from repro.core.steps import bottomup_level, topdown_level
    pc, chunk = part.pc, part.chunk
    axes = (args.row_axis, args.col_axis)
    sync = axes + ((sync_axis,) if sync_axis else ())
    i = lax.axis_index(args.row_axis)
    j = lax.axis_index(args.col_axis)
    g = {k: v[0, 0] for k, v in g.items()}

    gidx = ((i * pc + j) * chunk + jnp.arange(chunk)).astype(jnp.int32)
    pi, level, ctr, stats = _search_loop(
        g, gidx, root, n_total=part.n, cfg=cfg, axes=axes, sync=sync,
        td_level=lambda pi, f, lv=None: topdown_level(g, pi, f, args, lv),
        bu_level=lambda pi, f, lv=None: bottomup_level(g, pi, f, args, lv),
        # THE BUG: per-slice direction decisions, whole-mesh ppermutes
        sync_modes=False)
    return pi[None, None], level, ctr, stats


@contextmanager
def divergent_2d_fixture():
    """Scoped registration of the broken entry (+ a dense/csr LocalOps
    mirror so plans resolve); yields the Decomposition.  The registry
    is restored on exit no matter what."""
    from repro.core import decomp, local_ops
    entry = dataclasses.replace(
        decomp.get_decomposition("2d"), name=FIXTURE_NAME,
        body=_divergent_body_2d)
    decomp.register_decomposition(entry)
    mirrored = []
    try:
        for d, lm, st in local_ops.registered_combos():
            if d == "2d" and lm == "dense":
                src = local_ops.get_local_ops(d, lm, st)
                local_ops.register_local_ops(
                    dataclasses.replace(src, decomposition=FIXTURE_NAME))
                mirrored.append((FIXTURE_NAME, lm, st))
        yield entry
    finally:
        for key in mirrored:
            local_ops.unregister_local_ops(*key)
        decomp.unregister_decomposition(FIXTURE_NAME)


def lint_fixture(instrument: bool = False):
    """Lint the broken entry's pod-batched program; returns the
    findings (callers assert R1 is among them)."""
    from repro.analysis.registry import lint_plan, plan_case
    with divergent_2d_fixture():
        plan = plan_case(FIXTURE_NAME, {}, instrument=instrument,
                         batched=True)
        return lint_plan(plan, pod_axis="pod",
                         combo=f"{FIXTURE_NAME}/"
                               f"{'instr' if instrument else 'fast'}")
