"""CLI for the SPMD collective-schedule linter.

    python -m repro.analysis.lint [--json REPORT.json] [--quick]
                                  [--no-budgets] [--expect-fixture]
                                  [--devices N]

Walks every registered decomposition combo's pod-batched program
(rules R1–R3 over the closed jaxpr) and the registry budget
enumeration (rule R4 over lowered HLO, no XLA compile), prints a human
summary, optionally writes the full JSON report, and exits non-zero on
any finding.  ``--expect-fixture`` additionally lints the
deliberately-broken pre-PR-4 2d entry and FAILS if rule R1 does *not*
flag it — the linter proving it can catch the deadlock class it
exists for.

Run from a fresh process: ``--devices`` forces that many host devices
(the default 16 fits the 2x4-grid / 8-strip × 2-pod meshes the
enumeration traces against) and must be applied before jax
initializes.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _force_devices(n: int) -> None:
    """Pin the forced host-device count.  XLA reads XLA_FLAGS when the
    backend first initializes (the first jax.devices()/trace), not at
    import — so setting the env var here works as long as nothing has
    touched the backend yet; if something has, fail loudly rather than
    trace against too few devices."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()
    import jax
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"jax initialized with {len(jax.devices())} devices but the "
            f"registry lint needs {n}; run the CLI in a fresh process "
            f"(or pass --devices)")


def _print_findings(findings) -> None:
    for f in findings:
        print(f"  [{f['rule']}] {f['combo']}: {f['message']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static SPMD collective-schedule lint of every "
                    "registered decomposition combo")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full JSON report here")
    ap.add_argument("--quick", action="store_true",
                    help="one representative combo per entry (fast)")
    ap.add_argument("--no-budgets", action="store_true",
                    help="skip the R4 budget lowering sweep")
    ap.add_argument("--expect-fixture", action="store_true",
                    help="also lint the broken pre-PR-4 2d fixture and "
                         "fail unless R1 flags it")
    ap.add_argument("--devices", type=int, default=16,
                    help="forced host device count (default 16)")
    args = ap.parse_args(argv)

    _force_devices(args.devices)
    # heavy imports only after the device count is pinned
    from repro.analysis.fixtures import FIXTURE_NAME, lint_fixture
    from repro.analysis.registry import lint_registry

    report = lint_registry(quick=args.quick,
                           with_budgets=not args.no_budgets)
    rc = 0
    n_combos = len(report["combos"])
    if report["clean"]:
        print(f"lint: {n_combos} registry combos clean"
              + ("" if args.no_budgets else
                 f", {len(report.get('budget_cases', []))} budget cases "
                 f"within comm_model budgets"))
    else:
        print(f"lint: {report['n_findings']} finding(s) across "
              f"{n_combos} combos:")
        _print_findings(report["findings"])
        rc = 1

    if args.expect_fixture:
        fix = [f.to_json() for f in lint_fixture(instrument=False)]
        fix += [f.to_json() for f in lint_fixture(instrument=True)]
        report["fixture"] = {"name": FIXTURE_NAME, "findings": fix}
        r1 = [f for f in fix if f["rule"] == "R1"
              and f["detail"].get("collective") == "ppermute"]
        if r1:
            print(f"fixture: R1 correctly flags {FIXTURE_NAME} "
                  f"({len(r1)} divergent-ppermute finding(s)), e.g.:")
            _print_findings(r1[:1])
        else:
            print(f"fixture: FAILED — R1 did not flag {FIXTURE_NAME}; "
                  f"the linter cannot catch the deadlock class it "
                  f"exists for")
            _print_findings(fix)
            rc = 1

    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"report written to {args.json}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
