"""Static analysis of the SPMD collective schedule (the linter).

The latency analysis (paper §6, Buluç & Madduri arXiv 1104.4518) makes
the per-level collective *schedule* a first-class artifact, and PR 4
found — by hand — that a per-pod-divergent direction decision deadlocks
any entry whose collectives rendezvous with the whole mesh (the 2d
ppermutes).  This package turns both hazards into machine-checked
rules over the closed jaxpr and the lowered HLO of every registered
decomposition combo:

  R1 divergent-collective   a collective reachable under a cond/while
                            predicate not provably uniform over the
                            axes it rendezvouses on (deadlock hazard —
                            makes decomp's ``sync_modes`` *checked*)
  R2 branch-schedule-mismatch  cond branches issue different
                            (kind, axes) collective sequences while
                            the predicate can diverge over axes those
                            collectives rendezvous on
  R3 unknown-axis/pod-leak  collectives over axes outside the entry's
                            declared layout; graph data crossing the
                            pod axis; entries under-declaring their
                            ``rendezvous_axes`` contract
  R4 budget-drift           lowered-HLO collective counts vs
                            ``comm_model.level_collective_budget``,
                            auto-enumerated from the registry
                            (one source of truth for test_perf_guard)

Entry points: ``python -m repro.analysis.lint`` (CLI, JSON + human
output), ``BFSPlan.lint()`` (core/engine.py), and the pieces —
``uniformity.analyze_jaxpr`` (the mesh-uniformity lattice),
``rules`` (findings), ``registry`` (combo/budget enumeration),
``fixtures`` (the reintroduced pre-PR-4 divergent 2d entry).

This __init__ deliberately imports nothing: the CLI must pin the
forced host-device count BEFORE anything drags jax in.
"""
