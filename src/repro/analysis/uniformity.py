"""Mesh-uniformity lattice over jaxpr values.

The abstract value of every jaxpr variable is the set of mesh axes the
value is *provably uniform over*: every pair of devices differing only
along those axes holds bit-identical contents.  The lattice is the
powerset of mesh axes ordered by inclusion; meet is intersection;
constants/literals sit at top (uniform over everything), shard-resident
data at whatever its sharding leaves.

Transfer functions (the SPMD facts the linter rests on):

  * shard_map input sharded over axes S  ->  uniform over mesh - S
    (a replicated input — empty spec — is uniform everywhere)
  * ``axis_index(a)``                    ->  uniform over mesh - {a}
  * ``psum/pmax/pmin`` over axes S (no axis_index_groups): the result
    is bit-identical on every member of the reduction group ->
    in ∪ S.  Grouped reductions only unify within each group, which
    the axes no longer describe -> conservatively ``in``.
  * ``all_gather`` over S: every member receives the same concatenated
    buffer -> in ∪ S
  * ``all_to_all`` over S: each member keeps a different slice ->
    in - S
  * ``ppermute``: a permutation moves values between devices but a
    value uniform over an axis set stays uniform (all sources agree)
    -> in
  * pure eqns: meet of the inputs
  * ``cond``: branch bodies evaluate under the predicate's uniformity;
    outputs are the meet over branches, met with the predicate (a
    divergent predicate makes every output divergent)
  * ``while``/``scan`` carries: fixpoint iteration — carry(k+1) =
    init ∩ body_out(carry(k)); the lattice is finite and the
    transfer monotone, so this terminates

Alongside the abstract values the walker records every *collective
site* (kind, axes, the stack of enclosing predicates, a path) and
every *cond record* (predicate + per-branch ordered collective
sequences) — the raw material for rules R1–R3 in
``repro.analysis.rules``.  Each abstract value also carries a short
provenance string (``desc``) naming the binding constraint — the
collective or sharded input its uniformity came from — so findings can
name the non-uniform predicate in source terms.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

try:  # jax 0.4.x public core; newer jax moved these under jax.extend
    from jax.core import ClosedJaxpr, Jaxpr, Literal  # type: ignore
except ImportError:  # pragma: no cover - newer jax
    from jax._src.core import ClosedJaxpr, Jaxpr, Literal  # type: ignore

# collectives that rendezvous between devices (jaxpr primitive names)
REDUCTIONS = ("psum", "pmax", "pmin")
DATA_COLLECTIVES = ("all_gather", "all_to_all", "ppermute",
                    "psum_scatter", "all_to_all_invariant", "pbroadcast")
COLLECTIVES = REDUCTIONS + DATA_COLLECTIVES

# a fixpoint that hasn't stabilized after this many sweeps is a walker
# bug, not a real program (the lattice height bounds it far lower)
_MAX_FIXPOINT_SWEEPS = 64

# R2 marker: a nested cond whose branches already disagree
MISMATCH = ("<branch-mismatch>", ())


@dataclass(frozen=True)
class AbstractVal:
    """One lattice point: the axes a value is uniform over, plus the
    provenance of the *binding* constraint (smallest contributor)."""
    unif: frozenset
    desc: str

    def meet(self, other: "AbstractVal") -> "AbstractVal":
        u = self.unif & other.unif
        # keep the description of whichever input constrains the result
        desc = self.desc if len(self.unif) <= len(other.unif) else other.desc
        return AbstractVal(u, desc)


@dataclass(frozen=True)
class Pred:
    """One enclosing control-flow predicate."""
    kind: str          # "cond" | "while"
    unif: frozenset    # axes the predicate is provably uniform over
    desc: str          # provenance, e.g. "psum over ('data', 'model')"
    path: str


@dataclass(frozen=True)
class CollectiveSite:
    """One collective eqn and the control context it executes under."""
    kind: str                   # primitive name, e.g. "ppermute"
    axes: Tuple[str, ...]       # the op's named mesh axes
    preds: Tuple[Pred, ...]     # enclosing predicates, outermost first
    path: str

    def rendezvous(self, mesh_axes: Sequence[str]) -> Tuple[str, ...]:
        """Axes whose devices this op rendezvouses with.  XLA lowers
        collective-permute as one whole-program instruction regardless
        of source_target_pairs — every device participates — while
        all-reduce/-gather/-to-all carry replica_groups and stay local
        to the named axes."""
        if self.kind == "ppermute":
            return tuple(mesh_axes)
        return self.axes


@dataclass(frozen=True)
class CondRecord:
    """One lax.cond: predicate + each branch's collective sequence.
    A sequence element is (kind, axes); nested conds whose branches
    agree contribute their merged sequence, disagreeing ones a
    MISMATCH marker (which R2 always treats as a difference)."""
    pred: Pred
    path: str
    branch_seqs: Tuple[Tuple[Tuple[str, Tuple[str, ...]], ...], ...]


@dataclass
class Analysis:
    """Walker output for one closed jaxpr."""
    mesh_axes: Tuple[str, ...]
    sites: List[CollectiveSite]
    conds: List[CondRecord]
    out_vals: List[AbstractVal]   # top-level jaxpr outputs


def _norm_axes(ax) -> Tuple[str, ...]:
    if isinstance(ax, (tuple, list)):
        return tuple(ax)
    return (ax,)


def _sub_jaxprs(params) -> List[ClosedJaxpr]:
    """Every jaxpr-valued param of an eqn (pjit, custom_jvp, remat...)."""
    found = []
    for v in params.values():
        if isinstance(v, (ClosedJaxpr, Jaxpr)):
            found.append(v)
    return found


def _as_closed(j) -> ClosedJaxpr:
    return j if isinstance(j, ClosedJaxpr) else ClosedJaxpr(j, ())


class _Walker:
    def __init__(self, mesh_axes: Sequence[str]):
        self.mesh_axes = tuple(mesh_axes)
        self.full = frozenset(mesh_axes)
        self.sites: List[CollectiveSite] = []
        self.conds: List[CondRecord] = []

    # -- environment helpers ------------------------------------------------

    def _read(self, env: Dict, atom) -> AbstractVal:
        if isinstance(atom, Literal):
            return AbstractVal(self.full, "constant")
        return env[atom]

    def _meet_inputs(self, env, eqn) -> AbstractVal:
        vals = [self._read(env, a) for a in eqn.invars]
        if not vals:
            return AbstractVal(self.full, "constant")
        out = vals[0]
        for v in vals[1:]:
            out = out.meet(v)
        return out

    # -- jaxpr evaluation ---------------------------------------------------

    def eval_closed(self, cj, in_vals: Sequence[AbstractVal],
                    preds: Tuple[Pred, ...], path: str, record: bool):
        """Returns (out_vals, collective_seq)."""
        cj = _as_closed(cj)
        jaxpr = cj.jaxpr
        env: Dict = {}
        for v in jaxpr.constvars:
            env[v] = AbstractVal(self.full, "constant")
        assert len(jaxpr.invars) == len(in_vals), (
            f"jaxpr arity mismatch at {path}: "
            f"{len(jaxpr.invars)} vars, {len(in_vals)} values")
        for v, val in zip(jaxpr.invars, in_vals):
            env[v] = val
        seq: List[Tuple[str, Tuple[str, ...]]] = []
        for i, eqn in enumerate(jaxpr.eqns):
            self._eval_eqn(env, eqn, preds, f"{path}/{i}:{eqn.primitive.name}",
                           record, seq)
        return [self._read(env, v) for v in jaxpr.outvars], seq

    def _bind(self, env, eqn, vals: Sequence[AbstractVal]):
        assert len(eqn.outvars) == len(vals)
        for v, val in zip(eqn.outvars, vals):
            env[v] = val

    def _eval_eqn(self, env, eqn, preds, path, record, seq):
        name = eqn.primitive.name
        params = eqn.params

        if name in COLLECTIVES:
            self._eval_collective(env, eqn, preds, path, record, seq)
        elif name == "axis_index":
            ax = params["axis_name"]
            self._bind(env, eqn, [AbstractVal(self.full - {ax},
                                              f"axis_index({ax!r})")])
        elif name == "shard_map":
            self._eval_shard_map(env, eqn, preds, path, record, seq)
        elif name == "cond":
            self._eval_cond(env, eqn, preds, path, record, seq)
        elif name == "while":
            self._eval_while(env, eqn, preds, path, record, seq)
        elif name == "scan":
            self._eval_scan(env, eqn, preds, path, record, seq)
        elif name == "pallas_call":
            # opaque pure kernel: no collectives inside, outputs inherit
            # the meet of the inputs
            val = self._meet_inputs(env, eqn)
            self._bind(env, eqn, [val] * len(eqn.outvars))
        elif _sub_jaxprs(params):
            # transparent call-like primitives: pjit, closed_call,
            # custom_jvp/vjp_call, remat... — recurse into the (single)
            # sub-jaxpr with the eqn inputs
            subs = _sub_jaxprs(params)
            sub = _as_closed(subs[0])
            n = len(sub.jaxpr.invars)
            in_vals = [self._read(env, a) for a in eqn.invars]
            if len(in_vals) >= n:
                # call-like prims may append/prepend tangent args; keep
                # the trailing n (pjit passes exactly n)
                in_vals = in_vals[len(in_vals) - n:]
                out_vals, sub_seq = self.eval_closed(
                    sub, in_vals, preds, path, record)
                seq.extend(sub_seq)
                self._bind(env, eqn, out_vals[: len(eqn.outvars)])
            else:  # arity surprise: fall back to conservative meet
                val = self._meet_inputs(env, eqn)
                self._bind(env, eqn, [val] * len(eqn.outvars))
        else:
            # pure eqn: meet of the inputs
            val = self._meet_inputs(env, eqn)
            self._bind(env, eqn, [val] * len(eqn.outvars))

    # -- collectives --------------------------------------------------------

    def _eval_collective(self, env, eqn, preds, path, record, seq):
        name = eqn.primitive.name
        params = eqn.params
        axes = _norm_axes(params.get("axes", params.get("axis_name", ())))
        grouped = params.get("axis_index_groups") is not None
        in_val = self._meet_inputs(env, eqn)
        desc = f"{name} over {axes!r}"
        if name in REDUCTIONS and not grouped:
            out = AbstractVal(in_val.unif | set(axes), desc)
        elif name == "all_gather" and not grouped:
            out = AbstractVal(in_val.unif | set(axes), desc)
        elif name in ("all_to_all", "all_to_all_invariant", "psum_scatter"):
            out = AbstractVal(in_val.unif - set(axes), in_val.desc)
        else:  # ppermute / grouped / pbroadcast: preserve the input
            out = AbstractVal(in_val.unif, in_val.desc)
        self._bind(env, eqn, [out] * len(eqn.outvars))
        if record:
            self.sites.append(CollectiveSite(name, axes, preds, path))
        seq.append((name, axes))

    # -- structured control flow --------------------------------------------

    def _eval_shard_map(self, env, eqn, preds, path, record, seq):
        params = eqn.params
        inner = _as_closed(params["jaxpr"])
        in_names = params["in_names"]
        in_vals = []
        for names in in_names:
            used = set()
            for ax_tuple in names.values():
                used.update(_norm_axes(ax_tuple))
            if used:
                in_vals.append(AbstractVal(
                    self.full - used,
                    f"shard_map input sharded over {tuple(sorted(used))}"))
            else:
                in_vals.append(AbstractVal(self.full, "replicated input"))
        out_vals, sub_seq = self.eval_closed(inner, in_vals, preds,
                                             f"{path}/shard_map", record)
        seq.extend(sub_seq)
        self._bind(env, eqn, out_vals)

    def _eval_cond(self, env, eqn, preds, path, record, seq):
        branches = eqn.params["branches"]
        idx_val = self._read(env, eqn.invars[0])
        op_vals = [self._read(env, a) for a in eqn.invars[1:]]
        pred = Pred("cond", idx_val.unif, idx_val.desc, path)
        sub_preds = preds + (pred,)
        branch_outs, branch_seqs = [], []
        for b, bj in enumerate(branches):
            outs, bseq = self.eval_closed(bj, op_vals, sub_preds,
                                          f"{path}[branch {b}]", record)
            branch_outs.append(outs)
            branch_seqs.append(tuple(bseq))
        out_vals = []
        for outs in zip(*branch_outs):
            val = outs[0]
            for o in outs[1:]:
                val = val.meet(o)
            out_vals.append(AbstractVal(val.unif & pred.unif, val.desc))
        self._bind(env, eqn, out_vals)
        if record:
            self.conds.append(CondRecord(pred, path, tuple(branch_seqs)))
        # R2 sequence merging: agreeing branches contribute their shared
        # sequence upward; disagreeing ones poison the parent with a
        # mismatch marker
        if len(set(branch_seqs)) == 1:
            seq.extend(branch_seqs[0])
        else:
            seq.append(MISMATCH)

    def _eval_while(self, env, eqn, preds, path, record, seq):
        params = eqn.params
        cn, bn = params["cond_nconsts"], params["body_nconsts"]
        invals = [self._read(env, a) for a in eqn.invars]
        cconsts, bconsts = invals[:cn], invals[cn:cn + bn]
        init = invals[cn + bn:]
        carry = list(init)
        pred = None
        for _ in range(_MAX_FIXPOINT_SWEEPS):
            pred_outs, _ = self.eval_closed(
                params["cond_jaxpr"], cconsts + carry, preds,
                f"{path}/while.cond", record=False)
            pred = Pred("while", pred_outs[0].unif, pred_outs[0].desc,
                        f"{path}/while.cond")
            body_outs, _ = self.eval_closed(
                params["body_jaxpr"], bconsts + carry, preds + (pred,),
                f"{path}/while.body", record=False)
            new = [i.meet(b) for i, b in zip(init, body_outs)]
            if [v.unif for v in new] == [v.unif for v in carry]:
                carry = new
                break
            carry = new
        else:  # pragma: no cover - lattice is finite, cannot happen
            raise RuntimeError(f"uniformity fixpoint diverged at {path}")
        # stable: one recording pass through cond + body
        pred_outs, _ = self.eval_closed(
            params["cond_jaxpr"], cconsts + carry, preds,
            f"{path}/while.cond", record)
        pred = Pred("while", pred_outs[0].unif, pred_outs[0].desc,
                    f"{path}/while.cond")
        body_outs, body_seq = self.eval_closed(
            params["body_jaxpr"], bconsts + carry, preds + (pred,),
            f"{path}/while.body", record)
        seq.extend(body_seq)
        outs = [AbstractVal(i.meet(b).unif & pred.unif, i.meet(b).desc)
                for i, b in zip(init, body_outs)]
        self._bind(env, eqn, outs)

    def _eval_scan(self, env, eqn, preds, path, record, seq):
        params = eqn.params
        nc, ncar = params["num_consts"], params["num_carry"]
        invals = [self._read(env, a) for a in eqn.invars]
        consts, init, xs = invals[:nc], invals[nc:nc + ncar], invals[nc + ncar:]
        carry = list(init)
        for _ in range(_MAX_FIXPOINT_SWEEPS):
            outs, _ = self.eval_closed(
                params["jaxpr"], consts + carry + xs, preds,
                f"{path}/scan.body", record=False)
            new = [i.meet(b) for i, b in zip(init, outs[:ncar])]
            if [v.unif for v in new] == [v.unif for v in carry]:
                carry = new
                break
            carry = new
        else:  # pragma: no cover
            raise RuntimeError(f"uniformity fixpoint diverged at {path}")
        outs, body_seq = self.eval_closed(
            params["jaxpr"], consts + carry + xs, preds,
            f"{path}/scan.body", record)
        seq.extend(body_seq)
        self._bind(env, eqn, list(outs[:ncar]) + list(outs[ncar:]))


def analyze_jaxpr(closed_jaxpr, mesh_axes: Sequence[str],
                  in_vals: Optional[Sequence[AbstractVal]] = None
                  ) -> Analysis:
    """Walk a closed jaxpr and return the collective sites, cond
    records, and output lattice values.

    ``mesh_axes`` is the full mesh the program runs on (pod axis
    included for batched programs).  Top-level inputs default to
    uniform-everywhere, which matches host-level values entering a
    jitted program before any shard_map (the shard_map eqn re-seeds
    its body's inputs from ``in_names``); pass explicit ``in_vals``
    when analyzing a bare shard_map *body* jaxpr directly."""
    w = _Walker(mesh_axes)
    cj = _as_closed(closed_jaxpr)
    if in_vals is None:
        in_vals = [AbstractVal(w.full, "program input")
                   for _ in cj.jaxpr.invars]
    out_vals, _ = w.eval_closed(cj, list(in_vals), (), "", record=True)
    return Analysis(mesh_axes=tuple(mesh_axes), sites=w.sites,
                    conds=w.conds, out_vals=out_vals)
