"""Distributed device-side graph construction (born-sharded graphs).

The Graph500 discipline (and the paper's §7 setup) is that *generation
and CSR/DCSC construction are themselves distributed* — the host never
materializes the edge list.  This module builds ``Blocked1DGraph`` /
``BlockedGraph`` shards entirely on device:

  1. **generate** — each device draws its slice [k*m/p, (k+1)*m/p) of
     the counter-based R-MAT stream (graph/rmat.py): the stream is a
     pure function of (seed, edge index), so the union of shard slices
     is bit-identical for every device count p.
  2. **owner-route** — every edge is emitted in both directions
     (symmetrization before routing) and shipped to the owner of its
     destination vertex with the same capped-bucket tiled
     ``lax.all_to_all`` idiom the level exchanges use: scatter records
     into a static (p_dest, cap_route) bucket buffer, one all_to_all,
     sentinel-fill past each bucket's count.  The 2D build routes in
     two hops — along the "model" axis to the block *column* owner
     (bj = u // nc), then along "data" to the block *row* owner
     (bi = v // nr) — so each hop is a plain single-axis all_to_all.
     Bucket overflow is detected on device and raised loudly on host
     (``route_slack`` inflates the comm_model.plan_cap_route caps).
  3. **dedup shard-locally** — self-loops were dropped pre-routing;
     received records are lexsorted by (source, local dest) and
     first-occurrence-compacted.  Dedup commutes with owner routing
     (ownership is a function of the edge), so the per-shard edge sets
     are bit-identical to host ``preprocess`` + ``build_blocked*``.
  4. **build formats in place** — CSR/CSC/DCSC/strip-DCSC pointer
     arrays per shard, padded to the global static capacities.

Static shapes force a **two-phase** scheme: phase 1 returns the routed
+ deduped edges (static (p*cap_route,) buffers that stay on device) and
per-shard scalar stats (nnz, nzc, max segment sizes, overflow flags) —
the ONLY values pulled to host; phase 2 consumes the host-planned
capacities (cap, cap_seg, cap_nzc — the same rounding rules as the host
builders) and emits format arrays bit-identical to
``build_blocked_1d`` / ``build_blocked`` on the same edge set.

The resulting graph dataclasses carry sharded ``jax.Array`` fields;
``BFSEngine`` ships them without a host round-trip, so scale 18+ builds
+ traverses where the host path would thrash.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import comm_model
from repro.core.compat import shard_map
from repro.core.partition import make_partition, make_partition_1d
from repro.graph.formats import Blocked1DGraph, BlockedGraph, _round_up
from repro.graph.rmat import rmat_edges_counter, rmat_edges_counter_jax
from repro.launch.mesh import COL_AXIS, ROW_AXIS
from repro.runtime.retry import CapacityOverflow, RetryAttempt


@dataclass(frozen=True)
class BuildSpec:
    """Everything that determines the generated graph, hashable into the
    checkpoint store's config hash.  The edge stream is the counter
    stream of ``rmat_edges_counter``; graphs are always symmetrized
    (Graph500 undirected discipline)."""
    scale: int
    edge_factor: int = 16
    seed: int = 1
    a: float = 0.57
    b: float = 0.19
    c: float = 0.19

    @property
    def n(self) -> int:
        return 1 << self.scale

    @property
    def m_input(self) -> int:
        return self.edge_factor << self.scale

    def validate(self):
        if self.scale > 30:
            raise ValueError(f"scale={self.scale} > 30 overflows int32 "
                             f"vertex ids on x64-disabled devices")
        if self.m_input >= 1 << 32:
            raise ValueError(f"m_input={self.m_input} exhausts the uint32 "
                             f"counter space")


def _route(ru, rv, ok, dest, p_dest: int, cap_route: int, axis: str,
           sentinel_u: int, sentinel_v: int):
    """One capped-bucket all_to_all routing round (the MoE/fold idiom):
    scatter records into (p_dest, cap_route) per-destination buckets,
    exchange along ``axis``, return flat received records + overflow
    stats.  Records with ok=False are dropped; bucket slots past a
    bucket's count carry (sentinel_u, sentinel_v)."""
    nrec = ru.shape[0]
    dest = jnp.where(ok, dest, p_dest).astype(jnp.int32)
    counts = jnp.bincount(dest, length=p_dest + 1)
    start_b = jnp.concatenate([jnp.zeros(1, counts.dtype),
                               jnp.cumsum(counts)])
    order = jnp.argsort(dest, stable=True)
    du, dv, dd = ru[order], rv[order], dest[order]
    slot = jnp.arange(nrec, dtype=jnp.int32) - start_b[dd].astype(jnp.int32)
    flat = jnp.where((dd < p_dest) & (slot < cap_route),
                     dd * cap_route + slot, p_dest * cap_route)
    su = jnp.full(p_dest * cap_route, sentinel_u, jnp.int32
                  ).at[flat].set(du, mode="drop")
    sv = jnp.full(p_dest * cap_route, sentinel_v, jnp.int32
                  ).at[flat].set(dv, mode="drop")
    send = jnp.stack([su.reshape(p_dest, cap_route),
                      sv.reshape(p_dest, cap_route)], axis=-1)
    recv = lax.all_to_all(send, axis, split_axis=0, concat_axis=0)
    k = lax.axis_index(axis)
    # wire accounting: records actually destined off-device this round
    sent = jnp.sum(counts[:p_dest]) - counts[k]
    over = jnp.maximum(jnp.max(counts[:p_dest]) - cap_route, 0)
    return (recv[..., 0].reshape(-1), recv[..., 1].reshape(-1),
            sent.astype(jnp.int32), over.astype(jnp.int32))


def _dedup_sorted(u, v, sent_u: int, sent_v: int):
    """Lexsort records by (u, v), drop sentinels + duplicates, compact
    unique records to the front (tail re-sentineled).  Returns compacted
    (u, v) and the unique count."""
    r = u.shape[0]
    order = jnp.lexsort((v, u))        # primary u, secondary v
    su, sv = u[order], v[order]
    valid = su < sent_u
    prev_u = jnp.concatenate([jnp.full(1, -1, su.dtype), su[:-1]])
    prev_v = jnp.concatenate([jnp.full(1, -1, sv.dtype), sv[:-1]])
    uniq = valid & ~((su == prev_u) & (sv == prev_v))
    nnz = jnp.sum(uniq).astype(jnp.int32)
    pos = jnp.where(uniq, jnp.cumsum(uniq) - 1, r)
    cu = jnp.full(r, sent_u, jnp.int32).at[pos].set(su, mode="drop")
    cv = jnp.full(r, sent_v, jnp.int32).at[pos].set(sv, mode="drop")
    return cu, cv, nnz


def _first_occurrence(cu, nnz, n_sentinel: int, cap_nz: int):
    """(jc, cp)-style doubly-compressed pointers over a front-compacted
    primary-sorted array: unique primaries (sentinel-padded) + their
    first-occurrence indices (tail = nnz), matching the host builders'
    np.unique(..., return_index=True) layout."""
    r = cu.shape[0]
    valid = jnp.arange(r) < nnz
    prev = jnp.concatenate([jnp.full(1, -1, cu.dtype), cu[:-1]])
    newcol = valid & (cu != prev)
    # drop index must clear BOTH targets: cp is one entry longer than jc
    colpos = jnp.where(newcol, jnp.cumsum(newcol) - 1, cap_nz + 1)
    jc = jnp.full(cap_nz, n_sentinel, jnp.int32
                  ).at[colpos].set(cu, mode="drop")
    cp = jnp.full(cap_nz + 1, nnz, jnp.int32
                  ).at[colpos].set(jnp.arange(r, dtype=jnp.int32),
                                   mode="drop")
    nzc = jnp.sum(newcol).astype(jnp.int32)
    # per-primary segment lengths -> max column degree
    seg = jnp.where(valid, jnp.cumsum(newcol) - 1, r)
    seg_len = jnp.bincount(seg, length=r + 1)[:r]
    maxdeg = jnp.max(seg_len).astype(jnp.int32)
    return jc, cp, nzc, maxdeg


def _scatter_front(vals, nnz, cap: int, fill: int = 0):
    """First ``nnz`` entries of ``vals`` into a (cap,) zero/fill-padded
    array (the host builders' zero-padded block rows)."""
    r = vals.shape[0]
    idx = jnp.where(jnp.arange(r) < nnz, jnp.arange(r), cap)
    return jnp.full(cap, fill, jnp.int32).at[idx].set(vals, mode="drop")


# ---------------------------------------------------------------------------
# 1D strip build
# ---------------------------------------------------------------------------


def dist_build_1d(spec: BuildSpec, p: int, mesh, *, align: int = 128,
                  cap_pad: int = 128, route_slack: float = 1.5,
                  row_axis: str = ROW_AXIS,
                  ) -> Tuple[Blocked1DGraph, Dict[str, Any]]:
    """Device-side distributed build of the 1D row-strip format.

    Bit-identical to ``build_blocked_1d(rmat_graph(..., generator=
    "counter"), p, align, cap_pad)`` — same edge set, same sort orders,
    same capacity rounding — but no edge array ever exists on host:
    only per-shard scalar stats cross the device boundary."""
    spec.validate()
    part = make_partition_1d(spec.n, p, align)
    chunk, n_pad = part.chunk, part.n
    m_input = spec.m_input
    m_per = -(-m_input // p)                     # static per-device slice
    nrec = 2 * m_per
    cap_route = comm_model.plan_cap_route(nrec, p, spec.a, spec.b,
                                          slack=route_slack)
    r_buf = p * cap_route

    def phase1():
        k = lax.axis_index(row_axis)
        start = jnp.asarray(k, jnp.uint32) * jnp.uint32(m_per)
        u, v = rmat_edges_counter_jax(spec.scale, m_per, start,
                                      spec.edge_factor, spec.a, spec.b,
                                      spec.c, spec.seed)
        in_stream = (jnp.arange(m_per, dtype=jnp.uint32) + start) \
            < jnp.uint32(m_input)
        # symmetrize pre-routing: both directions of every kept edge
        ru = jnp.concatenate([u, v])
        rv = jnp.concatenate([v, u])
        ok = (ru != rv) & jnp.concatenate([in_stream, in_stream])
        dest = rv // chunk
        gu, gv, sent, over = _route(ru, rv, ok, dest, p, cap_route,
                                    row_axis, n_pad, chunk)
        v_loc = jnp.where(gu < n_pad, gv - dest_base(k), chunk)

        cu, cv, nnz = _dedup_sorted(gu, v_loc, n_pad, chunk)
        valid = jnp.arange(r_buf) < nnz
        prev = jnp.concatenate([jnp.full(1, -1, jnp.int32), cu[:-1]])
        newcol = valid & (cu != prev)
        nzc = jnp.sum(newcol).astype(jnp.int32)
        seg = jnp.where(valid, jnp.cumsum(newcol) - 1, r_buf)
        maxdeg = jnp.max(jnp.bincount(seg, length=r_buf + 1)[:r_buf])
        deg = jnp.bincount(jnp.where(valid, cv, chunk),
                           length=chunk + 1)[:chunk].astype(jnp.int32)
        stats = jnp.stack([nnz, nzc, maxdeg.astype(jnp.int32), over, sent])
        return (cu.reshape(1, r_buf), cv.reshape(1, r_buf),
                deg.reshape(1, chunk), stats.reshape(1, -1))

    def dest_base(k):
        return jnp.asarray(k, jnp.int32) * chunk

    p1 = jax.jit(shard_map(phase1, mesh=mesh, in_specs=(),
                           out_specs=(P(row_axis), P(row_axis),
                                      P(row_axis), P(row_axis)),
                           check_vma=False))
    t0 = time.perf_counter()
    cu_all, cv_all, deg_all, stats_all = p1()
    stats = np.asarray(stats_all)                # (p, 5) scalars only
    t1 = time.perf_counter()
    if stats[:, 3].max() > 0:
        raise CapacityOverflow(
            f"1D routing bucket overflow by {int(stats[:, 3].max())} "
            f"records (cap_route={cap_route}); rebuild with a larger "
            f"route_slack (> {route_slack})",
            cap_name="route_slack", cap_value=route_slack)
    nnz = stats[:, 0].astype(np.int64)
    cap = _round_up(max(int(nnz.max()), 1), cap_pad)
    cap_nzc = _round_up(max(int(stats[:, 1].max()), 1), 8)
    maxdeg_col = int(stats[:, 2].max())
    m = int(nnz.sum())

    def phase2(cu, cv, deg):
        cu, cv, deg = cu[0], cv[0], deg[0]
        nnz_l = jnp.sum(cu < n_pad).astype(jnp.int32)
        edge_src = _scatter_front(cu, nnz_l, cap)
        row_idx = _scatter_front(cv, nnz_l, cap)
        # bottom-up orientation: CSR by local dest row
        order = jnp.lexsort((cu, cv))
        bu, bv = cu[order], cv[order]
        col_idx = _scatter_front(bu, nnz_l, cap)
        edge_dst = _scatter_front(bv, nnz_l, cap)
        cnt = jnp.bincount(cv, length=chunk + 1)[:chunk]
        row_ptr = jnp.concatenate(
            [jnp.zeros(1, jnp.int32), jnp.cumsum(cnt).astype(jnp.int32)])
        jc, cp, nzc_l, _ = _first_occurrence(cu, nnz_l, n_pad, cap_nzc)
        one = lambda x: x.reshape((1,) + x.shape)
        return (one(edge_src), one(row_idx), one(row_ptr), one(col_idx),
                one(edge_dst), one(jc), one(cp), nnz_l.reshape(1),
                nzc_l.reshape(1), one(deg))

    p2 = jax.jit(shard_map(
        phase2, mesh=mesh,
        in_specs=(P(row_axis), P(row_axis), P(row_axis)),
        out_specs=tuple(P(row_axis) for _ in range(10)),
        check_vma=False))
    (edge_src, row_idx, row_ptr, col_idx, edge_dst, jc, cp,
     nnz_d, nzc_d, deg_A) = p2(cu_all, cv_all, deg_all)
    jax.block_until_ready(edge_src)
    t2 = time.perf_counter()

    graph = Blocked1DGraph(
        part=part, m_input=m_input, m=m,
        edge_src=edge_src, row_idx=row_idx, row_ptr=row_ptr,
        col_idx=col_idx, edge_dst=edge_dst, jc=jc, cp=cp,
        nnz=nnz_d, nzc=nzc_d, deg_A=deg_A,
        cap=cap, cap_nzc=cap_nzc, maxdeg_col=maxdeg_col, col_ptr=None)
    info = {
        "build_s": t2 - t0, "gen_route_s": t1 - t0, "format_s": t2 - t1,
        "cap_route": cap_route, "m": m, "m_input": m_input,
        "build_teps": m_input / max(t2 - t0, 1e-12),
        "route_words_measured": float(stats[:, 4].sum()),
        "route_words_expected": comm_model.build_route_1d_words(m_input, p),
        "route_words_padded": comm_model.build_route_padded_words(
            p, cap_route),
    }
    return graph, info


# ---------------------------------------------------------------------------
# 2D checkerboard build
# ---------------------------------------------------------------------------


def dist_build_2d(spec: BuildSpec, pr: int, pc: int, mesh, *,
                  align: int = 128, cap_pad: int = 128,
                  route_slack: float = 1.5, row_axis: str = ROW_AXIS,
                  col_axis: str = COL_AXIS,
                  ) -> Tuple[BlockedGraph, Dict[str, Any]]:
    """Device-side distributed build of the 2D (pr x pc) checkerboard,
    bit-identical to ``build_blocked`` on the counter edge stream.

    Owner routing is TWO single-axis hops (column owner along "model",
    then row owner along "data") instead of one p-way exchange — each
    hop is the same capped-bucket all_to_all as the 1D build, and the
    closed form is comm_model.build_route_2d_words."""
    spec.validate()
    part = make_partition(spec.n, pr, pc, align)
    nr, nc, chunk, p = part.nr, part.nc, part.chunk, part.p
    n_pad = part.n
    m_input = spec.m_input
    m_per = -(-m_input // p)
    nrec = 2 * m_per
    cap_r1 = comm_model.plan_cap_route(nrec, pc, spec.a, spec.b,
                                       slack=route_slack)
    # hop 2 buckets the whole column's records by block row: the worst
    # row bucket of the worst column takes skew(pr)*skew(pc) of the
    # 2*m_input records a processor row generated
    rec1 = pc * cap_r1
    cap_r2 = comm_model.plan_cap_route(
        int(nrec * pc * comm_model.rmat_strip_skew(pc, spec.a, spec.b)),
        pr, spec.a, spec.b, slack=route_slack)
    cap_r2 = min(cap_r2, _round_up(rec1, 32))    # can't exceed hop-1 recv
    r_buf = pr * cap_r2

    def phase1():
        i = lax.axis_index(row_axis)
        j = lax.axis_index(col_axis)
        k = i * pc + j
        start = jnp.asarray(k, jnp.uint32) * jnp.uint32(m_per)
        u, v = rmat_edges_counter_jax(spec.scale, m_per, start,
                                      spec.edge_factor, spec.a, spec.b,
                                      spec.c, spec.seed)
        in_stream = (jnp.arange(m_per, dtype=jnp.uint32) + start) \
            < jnp.uint32(m_input)
        ru = jnp.concatenate([u, v])
        rv = jnp.concatenate([v, u])
        ok = (ru != rv) & jnp.concatenate([in_stream, in_stream])
        # hop 1: to block-column owner bj = u // nc along the model axis
        g1u, g1v, sent1, over1 = _route(ru, rv, ok, ru // nc, pc, cap_r1,
                                        col_axis, n_pad, n_pad)
        ok1 = g1u < n_pad
        # hop 2: to block-row owner bi = v // nr along the data axis
        g2u, g2v, sent2, over2 = _route(g1u, g1v, ok1, g1v // nr, pr,
                                        cap_r2, row_axis, n_pad, n_pad)
        ok2 = g2u < n_pad
        u_loc = jnp.where(ok2, g2u - j * nc, nc)
        v_loc = jnp.where(ok2, g2v - i * nr, nr)

        # dedup in CSC order (primary u_loc, secondary v_loc)
        cu, cv, nnz = _dedup_sorted(u_loc, v_loc, nc, nr)
        valid = jnp.arange(r_buf) < nnz
        prev = jnp.concatenate([jnp.full(1, -1, jnp.int32), cu[:-1]])
        newc = valid & (cu != prev)
        nzc = jnp.sum(newc).astype(jnp.int32)
        segc = jnp.where(valid, jnp.cumsum(newc) - 1, r_buf)
        maxdeg = jnp.max(jnp.bincount(segc, length=r_buf + 1)[:r_buf])
        # CSR-side stats: row counts give nzr + the max chunk-segment
        rcnt = jnp.bincount(jnp.where(valid, cv, nr), length=nr + 1)[:nr]
        nzr = jnp.sum(rcnt > 0).astype(jnp.int32)
        max_seg = jnp.max(jnp.sum(rcnt.reshape(pc, chunk), axis=1))
        # degree: strip in-degree (psum over the block row) sliced to
        # this device's layout-A chunk (i*pc+j <-> strip offset j*chunk)
        strip_deg = lax.psum(rcnt, col_axis)
        deg = lax.dynamic_slice(strip_deg, (j * chunk,), (chunk,))
        stats = jnp.stack([nnz, nzc, nzr, maxdeg.astype(jnp.int32),
                           max_seg.astype(jnp.int32), over1 + over2,
                           sent1 + sent2])
        return (cu.reshape(1, 1, r_buf), cv.reshape(1, 1, r_buf),
                deg.reshape(1, 1, chunk).astype(jnp.int32),
                stats.reshape(1, 1, -1))

    axes = (row_axis, col_axis)
    p1 = jax.jit(shard_map(phase1, mesh=mesh, in_specs=(),
                           out_specs=tuple(P(*axes) for _ in range(4)),
                           check_vma=False))
    t0 = time.perf_counter()
    cu_all, cv_all, deg_all, stats_all = p1()
    stats = np.asarray(stats_all).reshape(p, -1)
    t1 = time.perf_counter()
    if stats[:, 5].max() > 0:
        raise CapacityOverflow(
            f"2D routing bucket overflow by {int(stats[:, 5].max())} "
            f"records (cap_r1={cap_r1}, cap_r2={cap_r2}); rebuild with "
            f"a larger route_slack (> {route_slack})",
            cap_name="route_slack", cap_value=route_slack)
    nnz = stats[:, 0].astype(np.int64)
    cap = _round_up(max(int(nnz.max()), 1), cap_pad)
    cap_nzc = _round_up(max(int(stats[:, 1].max()), 1), 8)
    cap_nzr = _round_up(max(int(stats[:, 2].max()), 1), 8)
    maxdeg_col = int(stats[:, 3].max())
    cap_seg = _round_up(max(int(stats[:, 4].max()), 1), cap_pad)
    m = int(nnz.sum())

    def phase2(cu, cv, deg):
        cu, cv, deg = cu[0, 0], cv[0, 0], deg[0, 0]
        nnz_l = jnp.sum(cu < nc).astype(jnp.int32)
        # CSC orientation (already sorted by u_loc, v_loc)
        edge_src = _scatter_front(cu, nnz_l, cap)
        row_idx = _scatter_front(cv, nnz_l, cap)
        ccnt = jnp.bincount(cu, length=nc + 1)[:nc]
        col_ptr = jnp.concatenate(
            [jnp.zeros(1, jnp.int32), jnp.cumsum(ccnt).astype(jnp.int32)])
        jc, cp, _, _ = _first_occurrence(cu, nnz_l, nc, cap_nzc)
        # CSR orientation
        order = jnp.lexsort((cu, cv))
        bu, bv = cu[order], cv[order]
        col_idx = _scatter_front(bu, nnz_l, cap + cap_seg)
        edge_dst = _scatter_front(bv, nnz_l, cap + cap_seg)
        rcnt = jnp.bincount(cv, length=nr + 1)[:nr]
        row_ptr = jnp.concatenate(
            [jnp.zeros(1, jnp.int32), jnp.cumsum(rcnt).astype(jnp.int32)])
        jr, rp, _, _ = _first_occurrence(bv, nnz_l, nr, cap_nzr)
        seg_ptr = row_ptr[jnp.arange(pc + 1) * chunk]
        nzc_l = jnp.sum(ccnt > 0).astype(jnp.int32)
        nzr_l = jnp.sum(rcnt > 0).astype(jnp.int32)
        one = lambda x: x.reshape((1, 1) + x.shape)
        return (one(col_ptr), one(row_idx), one(edge_src), one(row_ptr),
                one(col_idx), one(edge_dst), one(seg_ptr), one(jc),
                one(cp), one(jr), one(rp), nnz_l.reshape(1, 1),
                nzc_l.reshape(1, 1), nzr_l.reshape(1, 1), one(deg))

    p2 = jax.jit(shard_map(
        phase2, mesh=mesh, in_specs=tuple(P(*axes) for _ in range(3)),
        out_specs=tuple(P(*axes) for _ in range(15)),
        check_vma=False))
    (col_ptr, row_idx, edge_src, row_ptr, col_idx, edge_dst, seg_ptr,
     jc, cp, jr, rp, nnz_d, nzc_d, nzr_d, deg_A) = p2(cu_all, cv_all,
                                                      deg_all)
    jax.block_until_ready(row_idx)
    t2 = time.perf_counter()

    graph = BlockedGraph(
        part=part, m_input=m_input, m=m,
        col_ptr=col_ptr, row_idx=row_idx, edge_src=edge_src,
        row_ptr=row_ptr, col_idx=col_idx, edge_dst=edge_dst,
        seg_ptr=seg_ptr, jc=jc, cp=cp, jr=jr, rp=rp,
        nnz=nnz_d, nzc=nzc_d, nzr=nzr_d, deg_A=deg_A,
        cap=cap, cap_seg=cap_seg, maxdeg_col=maxdeg_col)
    info = {
        "build_s": t2 - t0, "gen_route_s": t1 - t0, "format_s": t2 - t1,
        "cap_route": (cap_r1, cap_r2), "m": m, "m_input": m_input,
        "build_teps": m_input / max(t2 - t0, 1e-12),
        "route_words_measured": float(stats[:, 6].sum()),
        "route_words_expected": comm_model.build_route_2d_words(
            m_input, pr, pc),
        "route_words_padded": comm_model.build_route_padded_words(
            pc, cap_r1) + comm_model.build_route_padded_words(pr, cap_r2),
    }
    return graph, info


def dist_build(spec: BuildSpec, decomposition: str, mesh, grid,
               max_attempts: int = 3, **kw):
    """Dispatch on decomposition: "1d"/"1ds" build the strip format on
    p = prod(grid) devices, "2d" the checkerboard.  ``grid`` is (pr, pc),
    or an int / 1-tuple p for the 1D formats.

    Routing-bucket overflow self-heals: the single-shot builders
    (``dist_build_1d`` / ``dist_build_2d``) still raise
    ``CapacityOverflow`` loudly, but this dispatcher catches it,
    doubles ``route_slack``, and rebuilds — at most ``max_attempts``
    total attempts, each recorded in ``info["retry_log"]`` (empty when
    the first attempt routes clean).  The rebuilt graph is bit-identical
    to a first-try build with the final slack: the edge stream is a
    pure function of (seed, edge index) and slack only sizes the
    exchange buckets.  Exhaustion re-raises with the full escalation
    history attached."""
    if isinstance(grid, int):
        grid = (grid, 1)
    elif len(grid) == 1:
        grid = (grid[0], 1)
    pr, pc = grid
    if decomposition in ("1d", "1ds"):
        build = lambda **k: dist_build_1d(spec, pr * pc, mesh, **k)
    elif decomposition == "2d":
        build = lambda **k: dist_build_2d(spec, pr, pc, mesh, **k)
    else:
        raise ValueError(f"unknown decomposition {decomposition!r}")

    slack = float(kw.pop("route_slack", 1.5))
    history = []
    for attempt in range(1, max(1, max_attempts) + 1):
        try:
            graph, info = build(route_slack=slack, **kw)
        except CapacityOverflow as e:
            history.append(RetryAttempt(
                attempt=attempt, cap_name="route_slack", cap_value=slack,
                outcome="overflow", detail={"error": str(e)}))
            if attempt >= max(1, max_attempts):
                raise CapacityOverflow(
                    f"routing overflow persisted through {attempt} build "
                    f"attempts: {e}", cap_name="route_slack",
                    cap_value=slack, history=history) from e
            slack *= 2.0
            continue
        if history:
            history.append(RetryAttempt(
                attempt=attempt, cap_name="route_slack", cap_value=slack,
                outcome="ok", detail={}))
        info["retry_log"] = [a.to_json() for a in history]
        return graph, info


# ---------------------------------------------------------------------------
# Host shard regeneration (GraphStore integrity repair)
# ---------------------------------------------------------------------------
#
# A corrupted or truncated store shard is regenerated from the SAME
# counter stream the device build consumed: ``rmat_edges_counter`` is a
# pure function of (seed, edge index), and shard contents depend only on
# the edge subset owned by that shard — so the host twin below filters
# the full stream down to one shard's edges and replays phases 1+2 with
# numpy, producing arrays bit-identical to the device build (the store
# re-checks the stored CRC after regeneration to prove it).

_REGEN_STEP = 1 << 22     # stream chunking: bounds peak host memory


def _pad_i32(vals, cap: int, fill: int = 0) -> np.ndarray:
    out = np.full(cap, fill, np.int32)
    out[: len(vals)] = vals
    return out


def _shard_edges(spec: BuildSpec, keep) -> Tuple[np.ndarray, np.ndarray]:
    """Deduped (u, v) int64 pairs of the symmetrized self-loop-free
    stream for which ``keep(u, v)`` holds, sorted by (u, v) — the CSC
    dedup order of ``_dedup_sorted``."""
    us, vs = [], []
    for s in range(0, spec.m_input, _REGEN_STEP):
        cnt = min(_REGEN_STEP, spec.m_input - s)
        u, v = rmat_edges_counter(spec.scale, spec.edge_factor, spec.a,
                                  spec.b, spec.c, spec.seed, start=s,
                                  count=cnt)
        for a, b in ((u, v), (v, u)):
            mask = (a != b) & keep(a, b)
            if mask.any():
                us.append(a[mask])
                vs.append(b[mask])
    if not us:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64))
    pairs = np.stack([np.concatenate(us), np.concatenate(vs)], axis=1)
    pairs = np.unique(pairs, axis=0)     # lexsort by (u, v) + dedup
    return pairs[:, 0], pairs[:, 1]


def regen_shard_1d(spec: BuildSpec, part, k: int, *, cap: int,
                   cap_nzc: int) -> Dict[str, np.ndarray]:
    """Strip ``k``'s Blocked1DGraph arrays (shard slice, no leading
    block dim), bit-identical to ``dist_build_1d`` phase 2."""
    chunk, n_pad = part.chunk, part.n
    lo = k * chunk
    gu, gv = _shard_edges(spec,
                          lambda a, b: (b >= lo) & (b < lo + chunk))
    u = gu.astype(np.int32)
    v = (gv - lo).astype(np.int32)
    nnz = len(u)
    order = np.lexsort((u, v))           # CSR: primary v, secondary u
    cnt = np.bincount(v, minlength=chunk)[:chunk] if nnz \
        else np.zeros(chunk, np.int64)
    uu, fi = (np.unique(u, return_index=True) if nnz
              else (np.zeros(0, np.int32), np.zeros(0, np.int64)))
    cp = np.full(cap_nzc + 1, nnz, np.int32)
    cp[: len(fi)] = fi.astype(np.int32)
    # the optional uncompressed strip CSC pointer (host builds with
    # with_col_ptr=True persist it; regen_shard filters to the stored
    # field set)
    col_ptr = np.zeros(n_pad + 1, np.int64)
    col_ptr[1:] = np.cumsum(np.bincount(u, minlength=n_pad)[:n_pad]) \
        if nnz else 0
    return {
        "col_ptr": col_ptr.astype(np.int32),
        "edge_src": _pad_i32(u, cap),
        "row_idx": _pad_i32(v, cap),
        "row_ptr": np.concatenate(
            [[0], np.cumsum(cnt)]).astype(np.int32),
        "col_idx": _pad_i32(u[order], cap),
        "edge_dst": _pad_i32(v[order], cap),
        "jc": _pad_i32(uu, cap_nzc, fill=n_pad),
        "cp": cp,
        "nnz": np.int32(nnz),
        "nzc": np.int32(len(uu)),
        "deg_A": cnt.astype(np.int32),
    }


def regen_shard_2d(spec: BuildSpec, part, i: int, j: int, *, cap: int,
                   cap_seg: int, cap_nzc: int,
                   cap_nzr: int) -> Dict[str, np.ndarray]:
    """Block ``(i, j)``'s BlockedGraph arrays (shard slice, no leading
    block dims), bit-identical to ``dist_build_2d`` phase 2."""
    nr, nc, chunk, pc = part.nr, part.nc, part.chunk, part.pc
    gu, gv = _shard_edges(
        spec, lambda a, b: (a // nc == j) & (b // nr == i))
    u = (gu - j * nc).astype(np.int32)
    v = (gv - i * nr).astype(np.int32)
    nnz = len(u)
    ccnt = np.bincount(u, minlength=nc)[:nc] if nnz \
        else np.zeros(nc, np.int64)
    rcnt = np.bincount(v, minlength=nr)[:nr] if nnz \
        else np.zeros(nr, np.int64)
    uu, fiu = (np.unique(u, return_index=True) if nnz
               else (np.zeros(0, np.int32), np.zeros(0, np.int64)))
    cp = np.full(cap_nzc + 1, nnz, np.int32)
    cp[: len(fiu)] = fiu.astype(np.int32)
    order = np.lexsort((u, v))           # CSR: primary v, secondary u
    bv = v[order]
    vv, fiv = (np.unique(bv, return_index=True) if nnz
               else (np.zeros(0, np.int32), np.zeros(0, np.int64)))
    rp = np.full(cap_nzr + 1, nnz, np.int32)
    rp[: len(fiv)] = fiv.astype(np.int32)
    row_ptr = np.concatenate([[0], np.cumsum(rcnt)]).astype(np.int32)
    # deg_A: whole-row strip in-degree sliced to this block's layout-A
    # chunk — needs edges from EVERY column block of row i
    dlo = i * nr + j * chunk
    du, dv = _shard_edges(
        spec, lambda a, b: (b >= dlo) & (b < dlo + chunk))
    deg = (np.bincount((dv - dlo).astype(np.int64),
                       minlength=chunk)[:chunk] if len(dv)
           else np.zeros(chunk, np.int64))
    return {
        "col_ptr": np.concatenate(
            [[0], np.cumsum(ccnt)]).astype(np.int32),
        "row_idx": _pad_i32(v, cap),
        "edge_src": _pad_i32(u, cap),
        "row_ptr": row_ptr,
        "col_idx": _pad_i32(u[order], cap + cap_seg),
        "edge_dst": _pad_i32(bv, cap + cap_seg),
        "seg_ptr": row_ptr[np.arange(pc + 1) * chunk],
        "jc": _pad_i32(uu, cap_nzc, fill=nc),
        "cp": cp,
        "jr": _pad_i32(vv, cap_nzr, fill=nr),
        "rp": rp,
        "nnz": np.int32(nnz),
        "nzc": np.int32(int(np.sum(ccnt > 0))),
        "nzr": np.int32(int(np.sum(rcnt > 0))),
        "deg_A": deg.astype(np.int32),
    }


def regen_shard(spec: BuildSpec, graph_kind: str, part, shard: int,
                scalars: Dict[str, int],
                fields: Dict[str, Any]) -> Dict[str, np.ndarray]:
    """Regenerate one store shard from its BuildSpec + stored geometry.

    ``shard`` is the flat shard index (k for strips, i*pc + j for the
    checkerboard); ``scalars``/``fields`` are the store meta entries
    (fields supply the capacities the scalars don't carry:
    cap_nzc/cap_nzr from the jc/jr shapes).  Returns only the arrays
    named in ``fields``."""
    if graph_kind == "Blocked1DGraph":
        arrs = regen_shard_1d(
            spec, part, shard, cap=int(scalars["cap"]),
            cap_nzc=int(fields["jc"][0][-1]))
    elif graph_kind == "BlockedGraph":
        arrs = regen_shard_2d(
            spec, part, shard // part.pc, shard % part.pc,
            cap=int(scalars["cap"]), cap_seg=int(scalars["cap_seg"]),
            cap_nzc=int(fields["jc"][0][-1]),
            cap_nzr=int(fields["jr"][0][-1]))
    else:
        raise ValueError(f"cannot regenerate shards of {graph_kind!r}")
    return {k: arrs[k] for k in fields}
