"""k-hop neighbor sampler (GraphSAGE-style, static shapes).

``minibatch_lg`` needs a real sampler: this one is jit-compatible and runs
on device as part of the train step.  It is a *batched BFS frontier
expansion with fanout caps* — the paper's frontier machinery specialized
to sampling (DESIGN.md §Arch-applicability).

Occurrence-tree formulation (static shapes): every sampled neighbor is a
fresh "occurrence node"; layer l has B*f1*...*fl occurrences.  Edges
connect child occurrences to their parent occurrence, giving a forest the
GNN aggregates bottom-up.  Zero-degree vertices self-sample (self-loop).
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp


def khop_sample(key: jax.Array, row_ptr: jnp.ndarray, col_idx: jnp.ndarray,
                seeds: jnp.ndarray, fanouts: Sequence[int]
                ) -> Dict[str, jnp.ndarray]:
    """Returns occurrence-tree arrays:
       node_ids (n_sub,), senders/receivers (E_sub,), edge_mask (E_sub,),
       layer_sizes (static python list).
    Occurrence 0..B-1 are the seeds (loss is taken on them)."""
    layers = [seeds.astype(jnp.int32)]
    offsets = [0]
    senders, receivers = [], []
    total = seeds.shape[0]
    for li, f in enumerate(fanouts):
        parents = layers[-1]                       # (P,) vertex ids
        P = parents.shape[0]
        key, sub = jax.random.split(key)
        deg = (row_ptr[parents + 1] - row_ptr[parents]).astype(jnp.int32)
        r = jax.random.randint(sub, (P, f), 0, 1 << 30)
        safe_deg = jnp.maximum(deg, 1)[:, None]
        eidx = row_ptr[parents][:, None] + (r % safe_deg)
        child = jnp.where(deg[:, None] > 0, col_idx[eidx],
                          parents[:, None])       # self-sample if isolated
        child = child.reshape(-1).astype(jnp.int32)
        parent_occ = offsets[-1] + jnp.arange(P, dtype=jnp.int32)
        child_occ = total + jnp.arange(P * f, dtype=jnp.int32)
        senders.append(child_occ)
        receivers.append(jnp.repeat(parent_occ, f))
        offsets.append(total)
        layers.append(child)
        total += P * f
    node_ids = jnp.concatenate(layers)
    return {
        "node_ids": node_ids,
        "senders": jnp.concatenate(senders),
        "receivers": jnp.concatenate(receivers),
        "edge_mask": jnp.ones((sum(l.shape[0] for l in layers[1:]),),
                              jnp.float32),
        "n_seed": seeds.shape[0],
    }
