"""Synthetic dataset builders for the GNN shape cells + input specs.

Full-size graphs appear only as ShapeDtypeStructs in the dry-run; smoke
tests build *reduced* instances with the same structure (the instructions'
reduced-config rule).  The Twitter standin for the paper's Fig. 9 lives in
graph/rmat.py (scale_free_standin).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs.base import GNNConfig, GNNShape
from repro.graph.rmat import rmat_edges_counter, rmat_graph

# host materialization bounds: full rmat_graph up to here (the legacy
# stream every pinned graph uses), counter-stream slices beyond
_MAX_HOST_SCALE = 16
_MAX_HOST_EF = 64
_MAX_COUNTER_SCALE = 30   # int32 vertex-id ceiling of the counter stream


def _edges_for(n_nodes: int, n_edges: int, seed: int = 0):
    scale = max(int(np.ceil(np.log2(max(n_nodes, 2)))), 2)
    ef = max(1, n_edges // (1 << scale))
    if scale <= _MAX_HOST_SCALE and ef <= _MAX_HOST_EF:
        # legacy level-vectorized stream: pinned small graphs unchanged
        e = rmat_graph(scale, edge_factor=ef, seed=seed)
        s, d = e.src, e.dst
    elif scale <= _MAX_COUNTER_SCALE and (ef << scale) < 2 ** 32:
        # large request: slice exactly the edges needed from the
        # counter-based stream — O(n_edges) memory at any scale, never
        # a silently clamped smaller workload
        s, d = rmat_edges_counter(scale, edge_factor=ef, seed=seed,
                                  start=0, count=min(n_edges, ef << scale))
    else:
        raise ValueError(
            f"requested graph needs R-MAT scale={scale}, "
            f"edge_factor={ef} (n_nodes={n_nodes}, n_edges={n_edges}), "
            f"beyond the counter stream's limits (scale <= "
            f"{_MAX_COUNTER_SCALE}, edge_factor*2^scale < 2^32); build "
            f"it with graph.dist_build instead of _edges_for — earlier "
            f"versions silently clamped to scale<=16/edge_factor<=64, "
            f"which changed the workload without warning")
    s = (s % n_nodes).astype(np.int32)
    d = (d % n_nodes).astype(np.int32)
    if s.size >= n_edges:
        return s[:n_edges], d[:n_edges]
    reps = int(np.ceil(n_edges / s.size))
    return (np.tile(s, reps)[:n_edges],
            np.tile(d, reps)[:n_edges])


def build_gnn_batch(cfg: GNNConfig, shape: GNNShape, *, reduce_to: int = 0,
                    seed: int = 0) -> Dict[str, np.ndarray]:
    """Concrete (numpy) batch. reduce_to > 0 scales node/edge counts down
    for smoke tests while preserving structure."""
    rng = np.random.default_rng(seed)
    if shape.kind == "batched":
        n_g = max(shape.batch_graphs // (reduce_to or 1), 2) if reduce_to \
            else shape.batch_graphs
        npg, epg = shape.n_nodes, shape.n_edges
        N, E = n_g * npg, n_g * epg
        s = rng.integers(0, npg, E).astype(np.int32)
        d = rng.integers(0, npg, E).astype(np.int32)
        off = np.repeat(np.arange(n_g, dtype=np.int32) * npg, epg)
        senders, receivers = s + off, d + off
        graph_ids = np.repeat(np.arange(n_g, dtype=np.int32), npg)
        labels = rng.integers(0, cfg.n_classes, n_g).astype(np.int32)
        d_feat = 16
    else:
        scale = reduce_to or 1
        N = max(shape.n_nodes // scale, 64)
        E = max(shape.n_edges // scale, 256)
        senders, receivers = _edges_for(N, E, seed)
        graph_ids = np.zeros(N, np.int32)
        labels = rng.integers(0, cfg.n_classes, N).astype(np.int32)
        d_feat = shape.d_feat or 16
    x = rng.normal(size=(N, d_feat)).astype(np.float32)
    pos = rng.normal(size=(N, 3)).astype(np.float32)
    species = rng.integers(0, 8, N).astype(np.int32)
    rel = pos[senders] - pos[receivers]
    e_feat = np.concatenate(
        [rel, np.linalg.norm(rel, axis=1, keepdims=True)], 1).astype(
        np.float32)
    return {
        "x": x, "pos": pos, "species": species,
        "senders": senders.astype(np.int32),
        "receivers": receivers.astype(np.int32),
        "edge_mask": np.ones(len(senders), np.float32),
        "e_feat": e_feat, "graph_ids": graph_ids, "labels": labels,
        "targets": rng.normal(size=(N, 3)).astype(np.float32),
    }
