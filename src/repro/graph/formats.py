"""Blocked 2D graph storage: CSR/CSC per block + DCSC/DCSR compressions.

The adjacency block at device (i,j) is T[R_i, C_j], T[v,u]=1 iff edge u->v
(pre-transposed, paper §4.1).  Two orientations are stored, as the paper
stores each undirected adjacency twice (§5.1):

  * CSC-by-source-column  -> top-down SpMSV   (frontier u -> children v)
  * CSR-by-dest-row       -> bottom-up scan   (unvisited v -> parents u)

DCSC (doubly compressed sparse columns, Buluc & Gilbert) compresses the
O(n*pr) aggregate col_ptr down to O(nnz-columns); DCSR does the same for
rows.  Both share the index arrays with their uncompressed counterparts,
so a ``storage`` mode only changes which *pointer* arrays are shipped.

All arrays are statically padded to per-block capacity ``cap`` (XLA needs
static shapes); ``nnz[(i,j)]`` masks the tail.  ``edge_src``/``edge_dst``
are explicit per-edge locals for the edge-parallel jnp path (the Pallas
kernels use the pointer arrays instead).

Strip DCSC and the §5.1 storage charge against 1D
-------------------------------------------------
The paper's §5.1 argument for 2D is a *storage* argument against 1D: a
1D row strip T[V_i, :] spans all n source columns, so an uncompressed
CSC col_ptr costs n+1 words on EVERY processor — O(n*p) aggregate, vs
O(n*(pr+pc)) for the 2D blocks — which is why this repo's 1D path was
edge-parallel dense-only at first.  Strip DCSC answers that charge the
same way DCSC answers it for hypersparse 2D blocks: ``Blocked1DGraph``
stores, per strip, only the non-empty *global* source columns ``jc``
with pointers ``cp`` into the CSC-ordered ``row_idx`` — O(nzc) <= O(m/p)
words per strip, independent of n.  Because the ids in ``jc`` are
global, the strip SpMSV (kernels/spmsv/strip.py) tests them directly
against the allgathered frontier bitmap with col_offset = 0; no O(n)
pointer array is ever materialized on the device.  The uncompressed
strip ``col_ptr`` can be built host-side on request
(``with_col_ptr=True``, opt-in) so benchmarks can *measure* the
blow-up Fig. 6-style via ``storage_words("csr")`` vs
``storage_words("dcsc")``.
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Any, Dict

import jax
import numpy as np

from repro.core.partition import (Partition1D, Partition2D, make_partition,
                                  make_partition_1d)
from repro.graph.rmat import EdgeList


def _round_up(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


@dataclass
class BlockedGraph:
    part: Partition2D
    m_input: int
    m: int
    # --- top-down orientation (CSC by source column u) ---
    col_ptr: np.ndarray   # (pr, pc, nc+1) i32
    row_idx: np.ndarray   # (pr, pc, cap)  i32  local dest v, CSC order
    edge_src: np.ndarray  # (pr, pc, cap)  i32  local src u, CSC order
    # --- bottom-up orientation (CSR by dest row v) ---
    row_ptr: np.ndarray   # (pr, pc, nr+1) i32
    col_idx: np.ndarray   # (pr, pc, cap)  i32  local src u, CSR order
    edge_dst: np.ndarray  # (pr, pc, cap)  i32  local dest v, CSR order
    seg_ptr: np.ndarray   # (pr, pc, pc+1) i32  CSR ptr at chunk-segment bounds
    # --- hypersparse pointer compressions ---
    jc: np.ndarray        # (pr, pc, cap_nzc)   i32 non-empty source cols
    cp: np.ndarray        # (pr, pc, cap_nzc+1) i32 ptrs into row_idx
    jr: np.ndarray        # (pr, pc, cap_nzr)   i32 non-empty dest rows
    rp: np.ndarray        # (pr, pc, cap_nzr+1) i32 ptrs into col_idx
    # --- per-block / per-vertex metadata ---
    nnz: np.ndarray       # (pr, pc) i32
    nzc: np.ndarray       # (pr, pc) i32
    nzr: np.ndarray       # (pr, pc) i32
    deg_A: np.ndarray     # (pr, pc, chunk) i32 out-degree, layout-A chunks
    cap: int
    cap_seg: int
    maxdeg_col: int       # max CSC column-segment length over all blocks

    # ------------------------------------------------------------------
    def device_arrays(self) -> Dict[str, Any]:
        """The pytree of arrays shipped to devices (everything but
        part/ints).  Fields may be host np.ndarrays (host-built graphs)
        or already-sharded jax.Arrays (born-sharded device builds /
        store loads) — the engine ships the former and passes the
        latter through without a host round-trip."""
        out = {}
        for f in fields(self):
            v = getattr(self, f.name)
            if isinstance(v, (np.ndarray, jax.Array)):
                out[f.name] = v
        return out

    def storage_words(self, mode: str) -> Dict[str, int]:
        """64-bit-word accounting per §5.1 (we store i32 => 0.5 words each,
        reported in raw index units for clarity)."""
        p = self.part.p
        idx = 2 * self.cap * p                       # row_idx + col_idx
        if mode == "csr":
            ptr = (self.part.nc + 1 + self.part.nr + 1) * p
        elif mode == "dcsc":
            ptr = int(2 * (self.nzc.sum() + self.nzr.sum()) + 2 * p)
        else:
            raise ValueError(mode)
        return {"index_i32": idx, "pointer_i32": int(ptr),
                "total_i32": idx + int(ptr)}


@dataclass
class Blocked1DGraph:
    """1D row-strip storage: processor i holds T[V_i, :] (all edges into
    its owned vertices), in both orientations.

    Unlike the 2D format, source-column indices are *global* ids (the
    strip spans every column), so the top-down SpMSV and bottom-up scan
    run with ``col_offset = 0`` against the full allgathered frontier.
    Two top-down pointer compressions are available per strip:

      * strip CSC ``col_ptr`` (n+1 words/processor) — the O(n) aggregate
        blow-up the paper's §5.1 charges against 1D; kept so the charge
        can be *measured* (``storage_words("csr")``)
      * strip DCSC ``(jc, cp)`` over non-empty global source columns —
        O(nzc) words, the compression that makes 1D compressed formats
        viable (``storage_words("dcsc")``, kernels/spmsv/strip.py)

    The dense edge-parallel path (``edge_src``/``row_idx``) needs no
    pointers at all.
    """
    part: Partition1D
    m_input: int
    m: int
    # --- top-down orientation (edges sorted by source col u) ---
    edge_src: np.ndarray  # (p, cap) i32 GLOBAL source u
    row_idx: np.ndarray   # (p, cap) i32 local dest v
    # --- bottom-up orientation (CSR by dest row v) ---
    row_ptr: np.ndarray   # (p, chunk+1) i32
    col_idx: np.ndarray   # (p, cap) i32 GLOBAL source u, CSR order
    edge_dst: np.ndarray  # (p, cap) i32 local dest v, CSR order
    # --- strip DCSC (top-down pointer compression) ---
    jc: np.ndarray        # (p, cap_nzc)   i32 non-empty GLOBAL source cols
    cp: np.ndarray        # (p, cap_nzc+1) i32 ptrs into row_idx
    # --- per-block / per-vertex metadata ---
    nnz: np.ndarray       # (p,) i32
    nzc: np.ndarray       # (p,) i32 non-empty columns per strip
    deg_A: np.ndarray     # (p, chunk) i32 out-degree of owned vertices
    cap: int
    cap_nzc: int
    maxdeg_col: int       # max CSC column-segment length over all strips
    col_ptr: "np.ndarray | None" = None   # (p, n+1) i32, the §5.1 blow-up

    def device_arrays(self) -> Dict[str, Any]:
        out = {}
        for f in fields(self):
            v = getattr(self, f.name)
            if isinstance(v, (np.ndarray, jax.Array)):
                out[f.name] = v
        return out

    def storage_words(self, mode: str) -> Dict[str, int]:
        """i32 accounting mirroring BlockedGraph.storage_words(mode).
        Index arrays are mode-independent; "csr" charges the (n+1)-per-
        strip top-down col_ptr (the §5.1 1D blow-up) while "dcsc"
        charges 2*nzc+2 per strip — the strip-DCSC pointer savings.
        Both include the (chunk+1)-per-strip bottom-up row_ptr."""
        p = self.part.p
        idx = 2 * self.cap * p
        bu_ptr = (self.part.chunk + 1) * p
        if mode == "csr":
            ptr = (self.part.n + 1) * p + bu_ptr
        elif mode == "dcsc":
            ptr = int(2 * self.nzc.sum()) + 2 * p + bu_ptr
        else:
            raise ValueError(mode)
        return {"index_i32": idx, "pointer_i32": int(ptr),
                "total_i32": idx + int(ptr)}


def build_blocked_1d(edges: EdgeList, p: int, align: int = 128,
                     cap_pad: int = 128,
                     with_col_ptr: bool = False) -> Blocked1DGraph:
    """Partition edges u->v by owner of the *destination* v into p row
    strips; pad every strip to a common static capacity.

    ``with_col_ptr=True`` additionally materializes the (p, n+1)
    uncompressed strip CSC pointer — O(n*p) host words, wanted ONLY by
    the local_mode="kernel" / storage="csr" comparison cell of Fig. 6
    (measuring that blow-up is its purpose); dense and strip-DCSC runs
    never ship it, so it is opt-in."""
    part = make_partition_1d(edges.n, p, align)
    chunk = part.chunk
    u, v = edges.src.astype(np.int64), edges.dst.astype(np.int64)
    blk = v // chunk
    v_loc = v - blk * chunk

    nnz = np.bincount(blk, minlength=p).astype(np.int64)
    cap = _round_up(max(int(nnz.max()), 1), cap_pad)

    def _orient(primary, secondary):
        """Sort by (block, primary, secondary), return padded per-block
        (primary, secondary) arrays."""
        order = np.lexsort((secondary, primary, blk))
        pb, pp, ps = blk[order], primary[order], secondary[order]
        pri = np.zeros((p, cap), dtype=np.int64)
        sec = np.zeros((p, cap), dtype=np.int64)
        starts = np.concatenate([[0], np.cumsum(nnz)])
        for b in range(p):
            k = int(nnz[b])
            pri[b, :k] = pp[starts[b]:starts[b] + k]
            sec[b, :k] = ps[starts[b]:starts[b] + k]
        return pri, sec

    # top-down orientation: sorted by global source u
    edge_src, row_idx = _orient(u, v_loc)
    # bottom-up orientation: CSR by local dest row v
    edge_dst_, col_idx_ = _orient(v_loc, u)
    row_ptr = np.zeros((p, chunk + 1), dtype=np.int64)
    flat = blk * np.int64(chunk) + v_loc
    cnt = np.bincount(flat, minlength=p * chunk).reshape(p, chunk)
    row_ptr[:, 1:] = np.cumsum(cnt, axis=1)

    # strip DCSC: doubly compressed (jc, cp) over the strip's non-empty
    # GLOBAL source columns.  edge_src rows are sorted by u, so unique's
    # first-occurrence indices are exactly the CSC segment starts.
    nzc = np.zeros(p, dtype=np.int64)
    uniq = []
    maxdeg_col = 0
    for b in range(p):
        k = int(nnz[b])
        uu, first = np.unique(edge_src[b, :k], return_index=True)
        if k == 0:
            uu, first = uu[:0], first[:0]
        uniq.append((uu, first))
        nzc[b] = uu.size
        if uu.size:
            maxdeg_col = max(maxdeg_col,
                             int(np.diff(np.append(first, k)).max()))
    cap_nzc = _round_up(max(int(nzc.max()), 1), 8)
    jc = np.full((p, cap_nzc), part.n, dtype=np.int64)       # sentinel = n
    cp = np.zeros((p, cap_nzc + 1), dtype=np.int64)
    for b in range(p):
        uu, first = uniq[b]
        jc[b, :uu.size] = uu
        cp[b, :uu.size] = first
        cp[b, uu.size:] = int(nnz[b])

    col_ptr = None
    if with_col_ptr:
        # the uncompressed strip CSC pointer — (n+1) words per strip,
        # materialized on request so the §5.1 blow-up is measurable
        cnt = np.bincount(blk * np.int64(part.n) + u,
                          minlength=p * part.n).reshape(p, part.n)
        col_ptr = np.zeros((p, part.n + 1), dtype=np.int64)
        col_ptr[:, 1:] = np.cumsum(cnt, axis=1)

    deg = np.bincount(u, minlength=part.n).astype(np.int64)

    def _i32(x):
        return np.ascontiguousarray(x.astype(np.int32))

    return Blocked1DGraph(
        part=part, m_input=edges.m_input, m=edges.m,
        edge_src=_i32(edge_src), row_idx=_i32(row_idx),
        row_ptr=_i32(row_ptr), col_idx=_i32(col_idx_),
        edge_dst=_i32(edge_dst_),
        jc=_i32(jc), cp=_i32(cp),
        nnz=_i32(nnz), nzc=_i32(nzc), deg_A=_i32(deg.reshape(p, chunk)),
        cap=cap, cap_nzc=cap_nzc, maxdeg_col=maxdeg_col,
        col_ptr=None if col_ptr is None else _i32(col_ptr),
    )


def build_blocked(edges: EdgeList, pr: int, pc: int, align: int = 128,
                  cap_pad: int = 128) -> BlockedGraph:
    part = make_partition(edges.n, pr, pc, align)
    nr, nc, chunk, p = part.nr, part.nc, part.chunk, part.p
    u, v = edges.src.astype(np.int64), edges.dst.astype(np.int64)
    bi = v // nr          # block row   (dest strip)
    bj = u // nc          # block col   (source strip)
    blk = bi * pc + bj
    u_loc = (u - bj * nc).astype(np.int64)
    v_loc = (v - bi * nr).astype(np.int64)

    nnz = np.bincount(blk, minlength=p).astype(np.int64)
    cap = _round_up(max(int(nnz.max()), 1), cap_pad)

    def _orient(primary, secondary, n_primary):
        """Sort edges by (block, primary, secondary); build padded per-block
        primary-ptr, secondary index array, explicit primary array."""
        order = np.lexsort((secondary, primary, blk))
        pb, pp, ps = blk[order], primary[order], secondary[order]
        ptr = np.zeros((p, n_primary + 1), dtype=np.int64)
        # counts of (block, primary)
        flat = pb * np.int64(n_primary) + pp
        cnt = np.bincount(flat, minlength=p * n_primary).reshape(p, n_primary)
        ptr[:, 1:] = np.cumsum(cnt, axis=1)
        sec = np.zeros((p, cap), dtype=np.int64)
        pri = np.zeros((p, cap), dtype=np.int64)
        starts = np.concatenate([[0], np.cumsum(nnz)])
        for b in range(p):
            k = int(nnz[b])
            sec[b, :k] = ps[starts[b]:starts[b] + k]
            pri[b, :k] = pp[starts[b]:starts[b] + k]
        return ptr, sec, pri, cnt

    # CSC: primary = source col u, secondary = dest row v
    col_ptr, row_idx, edge_src, col_cnt = _orient(u_loc, v_loc, nc)
    # CSR: primary = dest row v, secondary = source col u
    row_ptr, col_idx, edge_dst, row_cnt = _orient(v_loc, u_loc, nr)

    # DCSC / DCSR: compress pointer arrays over non-empty primaries
    def _compress(ptr, cnt, n_primary):
        nz_counts = (cnt > 0).sum(axis=1)
        cap_nz = _round_up(max(int(nz_counts.max()), 1), 8)
        jx = np.full((p, cap_nz), n_primary, dtype=np.int64)   # sentinel
        px = np.zeros((p, cap_nz + 1), dtype=np.int64)
        for b in range(p):
            nz = np.flatnonzero(cnt[b])
            jx[b, :nz.size] = nz
            px[b, :nz.size] = ptr[b, nz]
            px[b, nz.size:] = ptr[b, n_primary]
        return jx, px, nz_counts, cap_nz

    jc, cp, nzc, _ = _compress(col_ptr, col_cnt, nc)
    jr, rp, nzr, _ = _compress(row_ptr, row_cnt, nr)

    # CSR ptr at chunk-segment boundaries (bottom-up sub-step windows)
    seg_bounds = np.arange(pc + 1) * chunk
    seg_ptr = row_ptr[:, seg_bounds]
    cap_seg = int(np.diff(seg_ptr, axis=1).max())
    cap_seg = _round_up(max(cap_seg, 1), cap_pad)
    # pad the CSR-orientation index arrays so a cap_seg-wide dynamic slice
    # starting at any segment boundary stays in bounds
    tail = np.zeros((p, cap_seg), dtype=np.int64)
    col_idx = np.concatenate([col_idx, tail], axis=1)
    edge_dst = np.concatenate([edge_dst, tail], axis=1)

    deg = np.bincount(u, minlength=part.n).astype(np.int64)
    deg_A = deg.reshape(pr, pc, chunk)

    def _blk(x):  # (p, ...) -> (pr, pc, ...) int32
        return np.ascontiguousarray(x.reshape(pr, pc, *x.shape[1:]).astype(np.int32))

    return BlockedGraph(
        part=part, m_input=edges.m_input, m=edges.m,
        col_ptr=_blk(col_ptr), row_idx=_blk(row_idx), edge_src=_blk(edge_src),
        row_ptr=_blk(row_ptr), col_idx=_blk(col_idx), edge_dst=_blk(edge_dst),
        seg_ptr=_blk(seg_ptr),
        jc=_blk(jc), cp=_blk(cp), jr=_blk(jr), rp=_blk(rp),
        nnz=_blk(nnz), nzc=_blk(nzc), nzr=_blk(nzr),
        deg_A=deg_A.astype(np.int32),
        cap=cap, cap_seg=cap_seg, maxdeg_col=int(col_cnt.max()),
    )
