"""Graph500 R-MAT generator (Chakrabarti et al.) + preprocessing.

Parameters follow the paper (§7.2): a,b,c,d = 0.57,0.19,0.19,0.05 and
edge factor (average degree) 16 unless stated.  ``scale`` means 2**scale
vertices.  Preprocessing prunes self loops and duplicate edges (the paper
does the same); graphs are used undirected, so edges are symmetrized.

Two generators coexist:

  * ``rmat_edges`` — the original sequential ``np.random.default_rng``
    level-draw generator; kept verbatim so every pinned bench/test
    graph is unchanged.
  * ``rmat_edges_counter`` (+ jax/Pallas twins) — a STATELESS
    counter-based generator: edge e's quadrant path is a pure function
    of (seed, e, level) through a uint32 bit-mixing hash, so any slice
    [start, start+count) of the edge stream is reproducible
    independently of how many shards the stream is split over.  This is
    the reproducibility contract the distributed device-side build
    (graph/dist_build.py) relies on: shard k of p generates edges
    [k*m/p, (k+1)*m/p) and the union is bit-identical for every p.
    The numpy and jnp implementations are bit-identical (pure uint32
    wrapping arithmetic, thresholds precomputed as Python ints).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

_M32 = 0xFFFFFFFF
_GOLDEN = 0x9E3779B9          # counter -> hash stream spreading constant


def _mix_int(x: int) -> int:
    """fmix32-style avalanche on a Python int (mod 2**32)."""
    x &= _M32
    x ^= x >> 16
    x = (x * 0x7FEB352D) & _M32
    x ^= x >> 15
    x = (x * 0x846CA68B) & _M32
    x ^= x >> 16
    return x


def level_salt(seed: int, level: int) -> int:
    """Per-(seed, level) salt for the counter hash — a Python int so the
    numpy / jnp / Pallas twins consume literally the same constant."""
    return _mix_int((int(seed) * 0x85EBCA6B + level * 0xC2B2AE35
                     + 0x27D4EB2F) & _M32)


def rmat_thresholds(a: float, b: float, c: float) -> Tuple[int, int, int]:
    """Cumulative quadrant thresholds as exact uint32 comparands: a draw
    u ~ U[0, 2**32) picks quadrant a/b/c/d by u < t1 / t2 / t3 / else."""
    t1 = min(int(round(a * 2.0 ** 32)), _M32)
    t2 = min(int(round((a + b) * 2.0 ** 32)), _M32)
    t3 = min(int(round((a + b + c) * 2.0 ** 32)), _M32)
    return t1, t2, t3


def _counter_u32_np(idx: np.ndarray, salt: int) -> np.ndarray:
    """One uint32 hash draw per counter (numpy twin of the jnp mixer)."""
    x = (idx * np.uint32(_GOLDEN)) ^ np.uint32(salt)
    x ^= x >> np.uint32(16)
    x = x * np.uint32(0x7FEB352D)
    x ^= x >> np.uint32(15)
    x = x * np.uint32(0x846CA68B)
    x ^= x >> np.uint32(16)
    return x


def rmat_edges_counter(scale: int, edge_factor: int = 16, a: float = 0.57,
                       b: float = 0.19, c: float = 0.19, seed: int = 1,
                       start: int = 0, count: int | None = None,
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Edges [start, start+count) of the counter-based R-MAT stream of
    m_input = edge_factor * 2**scale edges, as int64 (src, dst).

    The slice is a pure function of (scale, ef, a, b, c, seed, start,
    count): generating the full stream in one call or in any shard
    split yields bit-identical edges."""
    m_input = edge_factor << scale
    if count is None:
        count = m_input - start
    if not 0 <= start <= start + count <= m_input:
        raise ValueError(f"slice [{start}, {start + count}) outside the "
                         f"{m_input}-edge stream")
    t1, t2, t3 = rmat_thresholds(a, b, c)
    idx = (np.arange(count, dtype=np.uint32)
           + np.uint32(start & _M32))          # counter mod 2**32
    src = np.zeros(count, dtype=np.int64)
    dst = np.zeros(count, dtype=np.int64)
    for level in range(scale):
        u = _counter_u32_np(idx, level_salt(seed, level))
        src_bit = u >= np.uint32(t2)
        dst_bit = ((u >= np.uint32(t1)) & (u < np.uint32(t2))) \
            | (u >= np.uint32(t3))
        src |= src_bit.astype(np.int64) << level
        dst |= dst_bit.astype(np.int64) << level
    return src, dst


@dataclass(frozen=True)
class EdgeList:
    n: int
    src: np.ndarray  # int64[m]
    dst: np.ndarray  # int64[m]
    m_input: int     # edge count *before* dedup/symmetrize (TEPS denominator)

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n).astype(np.int64)


def rmat_edges(scale: int, edge_factor: int = 16, a: float = 0.57,
               b: float = 0.19, c: float = 0.19, seed: int = 1,
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized R-MAT: returns (src, dst) int64 arrays of 2**scale*ef edges."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    d = 1.0 - a - b - c
    # P(dst_bit=1 | src_bit=0) = b/(a+b);  P(dst_bit=1 | src_bit=1) = d/(c+d)
    p_dst_given0 = b / ab
    p_dst_given1 = d / (c + d)
    for level in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        src_bit = r1 >= ab
        dst_bit = np.where(src_bit, r2 < p_dst_given1, r2 < p_dst_given0)
        src |= src_bit.astype(np.int64) << level
        dst |= dst_bit.astype(np.int64) << level
    return src, dst


def rmat_edges_counter_jax(scale: int, count: int, start,
                           edge_factor: int = 16, a: float = 0.57,
                           b: float = 0.19, c: float = 0.19, seed: int = 1):
    """jnp twin of ``rmat_edges_counter``: (src, dst) int32 arrays of
    ``count`` edges starting at traced/static ``start``.  Pure uint32
    wrapping arithmetic — bit-identical to the numpy twin — and safe
    under disabled x64 (scale <= 30 fits int32).  This is the per-shard
    generator the distributed build maps over devices."""
    import jax.numpy as jnp
    if scale > 30:
        raise ValueError(f"scale={scale} > 30 overflows int32 vertex ids "
                         f"on x64-disabled devices")
    t1, t2, t3 = rmat_thresholds(a, b, c)
    idx = (jnp.arange(count, dtype=jnp.uint32)
           + jnp.asarray(start, jnp.uint32))
    src = jnp.zeros(count, dtype=jnp.int32)
    dst = jnp.zeros(count, dtype=jnp.int32)
    for level in range(scale):
        x = (idx * jnp.uint32(_GOLDEN)) ^ jnp.uint32(level_salt(seed, level))
        x ^= x >> jnp.uint32(16)
        x = x * jnp.uint32(0x7FEB352D)
        x ^= x >> jnp.uint32(15)
        x = x * jnp.uint32(0x846CA68B)
        x ^= x >> jnp.uint32(16)
        src_bit = x >= jnp.uint32(t2)
        dst_bit = ((x >= jnp.uint32(t1)) & (x < jnp.uint32(t2))) \
            | (x >= jnp.uint32(t3))
        src = src | (src_bit.astype(jnp.int32) << level)
        dst = dst | (dst_bit.astype(jnp.int32) << level)
    return src, dst


def rmat_edges_counter_kernel(scale: int, count: int, start,
                              edge_factor: int = 16, a: float = 0.57,
                              b: float = 0.19, c: float = 0.19,
                              seed: int = 1, tile: int = 4096,
                              interpret: bool = True):
    """Pallas build of the per-shard counter generator: a grid program
    over ``tile``-edge blocks, each an independent VPU-width batch of
    uint32 mixing (no cross-tile state — the whole point of the
    counter RNG).  Bit-identical to the jnp/numpy twins; kept
    ``interpret=True`` by default for CPU CI, matching kernels/*.

    The TPU core PRNG (pltpu.prng_random_bits) is deliberately NOT used:
    its stream depends on how work is split over cores, which would
    break the shard-count-independence contract."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    if scale > 30:
        raise ValueError(f"scale={scale} > 30 overflows int32 vertex ids")
    if count % tile:
        tile = count if count < tile else \
            next(t for t in range(tile, 0, -1) if count % t == 0)
    t1, t2, t3 = rmat_thresholds(a, b, c)
    salts = tuple(level_salt(seed, lv) for lv in range(scale))

    def kernel(start_ref, src_ref, dst_ref):
        pid = pl.program_id(0)
        base = start_ref[0] + (pid * tile).astype(jnp.uint32)
        idx = jnp.arange(tile, dtype=jnp.uint32) + base
        s = jnp.zeros(tile, dtype=jnp.int32)
        d = jnp.zeros(tile, dtype=jnp.int32)
        for level in range(scale):
            x = (idx * jnp.uint32(_GOLDEN)) ^ jnp.uint32(salts[level])
            x ^= x >> jnp.uint32(16)
            x = x * jnp.uint32(0x7FEB352D)
            x ^= x >> jnp.uint32(15)
            x = x * jnp.uint32(0x846CA68B)
            x ^= x >> jnp.uint32(16)
            sb = (x >= jnp.uint32(t2)).astype(jnp.int32)
            db = (((x >= jnp.uint32(t1)) & (x < jnp.uint32(t2)))
                  | (x >= jnp.uint32(t3))).astype(jnp.int32)
            s = s | (sb << level)
            d = d | (db << level)
        src_ref[...] = s
        dst_ref[...] = d

    start = jnp.asarray(start, jnp.uint32).reshape(1)
    out = jax.ShapeDtypeStruct((count,), jnp.int32)
    src, dst = pl.pallas_call(
        kernel,
        grid=(count // tile,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)],  # start scalar
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,))] * 2,
        out_shape=[out, out],
        interpret=interpret,
    )(start)
    return src, dst


def preprocess(src: np.ndarray, dst: np.ndarray, n: int,
               symmetrize: bool = True) -> EdgeList:
    """Prune self-loops + duplicates; optionally symmetrize (undirected)."""
    m_input = int(src.shape[0])
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    key = src * np.int64(n) + dst
    _, idx = np.unique(key, return_index=True)
    return EdgeList(n=n, src=src[idx], dst=dst[idx], m_input=m_input)


def rmat_graph(scale: int, edge_factor: int = 16, seed: int = 1,
               a: float = 0.57, b: float = 0.19, c: float = 0.19,
               generator: str = "numpy") -> EdgeList:
    """Host-side generate + preprocess.  ``generator="numpy"`` is the
    original sequential-RNG stream (every pinned graph in the repo);
    ``generator="counter"`` draws the stateless counter stream — the
    SAME edges the distributed device build generates, so host-built and
    device-built graphs at one (scale, ef, seed) are comparable
    bit-for-bit."""
    if generator == "numpy":
        src, dst = rmat_edges(scale, edge_factor, a, b, c, seed)
    elif generator == "counter":
        src, dst = rmat_edges_counter(scale, edge_factor, a, b, c, seed)
    else:
        raise ValueError(f"unknown generator {generator!r} "
                         f"(have 'numpy', 'counter')")
    return preprocess(src, dst, 1 << scale)


def scale_free_standin(n: int, m_target: int, seed: int = 7) -> EdgeList:
    """Synthetic scale-free graph used as the Twitter-dataset standin
    (container is offline).  Preferential-attachment-flavored R-MAT with a
    heavier hub parameter, matching Twitter's skew qualitatively."""
    scale = int(np.ceil(np.log2(max(n, 2))))
    ef = max(1, m_target // (1 << scale))
    src, dst = rmat_edges(scale, ef, a=0.65, b=0.15, c=0.15, seed=seed)
    return preprocess(src, dst, 1 << scale)


def random_source(edges: EdgeList, rng: np.random.Generator) -> int:
    """A random root with at least one edge (Graph500 requirement)."""
    deg = edges.out_degrees()
    candidates = np.flatnonzero(deg > 0)
    return int(rng.choice(candidates))
