"""Graph500 R-MAT generator (Chakrabarti et al.) + preprocessing.

Parameters follow the paper (§7.2): a,b,c,d = 0.57,0.19,0.19,0.05 and
edge factor (average degree) 16 unless stated.  ``scale`` means 2**scale
vertices.  Preprocessing prunes self loops and duplicate edges (the paper
does the same); graphs are used undirected, so edges are symmetrized.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class EdgeList:
    n: int
    src: np.ndarray  # int64[m]
    dst: np.ndarray  # int64[m]
    m_input: int     # edge count *before* dedup/symmetrize (TEPS denominator)

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n).astype(np.int64)


def rmat_edges(scale: int, edge_factor: int = 16, a: float = 0.57,
               b: float = 0.19, c: float = 0.19, seed: int = 1,
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized R-MAT: returns (src, dst) int64 arrays of 2**scale*ef edges."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    d = 1.0 - a - b - c
    # P(dst_bit=1 | src_bit=0) = b/(a+b);  P(dst_bit=1 | src_bit=1) = d/(c+d)
    p_dst_given0 = b / ab
    p_dst_given1 = d / (c + d)
    for level in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        src_bit = r1 >= ab
        dst_bit = np.where(src_bit, r2 < p_dst_given1, r2 < p_dst_given0)
        src |= src_bit.astype(np.int64) << level
        dst |= dst_bit.astype(np.int64) << level
    return src, dst


def preprocess(src: np.ndarray, dst: np.ndarray, n: int,
               symmetrize: bool = True) -> EdgeList:
    """Prune self-loops + duplicates; optionally symmetrize (undirected)."""
    m_input = int(src.shape[0])
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    key = src * np.int64(n) + dst
    _, idx = np.unique(key, return_index=True)
    return EdgeList(n=n, src=src[idx], dst=dst[idx], m_input=m_input)


def rmat_graph(scale: int, edge_factor: int = 16, seed: int = 1,
               a: float = 0.57, b: float = 0.19, c: float = 0.19) -> EdgeList:
    src, dst = rmat_edges(scale, edge_factor, a, b, c, seed)
    return preprocess(src, dst, 1 << scale)


def scale_free_standin(n: int, m_target: int, seed: int = 7) -> EdgeList:
    """Synthetic scale-free graph used as the Twitter-dataset standin
    (container is offline).  Preferential-attachment-flavored R-MAT with a
    heavier hub parameter, matching Twitter's skew qualitatively."""
    scale = int(np.ceil(np.log2(max(n, 2))))
    ef = max(1, m_target // (1 << scale))
    src, dst = rmat_edges(scale, ef, a=0.65, b=0.15, c=0.15, seed=seed)
    return preprocess(src, dst, 1 << scale)


def random_source(edges: EdgeList, rng: np.random.Generator) -> int:
    """A random root with at least one edge (Graph500 requirement)."""
    deg = edges.out_degrees()
    candidates = np.flatnonzero(deg > 0)
    return int(rng.choice(candidates))
