"""AdamW + SGD-momentum, pure-pytree (no optax in this container).

Optimizer state shards exactly like the parameters (ZeRO-style: the state
PartitionSpecs are inherited from the param specs by the launcher), so
per-device optimizer memory scales down with the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    schedule: str = "cosine"       # "cosine" | "constant"
    total_steps: int = 10_000
    state_dtype: str = "float32"   # "bfloat16" halves optimizer traffic

    def init(self, params: Any) -> AdamWState:
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros_like(p, dtype=dt)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def _lr(self, step):
        warm = jnp.minimum(1.0, (step + 1) / max(self.warmup_steps, 1))
        if self.schedule == "cosine":
            frac = jnp.clip(step / max(self.total_steps, 1), 0.0, 1.0)
            base = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        else:
            base = 1.0
        return self.lr * warm * base

    def update(self, grads: Any, state: AdamWState, params: Any
               ) -> Tuple[Any, AdamWState]:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-12))
        step = state.step + 1
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2

        new_mu = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32)
                          + (1 - b1) * (g.astype(jnp.float32) * scale)
                          ).astype(m.dtype),
            state.mu, grads)
        new_nu = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32)
                          + (1 - b2) * (g.astype(jnp.float32) * scale) ** 2
                          ).astype(v.dtype),
            state.nu, grads)

        def upd(p, m, v):
            mh = m.astype(jnp.float32) / (1 - b1 ** step)
            vh = v.astype(jnp.float32) / (1 - b2 ** step)
            d = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(
                jnp.float32)
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype)

        new_p = jax.tree.map(upd, params, new_mu, new_nu)
        return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


@dataclasses.dataclass(frozen=True)
class SGDM:
    lr: float = 1e-2
    momentum: float = 0.9

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(self, grads, state, params):
        new_m = jax.tree.map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32),
            state, grads)
        new_p = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - self.lr * m).astype(p.dtype),
            params, new_m)
        return new_p, new_m
