"""Data-parallel train step with compressed gradient all-reduce.

Integrates optim/grad_compress into the DP loop: each replica computes
local grads, compresses (error-feedback top-k or int8), the *compressed
payload* crosses the wire (psum), and replicas apply identical updates.
Residuals stay replica-local.  At 1000+-node scale this converts the
fixed per-step DP all-reduce from O(P) to O(P*ratio) bytes.

The exchanged volume is what shrinks: for top-k the psum runs over the
scattered-dense payload here (XLA has no sparse all-reduce); on the real
fleet the payload is an (indices, values) allgather — volume accounting
in EXPERIMENTS reflects ids+values, and the *math* (what update gets
applied) is identical, which is what the convergence test checks."""
from __future__ import annotations

from typing import Callable

import jax
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map
from repro.optim.grad_compress import (ef_init, int8_dequantize,
                                       int8_quantize, topk_compress,
                                       topk_decompress)


def make_dp_compressed_step(loss_fn: Callable, opt, mesh, dp_axis: str,
                            mode: str = "topk", ratio: float = 0.05):
    """loss_fn(params, batch) -> scalar.  Returns jitted
    step((params, opt_state, ef_state), batch) -> (state, metrics) with
    batch sharded over dp_axis."""

    def body(state, batch):
        params, opt_state, ef = state
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = lax.pmean(loss, dp_axis)
        if mode == "topk":
            vals, idxs, ef = topk_compress(grads, ef, ratio)
            dense = topk_decompress(vals, idxs, grads)
            synced = jax.tree.map(lambda d: lax.pmean(d, dp_axis), dense)
        elif mode == "int8":
            qs, ss = int8_quantize(grads)
            deq = int8_dequantize(qs, ss, grads)
            synced = jax.tree.map(lambda d: lax.pmean(d, dp_axis), deq)
        else:
            synced = jax.tree.map(lambda g: lax.pmean(g, dp_axis), grads)
        new_p, new_o = opt.update(synced, opt_state, params)
        return (new_p, new_o, ef), {"loss": loss}

    def step(state, batch):
        mapped = shard_map(
            body, mesh=mesh,
            in_specs=((jax.tree.map(lambda _: P(), state[0]),
                       jax.tree.map(lambda _: P(), state[1]),
                       jax.tree.map(lambda _: P(), state[2])),
                      jax.tree.map(lambda _: P(dp_axis), batch)),
            out_specs=((jax.tree.map(lambda _: P(), state[0]),
                        jax.tree.map(lambda _: P(), state[1]),
                        jax.tree.map(lambda _: P(), state[2])),
                       {"loss": P()}),
            check_vma=False)
        return mapped(state, batch)

    return jax.jit(step)


def init_dp_state(params, opt):
    return (params, opt.init(params), ef_init(params))
