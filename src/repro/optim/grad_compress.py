"""Gradient compression for DP all-reduce: error-feedback top-k and int8
quantization.  At 1000+-node scale the DP gradient all-reduce is the
dominant fixed cost per step; top-k with error feedback (Stich et al.)
cuts it ~(1/ratio)x while provably converging; int8 halves it with
per-tensor scales.

Compression happens *before* the cross-pod reduction: compress -> psum of
sparse/quantized payload -> decompress; residuals stay local."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any


def ef_init(params: Any) -> EFState:
    return EFState(jax.tree.map(
        lambda p: jnp.zeros_like(p, jnp.float32), params))


def topk_compress(grads: Any, state: EFState, ratio: float = 0.01
                  ) -> Tuple[Any, Any, EFState]:
    """Returns (values, indices, new_state): per-leaf top-k magnitude
    entries of (grad + residual); the rest accumulates into the residual
    (error feedback)."""
    def one(g, r):
        gz = g.astype(jnp.float32) + r
        flat = gz.reshape(-1)
        k = max(1, int(flat.size * ratio))
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        picked = flat[idx]
        new_r = flat.at[idx].set(0.0).reshape(gz.shape)
        return picked, idx, new_r

    gl, treedef = jax.tree.flatten(grads)
    rl = jax.tree.leaves(state.residual)
    outs = [one(g, r) for g, r in zip(gl, rl)]
    vals = treedef.unflatten([o[0] for o in outs])
    idxs = treedef.unflatten([o[1] for o in outs])
    res = treedef.unflatten([o[2] for o in outs])
    return vals, idxs, EFState(res)


def topk_decompress(vals: Any, idxs: Any, like: Any) -> Any:
    def one(v, i, g):
        flat = jnp.zeros((g.size,), jnp.float32).at[i].set(v)
        return flat.reshape(g.shape).astype(g.dtype)
    return jax.tree.map(one, vals, idxs, like)


def int8_quantize(grads: Any) -> Tuple[Any, Any]:
    def one(g):
        g32 = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        return q, scale
    gl, treedef = jax.tree.flatten(grads)
    outs = [one(g) for g in gl]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def int8_dequantize(qs: Any, ss: Any, like: Any) -> Any:
    return jax.tree.map(
        lambda q, s, g: (q.astype(jnp.float32) * s).astype(g.dtype),
        qs, ss, like)
