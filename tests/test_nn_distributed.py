"""Multi-device NN-substrate tests (subprocess, forced device count)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow   # multi-device subprocess cases, >60s each

_HERE = os.path.dirname(__file__)
_MAIN = os.path.join(_HERE, "_dist_nn_main.py")


@pytest.mark.parametrize("mode,n_dev", [
    ("moe_ep", 8), ("embedding", 8), ("dp_compress", 4),
    ("elastic_graph", 16),
])
def test_distributed_nn(mode, n_dev):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, _MAIN, str(n_dev), mode],
                       capture_output=True, text=True, timeout=1800,
                       env=env)
    assert r.returncode == 0, f"{mode}:\n{r.stdout}\n{r.stderr[-3000:]}"
    assert f"OK {mode}" in r.stdout
