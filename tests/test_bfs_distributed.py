"""Multi-device distributed BFS system tests.

Each case runs in a subprocess with XLA_FLAGS forcing N host devices —
the pytest process itself keeps the default single device (the dry-run
instructions require that smoke tests see 1 device)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow   # 16-device subprocess cases, >60s each

_HERE = os.path.dirname(__file__)
_MAIN = os.path.join(_HERE, "_dist_bfs_main.py")


def _run(n_dev, mode, timeout=1200):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, _MAIN, str(n_dev), mode],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"{mode} failed:\n{r.stdout}\n{r.stderr}"
    assert f"OK {mode}" in r.stdout


@pytest.mark.parametrize("mode", ["grids", "kernel", "counters",
                                  "multiroot", "optimized", "multipod",
                                  "podheur", "fastpath", "pipelined",
                                  "born"])
def test_distributed_bfs(mode):
    _run(16, mode)


def test_distributed_spmm():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    main = os.path.join(_HERE, "_dist_spmm_main.py")
    r = subprocess.run([sys.executable, main], capture_output=True,
                       text=True, timeout=1200, env=env)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "OK spmm" in r.stdout
