"""Deliverable (e)/(g) artifact checks: the multi-pod dry-run results
must exist for every (arch x shape x mesh) cell with roofline terms.
(Regenerate with: PYTHONPATH=src python -m repro.launch.dryrun)"""
import json
import os

import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

ARCH_SHAPES = (
    [(a, s) for a in ("stablelm-3b", "smollm-135m", "starcoder2-7b",
                      "qwen3-moe-30b-a3b", "mixtral-8x22b")
     for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k")]
    + [(a, s) for a in ("mace", "gin-tu", "gat-cora", "meshgraphnet")
       for s in ("full_graph_sm", "minibatch_lg", "ogb_products",
                 "molecule")]
    + [("autoint", s) for s in ("train_batch", "serve_p99", "serve_bulk",
                                "retrieval_cand")]
)
SKIPS = {("stablelm-3b", "long_500k"), ("smollm-135m", "long_500k"),
         ("starcoder2-7b", "long_500k"), ("qwen3-moe-30b-a3b", "long_500k")}


@pytest.mark.skipif(not os.path.isdir(RESULTS),
                    reason="dry-run results not generated yet")
@pytest.mark.parametrize("mesh", ["sp", "mp"])
def test_all_cells_present(mesh):
    assert len(ARCH_SHAPES) == 40, "40 (arch x shape) cells are assigned"
    for arch, shape in ARCH_SHAPES:
        path = os.path.join(RESULTS, f"{arch}__{shape}__{mesh}.json")
        assert os.path.exists(path), f"missing dry-run cell {path}"
        rec = json.load(open(path))
        if (arch, shape) in SKIPS:
            assert rec.get("skipped") and "full-attention" in rec["reason"]
            continue
        assert rec["mesh"] == ("2x16x16" if mesh == "mp" else "16x16")
        assert rec["flops"] > 0
        assert "roofline" in rec and rec["roofline"]["dominant"] in (
            "compute", "memory", "collective")
        assert rec["memory"].get("temp_size_in_bytes", 0) >= 0


@pytest.mark.skipif(not os.path.isdir(RESULTS),
                    reason="dry-run results not generated yet")
def test_bfs_cells_and_level_steps():
    for scale in ("scale22", "scale26", "scale30"):
        for mesh in ("sp", "mp"):
            path = os.path.join(RESULTS, f"bfs-rmat__{scale}__{mesh}.json")
            assert os.path.exists(path)
        rec = json.load(open(os.path.join(
            RESULTS, f"bfs-rmat__{scale}__sp.json")))
        assert "level_step" in rec, "roofline reads the level-step lowering"
        assert rec["level_step"]["collectives"]["total_bytes"] > 0


@pytest.mark.skipif(not os.path.isdir(RESULTS),
                    reason="dry-run results not generated yet")
def test_hillclimb_artifacts():
    for tag in ("bfs-rmat-i1__scale30__sp", "bfs-rmat-i2__scale30__sp",
                "bfs-rmat-opt__scale30__sp", "gin-tu-2d__ogb_products__sp",
                "mace-2d__ogb_products__sp",
                "bfs-rmat-multiroot__scale22__mp",
                "qwen3-moe-r2__train_4k__sp", "qwen3-moe-r3__train_4k__sp"):
        assert os.path.exists(os.path.join(RESULTS, tag + ".json")), tag
