"""System tests for the collective-schedule linter (the PR 9
tentpole): the deliberately-broken pre-PR-4 fixture is flagged by rule
R1 with the offending collective and the non-uniform predicate named;
every shipped registry combo lints clean; the registry stays pristine
around the fixture; ``BFSPlan.lint()`` is the in-process entry point.

The registry sweep and the fixture's pod-batched program need 16
forced host devices, so those run the CLI in a subprocess (exactly how
CI's lint lane runs it); the in-process tests stick to 1x1 meshes.
"""
import json
import os
import subprocess
import sys

import pytest

_ENV = dict(os.environ)
_ENV.pop("XLA_FLAGS", None)
_ENV["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                      + os.pathsep + _ENV.get("PYTHONPATH", ""))


def _run_cli(*args, timeout=900):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *args],
        capture_output=True, text=True, timeout=timeout, env=_ENV)


# ---------------------------------------------------------------------------
# in-process: registry hygiene + the podless mesh counterpoint
# ---------------------------------------------------------------------------


def test_fixture_registration_is_scoped():
    """The broken entry (and its LocalOps mirror) exists only inside
    the with-block; the registry pin in test_engine stays true."""
    from repro.analysis.fixtures import FIXTURE_NAME, divergent_2d_fixture
    from repro.core import decomp, local_ops
    assert decomp.registered_decompositions() == ("1d", "1ds", "2d")
    with divergent_2d_fixture() as entry:
        assert FIXTURE_NAME in decomp.registered_decompositions()
        assert entry.name == FIXTURE_NAME
        assert decomp.get_decomposition(FIXTURE_NAME) is entry
        assert any(d == FIXTURE_NAME
                   for d, _, _ in local_ops.registered_combos())
    assert decomp.registered_decompositions() == ("1d", "1ds", "2d")
    assert not any(d == FIXTURE_NAME
                   for d, _, _ in local_ops.registered_combos())


def test_fixture_clean_without_pod_axis():
    """R1 keys on the MESH, not the code: the same broken body is
    harmless on a podless mesh (its per-slice psum is uniform over the
    whole mesh there), and must lint clean — the hazard only exists
    once a pod axis can diverge."""
    from repro.analysis.fixtures import FIXTURE_NAME, divergent_2d_fixture
    from repro.configs.base import BFSConfig
    from repro.core.engine import plan_bfs
    from repro.graph.formats import build_blocked
    from repro.graph.rmat import rmat_graph
    from repro.launch.mesh import make_local_mesh
    e = rmat_graph(8, edge_factor=8, seed=4)
    g = build_blocked(e, 1, 1, align=32, cap_pad=32)
    with divergent_2d_fixture():
        plan = plan_bfs(g, BFSConfig(decomposition=FIXTURE_NAME),
                        make_local_mesh(1, 1))
        findings = plan.lint()
    assert findings == [], [f.message for f in findings]


def test_plan_lint_returns_structured_findings():
    """BFSPlan.lint() is the in-process hook: list of Finding with
    JSON-ready details (shipped plans return the empty list — asserted
    across entries in test_uniformity)."""
    from repro.configs.base import BFSConfig
    from repro.core.engine import plan_bfs, plan_for_part
    from repro.core.partition import make_partition
    from repro.graph.formats import build_blocked
    from repro.graph.rmat import rmat_graph
    from repro.launch.mesh import make_local_mesh
    e = rmat_graph(8, edge_factor=8, seed=4)
    g = build_blocked(e, 1, 1, align=32, cap_pad=32)
    plan = plan_bfs(g, BFSConfig(decomposition="2d"), make_local_mesh(1, 1))
    assert plan.lint() == []
    # graphless plans cannot trace -> explicit error, not a crash
    bare = plan_for_part(make_partition(e.n, 1, 1, align=32),
                         BFSConfig(decomposition="2d"),
                         make_local_mesh(1, 1), cap_seg=32)
    with pytest.raises(ValueError, match="graph"):
        bare.lint()


# ---------------------------------------------------------------------------
# subprocess: the CLI as CI runs it
# ---------------------------------------------------------------------------


def test_cli_quick_flags_fixture_and_clean_registry(tmp_path):
    """--quick --expect-fixture: every representative shipped combo is
    clean, and R1 flags the fixture naming the whole-mesh ppermute, the
    per-slice predicate, and the pod axis it can diverge over."""
    report_path = tmp_path / "lint-report.json"
    r = _run_cli("--quick", "--no-budgets", "--expect-fixture",
                 "--json", str(report_path))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    report = json.loads(report_path.read_text())
    assert report["clean"] and report["findings"] == []
    assert len(report["combos"]) >= 3        # one per shipped entry
    fix = report["fixture"]["findings"]
    r1 = [f for f in fix if f["rule"] == "R1"
          and f["detail"]["collective"] == "ppermute"]
    assert r1, fix
    d = r1[0]["detail"]
    assert d["divergent_axes"] == ["pod"]
    assert "pod" in d["rendezvous_axes"]
    assert "psum" in d["predicate"]          # the per-slice decision
    assert d["predicate_uniform_over"] == ["data", "model"]
    assert "ppermute" in r1[0]["message"] and "deadlock" in r1[0]["message"]


@pytest.mark.slow
def test_cli_full_registry_clean(tmp_path):
    """The full sweep (every LocalOps x schedule combo + all 18 budget
    cases + the fixture self-check) exits 0 — the CI lint lane."""
    report_path = tmp_path / "lint-report.json"
    r = _run_cli("--expect-fixture", "--json", str(report_path))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    report = json.loads(report_path.read_text())
    assert report["clean"]
    assert len(report["combos"]) >= 50       # the real sweep, not quick
    assert len(report["budget_cases"]) >= 18
