"""End-to-end example drivers run as tests (the fast ones in-process,
the rest as subprocesses)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow   # each case compiles + runs a full driver

_ROOT = os.path.join(os.path.dirname(__file__), "..")
_ENV = {**os.environ, "PYTHONPATH": os.path.join(_ROOT, "src")}
_ENV.pop("XLA_FLAGS", None)


def _run(args, timeout=1500):
    r = subprocess.run([sys.executable] + args, capture_output=True,
                       text=True, timeout=timeout, env=_ENV, cwd=_ROOT)
    assert r.returncode == 0, f"{args}:\n{r.stdout[-1500:]}\n{r.stderr[-3000:]}"
    return r.stdout


def test_quickstart():
    out = _run(["examples/quickstart.py"])
    assert "valid tree: True" in out


def test_graph500_driver():
    out = _run(["examples/graph500_bfs.py", "--scale", "11", "--roots", "4",
                "--grid", "1x1"])
    assert "harmonic-mean TEPS" in out


def test_serve_example():
    out = _run(["examples/serve_lm.py"])
    assert "served 6 requests" in out


def test_train_lm_example(tmp_path):
    out = _run(["examples/train_lm.py", "--steps", "12", "--batch", "2",
                "--seq", "64", "--d-model", "64", "--layers", "2",
                "--ckpt-dir", str(tmp_path / "lm_ck")])
    assert "trained" in out


def test_gnn_full_graph_example():
    out = _run(["examples/gnn_full_graph.py"])
    assert "matches segment_sum oracle" in out


def test_train_launcher_recsys(tmp_path):
    out = _run(["-m", "repro.launch.train", "--arch", "autoint",
                "--steps", "8", "--ckpt-dir", str(tmp_path / "ai_ck")])
    assert "autoint: 8 steps" in out
