"""Shared test config.

Two jobs:

1. Register the ``slow`` marker (subprocess system tests >60 s) so the CI
   fast lane can deselect them with ``-m "not slow"``.

2. Provide a *fallback* ``hypothesis`` shim when the real package is not
   installed (it is declared in requirements-dev.txt, but the tier-1 run
   must collect and pass without it).  The shim implements exactly the
   surface these tests use — ``@given(st.integers(a, b), ...)`` plus
   ``@settings(max_examples=, deadline=)`` — by re-running the test body
   ``max_examples`` times on values drawn from a *seeded* per-test RNG,
   so runs are deterministic (no shrinking, no example database).
"""
from __future__ import annotations

import sys
import types
import zlib


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: subprocess system test (>60s); deselect with "
        "-m 'not slow' for the fast CI lane")


def _install_hypothesis_shim():
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    class _strategies(types.ModuleType):
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def settings(max_examples: int = 20, deadline=None, **_kw):
        def deco(f):
            f._shim_max_examples = max_examples
            return f
        return deco

    def given(*strats):
        def deco(f):
            def wrapper():
                # zero-arg on purpose: pytest must not see the strategy
                # parameters of ``f`` as fixtures (no __wrapped__ either,
                # or inspect.signature would follow it back to ``f``)
                import numpy as np
                # read max_examples lazily so @settings works in either
                # decorator order (above @given it lands on the wrapper)
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(f, "_shim_max_examples", 20))
                seed = zlib.crc32(f.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    vals = [s._draw(rng) for s in strats]
                    f(*vals)
            for attr in ("__name__", "__qualname__", "__module__", "__doc__"):
                setattr(wrapper, attr, getattr(f, attr))
            return wrapper
        return deco

    mod = types.ModuleType("hypothesis")
    st_mod = _strategies("hypothesis.strategies")
    mod.given = given
    mod.settings = settings
    mod.strategies = st_mod
    mod.__is_repro_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:  # prefer the real package when available
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()
