"""Data-pipeline determinism + comm-model closed forms."""
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core import comm_model
from repro.data.pipeline import lm_batch, recsys_batch, step_stream


def test_lm_stream_step_indexed_determinism():
    cfg = reduced(get_config("smollm-135m"), vocab=512)
    a = lm_batch(cfg, 4, 32, step=17, seed=3)
    b = lm_batch(cfg, 4, 32, step=17, seed=3)
    c = lm_batch(cfg, 4, 32, step=18, seed=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted views of the same stream
    assert a["tokens"].shape == a["labels"].shape == (4, 32)
    assert (a["tokens"] < cfg.vocab).all()


def test_recsys_stream_in_vocab():
    cfg = get_config("autoint")
    b = recsys_batch(cfg, 64, step=0)
    assert b["idx"].shape == (64, cfg.n_sparse)
    for f, v in enumerate(cfg.vocab_sizes):
        assert (b["idx"][:, f] < v).all()
    assert set(np.unique(b["labels"])) <= {0.0, 1.0}


def test_step_stream_resume():
    mk = lambda s: {"x": np.asarray([s])}
    it = step_stream(mk, start_step=5)
    assert next(it)["x"][0] == 5 and next(it)["x"][0] == 6


def test_comm_model_eq2_structure():
    # Eq 2 (paper §6): the gain grows with the degree k, shrinks with
    # more bottom-up steps s_b, and saturates at 64/(2 s_b) for large pc
    # (it is NOT monotone in pc — it peaks, then the rotation term wins)
    assert comm_model.ratio_eq2(64, 128, 4) > comm_model.ratio_eq2(16, 128, 4)
    assert comm_model.ratio_eq2(16, 128, 3) > comm_model.ratio_eq2(16, 128, 6)
    import numpy as np
    limit = 64 / (2 * 4)
    assert abs(comm_model.ratio_eq2(16, 10**6, 4) - limit) < 0.1
    assert comm_model.ratio_eq2(16, 128, 4) > 1   # bottom-up always wins
    # typical-value check from the paper: k=16, pc=128 -> s_b ~ 47.6 steps
    # to break even
    s_b = 47.6
    w_ratio = comm_model.ratio_eq2(16, 128, s_b)
    assert abs(w_ratio - 1.0) < 0.05


def test_bottomup_words_matches_table1_structure():
    n, pr, pc, s_b = 1 << 20, 8, 8, 3.0
    w = comm_model.bottomup_words(n, pr, pc, s_b)
    expect = n * (s_b * (pr + pc + 1) / 64 + 2)
    assert w == expect


def test_fold_bitmap_words_closed_form():
    """The bitmap fold is exactly 2 bitmap all_to_all rounds + 2 id
    all_to_alls (values + offsets): 2*nr/64 + 2*pc*cap_w words per
    device.  (The old counter charged a third bitmap round and the old
    docstring dropped one id exchange.)"""
    nr, pc, cap_w = 4096, 16, 64
    w = comm_model.fold_bitmap_level_words(nr, pc, cap_w)
    assert w == 2 * nr / 64 + 2 * pc * cap_w
    # cheaper than the dense alltoall fold once cap_w << chunk
    assert w < (pc - 1) * (nr // pc) * pc  # vs dense per-device * pc...
    assert w < nr                          # vs the dense (pc-1)*chunk ~ nr


def test_fold_bitmap_counter_matches_closed_form():
    """The live wire_fold counter must reproduce the closed form: one
    charge of p * fold_bitmap_level_words per top-down level."""
    import numpy as np
    from repro.configs.base import BFSConfig
    from repro.core.bfs import run_bfs
    from repro.graph.formats import build_blocked
    from repro.graph.rmat import rmat_graph
    from repro.launch.mesh import make_local_mesh

    e = rmat_graph(8, edge_factor=8, seed=4)
    g = build_blocked(e, 1, 1, align=32, cap_pad=32)
    part = g.part
    res = run_bfs(g, int(np.flatnonzero(e.out_degrees())[0]),
                  BFSConfig(fold_mode="bitmap"), make_local_mesh(1, 1))
    modes = res.level_stats[: res.n_levels, 2]
    used = res.level_stats[: res.n_levels, 3]
    n_td = int(((modes == 0) & (used > 0)).sum())
    assert n_td > 0
    cap_w = max(part.chunk // 16, 32)
    want = n_td * part.p * comm_model.fold_bitmap_level_words(
        part.pc * part.chunk, part.pc, cap_w)
    assert abs(res.counters["wire_fold"] - want) <= 1e-5 * want, (
        res.counters["wire_fold"], want)


def test_uninstrumented_runs_carry_no_wire_counters():
    """The satellite bugfix pin: an instrument=False run used to return
    zero-valued counters — a "1ds" dense-fallback level's wire_expand
    came back as a measured-looking 0.0, silently vanishing from
    aggregates that mix fast and instrumented runs (sum(fast, inst)
    == sum(inst), no error).  The fast path must now carry NO counters
    at all, so mixing modes is a KeyError instead of a wrong number,
    and the exchange helper itself reports wire=None uninstrumented."""
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs.base import BFSConfig
    from repro.core.bfs import run_bfs
    from repro.core.compat import shard_map
    from repro.core.steps_1d_sparse import sparse_exchange_1d
    from repro.graph.formats import build_blocked_1d
    from repro.graph.rmat import rmat_graph
    from repro.launch.mesh import make_local_mesh_1d

    e = rmat_graph(8, edge_factor=8, seed=4)
    g = build_blocked_1d(e, 1, align=32, cap_pad=32)
    root = int(np.flatnonzero(e.out_degrees())[0])
    mesh = make_local_mesh_1d(1)
    fast = run_bfs(g, root, BFSConfig(decomposition="1ds",
                                      instrument=False), mesh)
    inst = run_bfs(g, root, BFSConfig(decomposition="1ds"), mesh)
    assert fast.counters == {}
    assert np.array_equal(fast.parents, inst.parents)
    # the helper itself: wire is None (absent), never a fake 0.0 float
    part = g.part
    front = np.zeros((1, part.chunk), bool)
    front[0, root] = True

    def wire_of(instrument):
        captured = {}

        def body(f):
            f_words, wire, over = sparse_exchange_1d(
                f[0], "data", 32, part, instrument=instrument)
            captured["wire"] = wire
            return f_words[None]

        shard_map(body, mesh=mesh, in_specs=(P("data"),),
                  out_specs=P("data"), check_vma=False)(front)
        return captured["wire"]

    assert wire_of(False) is None
    assert wire_of(True) is not None
