"""Graph + executable checkpoint store, and the config_hash it rides on:
cross-process hash stability, loud failures on stale specs / wrong
meshes / unhashable configs, save->load->traverse round trips, atomicity
under an interrupted save, retention.  Single-device fast-lane cases;
the multi-device disk->traversal lane is benchmarks/worker.py."""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.ckpt.checkpoint import config_hash
from repro.configs.base import BFSConfig
from repro.graph.dist_build import BuildSpec
from repro.graph.formats import build_blocked_1d
from repro.graph.rmat import rmat_graph

SPEC = BuildSpec(scale=8, edge_factor=8, seed=3)


# ---------------------------------------------------------------------------
# config_hash (satellite: repr()-hashing replaced by canonical JSON)
# ---------------------------------------------------------------------------


def test_config_hash_key_order_invariant():
    assert config_hash({"a": 1, "b": [2, 3]}) == \
        config_hash({"b": (2, 3), "a": np.int64(1)})


def test_config_hash_distinguishes_values():
    assert config_hash(SPEC) != config_hash(
        dataclasses.replace(SPEC, seed=4))


def test_config_hash_rejects_arbitrary_objects():
    """repr() fallbacks embedded id() memory addresses; now it's a loud
    error instead of a hash that never matches across processes."""
    with pytest.raises(TypeError, match="memory address"):
        config_hash(object())
    with pytest.raises(TypeError):
        config_hash({"f": lambda: 0})


def test_config_hash_stable_across_processes():
    """The regression the canonical-JSON rewrite exists for: the same
    dataclass must hash identically in a fresh interpreter."""
    code = (
        "import sys; sys.path.insert(0, {src!r})\n"
        "from repro.ckpt.checkpoint import config_hash\n"
        "from repro.graph.dist_build import BuildSpec\n"
        "print(config_hash(BuildSpec(scale=8, edge_factor=8, seed=3)))\n"
    ).format(src=os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=env)
    assert r.returncode == 0, r.stderr
    assert r.stdout.strip() == config_hash(SPEC)


# ---------------------------------------------------------------------------
# graph round trips
# ---------------------------------------------------------------------------


def _host_graph(p=1):
    edges = rmat_graph(SPEC.scale, edge_factor=SPEC.edge_factor,
                       seed=SPEC.seed, generator="counter")
    return build_blocked_1d(edges, p, align=32, cap_pad=32)


def _mesh1():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def test_graph_round_trip_arrays_and_parents(tmp_path):
    from repro.ckpt.graph_store import GraphStore, plan_bfs_from_store
    from repro.core.engine import plan_bfs
    store = GraphStore(str(tmp_path))
    g = _host_graph()
    store.save_graph("g", g, spec=SPEC)
    loaded = store.load_graph("g", expect_spec=SPEC)
    assert type(loaded) is type(g)
    assert (loaded.cap, loaded.cap_nzc, loaded.maxdeg_col, loaded.m,
            loaded.m_input) == (g.cap, g.cap_nzc, g.maxdeg_col, g.m,
                                g.m_input)
    ha = g.device_arrays()
    for k, v in loaded.device_arrays().items():
        assert np.array_equal(np.asarray(v), np.asarray(ha[k])), k
    # disk -> traversal: parents identical to the in-memory graph's
    mesh = _mesh1()
    cfg = BFSConfig(decomposition="1d", instrument=False)
    ra = plan_bfs(g, cfg, mesh).compile().run(5)
    rb = plan_bfs_from_store(store, "g", cfg, mesh,
                             expect_spec=SPEC).compile().run(5)
    assert np.array_equal(ra.parents, rb.parents)
    assert ra.n_levels == rb.n_levels


def test_stale_spec_hash_fails_loudly(tmp_path):
    from repro.ckpt.graph_store import GraphStore
    store = GraphStore(str(tmp_path))
    store.save_graph("g", _host_graph(), spec=SPEC)
    with pytest.raises(ValueError, match="spec_hash"):
        store.load_graph("g", expect_spec=dataclasses.replace(SPEC, seed=9))


def test_mesh_mismatch_fails_loudly(tmp_path):
    from repro.ckpt.graph_store import GraphStore
    store = GraphStore(str(tmp_path))
    store.save_graph("g2", _host_graph(p=2), spec=SPEC)  # built for p=2
    with pytest.raises(ValueError, match="partitioned for"):
        store.load_graph("g2", mesh=_mesh1())            # mesh has data=1
    # without a mesh the p=2 shards load fine (host-side inspection)
    assert store.load_graph("g2").part.p == 2


def test_interrupted_save_is_atomic(tmp_path, monkeypatch):
    """Killing the writer mid-save must leave the previous step intact
    and publish nothing partial."""
    from repro.ckpt import checkpoint
    from repro.ckpt.graph_store import GraphStore
    store = GraphStore(str(tmp_path))
    g = _host_graph()
    store.save_graph("g", g, spec=SPEC)
    before = checkpoint.latest_step(os.path.join(str(tmp_path),
                                                 "graphs", "g"))

    real_savez = np.savez

    def dying_savez(*a, **kw):
        raise OSError("simulated crash mid-write")

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(OSError):
        store.save_graph("g", g, spec=SPEC)
    monkeypatch.setattr(np, "savez", real_savez)

    gdir = os.path.join(str(tmp_path), "graphs", "g")
    assert checkpoint.latest_step(gdir) == before
    assert not [d for d in os.listdir(gdir) if d.startswith(".tmp_")]
    loaded = store.load_graph("g", expect_spec=SPEC)    # survivor readable
    assert loaded.m == g.m


def test_retention_keeps_newest(tmp_path):
    from repro.ckpt.graph_store import GraphStore
    store = GraphStore(str(tmp_path), keep=2)
    g = _host_graph()
    for _ in range(5):
        store.save_graph("g", g, spec=SPEC)
    gdir = os.path.join(str(tmp_path), "graphs", "g")
    steps = sorted(d for d in os.listdir(gdir) if d.startswith("step_"))
    assert steps == ["step_0000000003", "step_0000000004"]
    assert store.load_graph("g").m == g.m


# ---------------------------------------------------------------------------
# executable round trips
# ---------------------------------------------------------------------------


def test_executable_store_hit(tmp_path):
    from repro.ckpt.graph_store import GraphStore
    from repro.core.engine import plan_bfs
    store = GraphStore(str(tmp_path))
    g = _host_graph()
    mesh = _mesh1()
    cfg = BFSConfig(decomposition="1d", instrument=False)
    e1 = plan_bfs(g, cfg, mesh).compile(store=store)
    assert not e1.exec_from_store and e1.compile_s > 0
    e2 = plan_bfs(g, cfg, mesh).compile(store=store)
    assert e2.exec_from_store and e2.compile_s == 0.0
    assert np.array_equal(e1.run(5).parents, e2.run(5).parents)
    # a different plan misses (its hash differs) and compiles fresh
    e3 = plan_bfs(g, dataclasses.replace(cfg, alpha=7.0),
                  mesh).compile(store=store)
    assert not e3.exec_from_store


def test_build_spec_registry_round_trips():
    from repro.configs.build_specs import (BUILD_SPECS, get_build_spec,
                                           store_name)
    for name, spec in BUILD_SPECS.items():
        spec.validate()
        assert get_build_spec(name) is spec
        assert json.dumps({"h": config_hash(spec)})   # canonicalizable
    assert store_name("g500-s14", "1ds") == "g500-s14-1d"
    assert store_name("g500-s14", "2d") == "g500-s14-2d"
    with pytest.raises(KeyError):
        get_build_spec("nope")
