"""Unit tests for the mesh-uniformity lattice
(repro.analysis.uniformity) on synthetic jaxprs, plus the known-clean
real program: the shipped ``_search_loop`` slice must lint clean.

Synthetic jaxprs are built with ``jax.make_jaxpr(..., axis_env=...)``
— no mesh, no devices — and walked with explicit input lattice values,
so these tests pin the transfer functions themselves:

  * psum/all_gather over S makes a value uniform over S (the
    "uniform-after-psum" fact the engine's lockstep sync rests on);
  * all_to_all over S destroys uniformity over S; ppermute preserves;
  * a while carry poisoned by ``axis_index`` stays non-uniform through
    the fixpoint, poisons the loop predicate, and R1 flags a ppermute
    under it (the PR 4 deadlock class, reduced to four lines);
  * nested while-in-cond stacks both predicates on the site, and the
    outer divergent cond is what R1 names;
  * branch-schedule divergence is only a finding when the predicate
    can diverge over axes the differing collectives rendezvous on
    (R2's hazard intersection).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.analysis.rules import (check_branch_schedules,
                                  check_divergent_collectives)
from repro.analysis.uniformity import MISMATCH, AbstractVal, analyze_jaxpr

MESH = ("data", "model", "pod")
AXIS_ENV = [("data", 2), ("model", 4), ("pod", 2)]
FULL = frozenset(MESH)


def _jaxpr(fn, *args):
    return jax.make_jaxpr(fn, axis_env=AXIS_ENV)(*args)


def _sharded(desc="sharded input"):
    return AbstractVal(frozenset(), desc)


# ---------------------------------------------------------------------------
# transfer functions
# ---------------------------------------------------------------------------


def test_uniform_after_psum():
    """A fully-sharded value becomes uniform over exactly the reduced
    axes — and the site records no enclosing predicate."""
    cj = _jaxpr(lambda x: lax.psum(x, ("data", "model")), jnp.float32(0))
    an = analyze_jaxpr(cj, MESH, in_vals=[_sharded()])
    assert an.out_vals[0].unif == frozenset({"data", "model"})
    assert "psum" in an.out_vals[0].desc
    (site,) = an.sites
    assert site.kind == "psum" and site.preds == ()
    assert site.rendezvous(MESH) == ("data", "model")
    assert check_divergent_collectives(an, "t") == []


def test_all_gather_adds_all_to_all_removes_ppermute_preserves():
    cj = _jaxpr(lambda x: lax.all_gather(x, "model"), jnp.zeros((4,)))
    an = analyze_jaxpr(cj, MESH, in_vals=[_sharded()])
    assert an.out_vals[0].unif == frozenset({"model"})

    cj = _jaxpr(lambda x: lax.all_to_all(x, "model", 0, 0),
                jnp.zeros((4, 4)))
    an = analyze_jaxpr(cj, MESH,
                       in_vals=[AbstractVal(FULL, "replicated")])
    assert an.out_vals[0].unif == FULL - {"model"}

    perm = [(i, (i + 1) % 2) for i in range(2)]
    cj = _jaxpr(lambda x: lax.ppermute(x, "data", perm), jnp.zeros((4,)))
    an = analyze_jaxpr(cj, MESH,
                       in_vals=[AbstractVal(frozenset({"pod"}), "r")])
    assert an.out_vals[0].unif == frozenset({"pod"})
    # ppermute lowers to a whole-mesh collective-permute regardless of
    # its named axis — the rendezvous is every mesh axis
    assert an.sites[-1].rendezvous(MESH) == MESH


def test_axis_index_and_constants():
    cj = _jaxpr(lambda x: x + lax.axis_index("pod"), jnp.int32(0))
    an = analyze_jaxpr(cj, MESH)  # default: inputs uniform everywhere
    assert an.out_vals[0].unif == FULL - {"pod"}
    assert "axis_index" in an.out_vals[0].desc
    cj = _jaxpr(lambda x: jnp.float32(2.0) * 3.0, jnp.float32(0))
    an = analyze_jaxpr(cj, MESH, in_vals=[_sharded()])
    assert an.out_vals[0].unif == FULL  # literals sit at top


# ---------------------------------------------------------------------------
# varying-through-while-carry (the reduced PR 4 deadlock)
# ---------------------------------------------------------------------------


def test_varying_carry_poisons_predicate_and_r1_fires():
    """axis_index leaks into the while carry; the fixpoint keeps the
    carry non-uniform over 'pod'; the loop predicate inherits that; the
    ppermute in the body rendezvouses whole-mesh -> R1."""
    perm = [(i, (i + 1) % 2) for i in range(2)]

    def f(x):
        def cond(c):
            i, v = c
            return i < v.sum().astype(jnp.int32)

        def body(c):
            i, v = c
            v = v + lax.axis_index("pod")       # poison
            v = lax.ppermute(v, "data", perm)   # whole-mesh rendezvous
            return i + 1, v

        return lax.while_loop(cond, body, (jnp.int32(0), x))

    an = analyze_jaxpr(_jaxpr(f, jnp.zeros((4,), jnp.int32)), MESH)
    (site,) = [s for s in an.sites if s.kind == "ppermute"]
    (pred,) = site.preds
    assert pred.kind == "while"
    assert "pod" not in pred.unif
    findings = check_divergent_collectives(an, "t")
    assert [f.rule for f in findings] == ["R1"]
    assert findings[0].detail["collective"] == "ppermute"
    assert findings[0].detail["divergent_axes"] == ["pod"]
    assert "axis_index" in findings[0].detail["predicate"]
    # loop outputs are met with the divergent predicate
    assert "pod" not in an.out_vals[1].unif


def test_uniform_carry_stays_clean():
    """Same loop shape with a psum-synced predicate: carry and
    predicate stay uniform, R1 has nothing to say."""
    perm = [(i, (i + 1) % 2) for i in range(2)]

    def f(x):
        def cond(c):
            i, v = c
            return i < lax.psum(v.sum(), MESH).astype(jnp.int32)

        def body(c):
            i, v = c
            return i + 1, lax.ppermute(v, "data", perm)

        return lax.while_loop(cond, body, (jnp.int32(0), x))

    an = analyze_jaxpr(_jaxpr(f, jnp.zeros((4,), jnp.int32)), MESH)
    (site,) = [s for s in an.sites if s.kind == "ppermute"]
    assert site.preds[0].unif == FULL
    assert check_divergent_collectives(an, "t") == []


# ---------------------------------------------------------------------------
# nested while-in-cond
# ---------------------------------------------------------------------------


def test_nested_while_in_cond_stacks_predicates():
    """A uniform inner while inside a pod-divergent cond: the inner
    psum site carries BOTH predicates, and R1 blames the outer cond
    (the inner while predicate is uniform)."""

    def f(x):
        outer = lax.axis_index("pod") == 0      # divergent over pod

        def true_branch(v):
            def cond(c):
                i, _ = c
                return i < 3

            def body(c):
                i, u = c
                return i + 1, lax.psum(u, ("data", "pod"))

            return lax.while_loop(cond, body, (jnp.int32(0), v))[1]

        return lax.cond(outer, true_branch, lambda v: v, x)

    an = analyze_jaxpr(_jaxpr(f, jnp.float32(0)), MESH)
    (site,) = [s for s in an.sites if s.kind == "psum"]
    assert [p.kind for p in site.preds] == ["cond", "while"]
    assert "pod" not in site.preds[0].unif       # the divergent cond
    assert site.preds[1].unif == FULL            # i < 3 is uniform
    findings = check_divergent_collectives(an, "t")
    assert len(findings) == 1
    assert findings[0].detail["predicate_kind"] == "cond"
    assert findings[0].detail["divergent_axes"] == ["pod"]
    # ...and the branch schedules differ under a divergent predicate
    # over an axis the psum rendezvouses on -> R2 fires too
    r2 = check_branch_schedules(an, "t")
    assert len(r2) == 1 and "pod" in r2[0].detail["divergent_axes"]


def test_r2_hazard_needs_rendezvous_overlap():
    """Differing branch schedules under a pod-divergent predicate are
    FINE while every collective stays on axes the predicate is uniform
    over (psum over 'data' within a pod) — R2's hazard intersection."""

    def f(x):
        pred = lax.axis_index("pod") == 0
        return lax.cond(pred, lambda v: lax.psum(v, "data"),
                        lambda v: v, x)

    an = analyze_jaxpr(_jaxpr(f, jnp.float32(0)), MESH)
    (rec,) = an.conds
    assert len(set(rec.branch_seqs)) == 2      # schedules DO differ
    assert check_branch_schedules(an, "t") == []
    # but R1 still applies to the guarded psum? no: psum over 'data'
    # rendezvouses only on 'data', where the predicate is uniform
    assert check_divergent_collectives(an, "t") == []


def test_mismatch_marker_poisons_parent_sequence():
    """A nested cond whose branches disagree contributes MISMATCH to
    the enclosing branch sequence, which R2 treats as always-different
    and worst-case whole-mesh rendezvous."""

    def f(x):
        inner_pred = x > 0                      # uniform (input default)
        outer = lax.axis_index("pod") == 0

        def true_branch(v):
            return lax.cond(v > 1, lambda u: lax.psum(u, "pod"),
                            lambda u: u, v)

        return lax.cond(outer, true_branch, lambda v: v,
                        jnp.where(inner_pred, x, -x))

    an = analyze_jaxpr(_jaxpr(f, jnp.float32(0)), MESH)
    outer_rec = [r for r in an.conds if "[branch" not in r.path][0]
    # jax orders cond branches (false, true): the nested mismatching
    # cond lives in the true branch, index 1
    assert MISMATCH in outer_rec.branch_seqs[1]
    assert outer_rec.branch_seqs[0] == ()
    r2 = check_branch_schedules(an, "t")
    assert any(MISMATCH[0] in str(f.detail["branch_sequences"])
               for f in r2)


# ---------------------------------------------------------------------------
# the real thing: the shipped _search_loop slice is clean
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_plans():
    from repro.configs.base import BFSConfig
    from repro.core.engine import plan_bfs
    from repro.graph.formats import build_blocked, build_blocked_1d
    from repro.graph.rmat import rmat_graph
    from repro.launch.mesh import make_local_mesh, make_local_mesh_1d
    e = rmat_graph(8, edge_factor=8, seed=4)
    g2 = build_blocked(e, 1, 1, align=32, cap_pad=32)
    g1 = build_blocked_1d(e, 1, align=32, cap_pad=32)
    return [
        plan_bfs(g2, BFSConfig(decomposition="2d"), make_local_mesh(1, 1)),
        plan_bfs(g1, BFSConfig(decomposition="1d"), make_local_mesh_1d(1)),
        plan_bfs(g1, BFSConfig(decomposition="1ds"), make_local_mesh_1d(1)),
    ]


def test_search_loop_slice_is_clean(small_plans):
    """The shipped whole-search program (the ``_search_loop`` while +
    level bodies) linted in-process on a 1x1 mesh: every collective's
    enclosing predicates are uniform over its rendezvous, no findings.
    This is ``sync_modes`` being *checked*, not trusted."""
    for plan in small_plans:
        assert plan.lint() == [], plan.cfg.decomposition


def test_search_loop_sites_are_synced(small_plans):
    """Structure of the clean result: the 2d search jaxpr's while body
    does issue collectives under the loop predicate, and that predicate
    is uniform over the whole mesh (the pmax'd lockstep sync)."""
    from repro.analysis.registry import _graph_sds
    plan = small_plans[0]
    mesh_axes = tuple(plan.mesh.shape)
    cj = jax.make_jaxpr(plan.build_fn())(
        _graph_sds(plan), jax.ShapeDtypeStruct((), np.int32))
    an = analyze_jaxpr(cj, mesh_axes)
    guarded = [s for s in an.sites
               if any(p.kind == "while" for p in s.preds)]
    assert guarded, "search loop lost its collectives?"
    full = frozenset(mesh_axes)
    for s in guarded:
        for p in s.preds:
            assert p.unif == full, (s.kind, p)
