"""LocalOps registry: the (decomposition, local_mode, storage) parity
matrix, strip-DCSC builder invariants, and the §5.1 storage accounting
for the 1D strip formats."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import BFSConfig
from repro.core import comm_model, local_ops
from repro.core.bfs import run_bfs
from repro.core.ref import bfs_depths, depths_from_parents, validate_parents
from repro.core.steps import COUNTER_KEYS
from repro.graph.formats import build_blocked, build_blocked_1d
from repro.graph.rmat import preprocess, rmat_graph
from repro.launch.mesh import make_local_mesh, make_local_mesh_1d


def test_registry_covers_fig6_grid():
    combos = set(local_ops.registered_combos())
    for decomp in ("1d", "1ds", "2d"):
        for lm in ("dense", "kernel"):
            for st_ in ("csr", "dcsc"):
                assert (decomp, lm, st_) in combos
    with pytest.raises(ValueError, match="no LocalOps registered"):
        local_ops.get_local_ops("1d", "nope", "csr")
    # every entry ships the arrays the shared search loop reads
    for combo in combos:
        ops = local_ops.get_local_ops(*combo)
        assert "deg_A" in ops.keys and "nnz" in ops.keys, combo
    # "1ds" mirrors the "1d" entries exactly (same strips, same kernels)
    for lm in ("dense", "kernel"):
        for st_ in ("csr", "dcsc"):
            a = local_ops.get_local_ops("1d", lm, st_)
            b = local_ops.get_local_ops("1ds", lm, st_)
            assert a.keys == b.keys and a.topdown is b.topdown
            assert a.bottomup is b.bottomup


# ---------------------------------------------------------------------------
# Parity matrix: every registered combo on the same fixed R-MAT graph
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fixed_graph():
    e = rmat_graph(8, edge_factor=8, seed=4)
    # with_col_ptr: the matrix includes the 1d/kernel/csr cell
    return (e, build_blocked_1d(e, 1, align=32, cap_pad=32,
                                with_col_ptr=True),
            build_blocked(e, 1, 1, align=32, cap_pad=32))


def test_parity_matrix(fixed_graph):
    """On one device the candidate-min semantics are identical in every
    combo, so not just depths but the parent arrays must agree — and the
    local format must not change what goes on the wire: all COUNTER_KEYS
    totals except edges_examined (dense deliberately scans all nnz where
    the kernels scan only frontier segments) match within a
    decomposition; edges_examined itself matches across the two kernel
    storages."""
    e, g1, g2 = fixed_graph
    root = int(np.flatnonzero(e.out_degrees())[0])
    d_ref = bfs_depths(e.n, e.src, e.dst, root)
    res = {}
    for decomp, lm, st_ in local_ops.registered_combos():
        g = g2 if decomp == "2d" else g1       # 1d/1ds share the strips
        mesh = make_local_mesh(1, 1) if decomp == "2d" \
            else make_local_mesh_1d(1)
        cfg = BFSConfig(decomposition=decomp, storage=st_)
        r = run_bfs(g, root, cfg, mesh, local_mode=lm)
        ok, msg = validate_parents(e.n, e.src, e.dst, root, r.parents)
        assert ok, (decomp, lm, st_, msg)
        assert np.array_equal(
            depths_from_parents(e.n, r.parents, root), d_ref), (decomp, lm, st_)
        res[(decomp, lm, st_)] = r

    combos = list(res)
    base = res[combos[0]].parents
    for c in combos[1:]:
        assert np.array_equal(res[c].parents, base), c

    wire_keys = [k for k in COUNTER_KEYS if k != "edges_examined"]
    for decomp in ("1d", "1ds", "2d"):
        group = [c for c in combos if c[0] == decomp]
        r0 = res[group[0]]
        for c in group[1:]:
            for k in wire_keys:
                assert res[c].counters[k] == pytest.approx(
                    r0.counters[k], rel=1e-6), (c, k)
        kern = [c for c in group if c[1] == "kernel"]
        assert (res[kern[0]].counters["edges_examined"]
                == pytest.approx(res[kern[1]].counters["edges_examined"]))


def test_multiroot_routes_through_registry():
    """make_multiroot_bfs_fn must honour local_mode instead of always
    shipping the dense key set."""
    from repro.core.bfs import make_multiroot_bfs_fn
    from repro.core.partition import make_partition
    part = make_partition(256, 1, 1, align=32)
    import jax
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1, 1),
        ("pod", "data", "model"))
    _, keys = make_multiroot_bfs_fn(mesh, part, BFSConfig(storage="dcsc"),
                                    cap_seg=32, n_roots=1, maxdeg=16,
                                    local_mode="kernel")
    assert "jc" in keys and "edge_src" not in keys
    _, keys_d = make_multiroot_bfs_fn(mesh, part, BFSConfig(), cap_seg=32,
                                      n_roots=1)
    assert "edge_src" in keys_d and "jc" not in keys_d


# ---------------------------------------------------------------------------
# Strip-DCSC builder invariants
# ---------------------------------------------------------------------------


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_strip_dcsc_roundtrips_to_edge_list(seed):
    """(jc, cp, row_idx) per strip reconstructs exactly the dense edge
    list, jc is strictly increasing over non-empty GLOBAL columns, and
    the segment walk agrees with the uncompressed col_ptr."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 80))
    m = int(rng.integers(1, 4 * n))
    p = int(rng.integers(1, 5))
    e = preprocess(rng.integers(0, n, m), rng.integers(0, n, m), n,
                   symmetrize=True)
    if e.m == 0:
        return
    g = build_blocked_1d(e, p, align=32, cap_pad=32, with_col_ptr=True)
    part = g.part
    got = set()
    maxseg = 0
    for b in range(p):
        k, nz = int(g.nnz[b]), int(g.nzc[b])
        jc, cp = g.jc[b], g.cp[b]
        assert (jc[nz:] == part.n).all() and (cp[nz:] == k).all()
        cols = jc[:nz].astype(np.int64)
        if nz > 1:
            assert (np.diff(cols) > 0).all()
        for s in range(nz):
            lo, hi = int(cp[s]), int(cp[s + 1])
            assert hi > lo                        # non-empty by definition
            maxseg = max(maxseg, hi - lo)
            for t in range(lo, hi):
                got.add((int(cols[s]), int(g.row_idx[b, t]) + b * part.chunk))
        # uncompressed col_ptr agrees with the compressed walk
        deg = np.diff(g.col_ptr[b].astype(np.int64))
        assert np.array_equal(np.flatnonzero(deg), cols)
    assert got == set(zip(e.src.tolist(), e.dst.tolist()))
    assert g.maxdeg_col == maxseg


def test_strip_storage_words_match_closed_forms():
    """storage_words(mode) minus the shared bottom-up row_ptr equals the
    comm_model closed forms, and DCSC wins by a growing margin as p
    grows at fixed n (the §5.1 asymptotics, 1D edition)."""
    e = rmat_graph(10, edge_factor=2, seed=4)
    ratios = []
    for p in (2, 8):
        g = build_blocked_1d(e, p, align=32, cap_pad=32)
        bu = (g.part.chunk + 1) * p
        csr = g.storage_words("csr")["pointer_i32"] - bu
        dcsc = g.storage_words("dcsc")["pointer_i32"] - bu
        assert csr == comm_model.strip_csr_pointer_words(g.part.n, p)
        assert dcsc == comm_model.strip_dcsc_pointer_words(
            int(g.nzc.sum()), p)
        assert g.storage_words("csr")["index_i32"] \
            == g.storage_words("dcsc")["index_i32"]
        ratios.append(csr / dcsc)
    assert ratios[1] > ratios[0] > 1.0, ratios
    with pytest.raises(ValueError):
        g.storage_words("nope")


def test_build_without_col_ptr_gates_csr_kernel():
    e = rmat_graph(8, edge_factor=8, seed=1)
    g = build_blocked_1d(e, 1, align=32, cap_pad=32)   # default: no blow-up
    assert g.col_ptr is None and "col_ptr" not in g.device_arrays()
    root = int(np.flatnonzero(e.out_degrees())[0])
    mesh = make_local_mesh_1d(1)
    with pytest.raises(ValueError, match="lacks arrays"):
        run_bfs(g, root, BFSConfig(decomposition="1d"), mesh,
                local_mode="kernel")
    # dcsc kernel path needs no col_ptr at all
    r = run_bfs(g, root, BFSConfig(decomposition="1d", storage="dcsc"),
                mesh, local_mode="kernel")
    ok, msg = validate_parents(e.n, e.src, e.dst, root, r.parents)
    assert ok, msg
