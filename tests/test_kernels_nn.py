"""Kernel-vs-oracle sweeps: embedding_bag (TBE) and flash attention."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag import ops as eb_ops
from repro.kernels.embedding_bag import ref as eb_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref


@pytest.mark.parametrize("V,D,B,L", [
    (64, 16, 32, 1), (128, 32, 64, 4), (1000, 16, 128, 8), (32, 8, 256, 2),
])
@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_kernel(V, D, B, L, mode, dtype):
    rng = np.random.default_rng(V + B + L)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype)
    ids = rng.integers(-1, V, (B, L)).astype(np.int32)
    w = jnp.asarray(rng.random((B, L)), jnp.float32)
    want = eb_ref.embedding_bag(table, jnp.asarray(ids), w, mode=mode)
    got = eb_ops.embedding_bag(table, jnp.asarray(ids), w, mode=mode,
                               bt=min(32, B))
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_embedding_bag_no_weights_all_padded():
    table = jnp.ones((16, 8), jnp.float32)
    ids = jnp.full((32, 4), -1, jnp.int32)
    out = eb_ops.embedding_bag(table, ids, bt=32)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("Sq,Sk,dh,causal,window,q_off", [
    (128, 128, 64, True, None, 0),
    (64, 64, 32, False, None, 0),
    (128, 256, 64, True, 64, 0),      # sliding window
    (1, 256, 64, True, None, 255),    # decode: 1 query over long KV
    (64, 192, 128, True, None, 128),  # chunked-prefill continuation
    (96, 100, 64, True, None, 4),     # ragged Sk (pad path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(Sq, Sk, dh, causal, window, q_off, dtype):
    rng = np.random.default_rng(Sq + Sk + dh)
    BH = 3
    q = jnp.asarray(rng.normal(size=(BH, Sq, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(BH, Sk, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(BH, Sk, dh)), dtype)
    want = fa_ref.attention(q, k, v, causal=causal, window=window,
                            q_offset=q_off)
    got = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_off, bq=64, bk=64)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_chunked_attention():
    """The model's pure-jnp chunked attention and the kernel agree."""
    from repro.models.common import chunked_attention
    rng = np.random.default_rng(0)
    B, S, Hq, Hkv, dh = 2, 128, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, Hq, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)), jnp.float32)
    out_model = chunked_attention(q, k, v, q_offset=0, causal=True,
                                  kv_chunk=32)
    # kernel path: flatten (B, H) and repeat KV for GQA
    rep = Hq // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, dh)
    kf = jnp.repeat(k, rep, 2).transpose(0, 2, 1, 3).reshape(B * Hq, S, dh)
    vf = jnp.repeat(v, rep, 2).transpose(0, 2, 1, 3).reshape(B * Hq, S, dh)
    out_k = fa_ops.flash_attention(qf, kf, vf, causal=True, bq=64, bk=64)
    out_k = out_k.reshape(B, Hq, S, dh).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_model),
                               rtol=2e-5, atol=2e-5)
