"""Device-side distributed build: counter-stream twins, shard-count
independence, p=1 bit-parity with the host builders, capacity overflow
loudness, and the datasets.py de-clamping.  All single-device fast-lane
cases; the 16-device parity sweep is tests/_dist_bfs_main.py mode
"born" (test_bfs_distributed.py)."""
import numpy as np
import pytest

from repro.graph.rmat import (rmat_edges_counter, rmat_edges_counter_jax,
                              rmat_edges_counter_kernel, rmat_graph)

SCALE, EF, SEED = 9, 8, 3


def test_counter_twins_bit_identical():
    """numpy / jnp / Pallas generators are the same pure function of
    (seed, edge index)."""
    count = 1 << 10
    su, sv = rmat_edges_counter(SCALE, EF, seed=SEED, start=0, count=count)
    ju, jv = rmat_edges_counter_jax(SCALE, count, 0, edge_factor=EF,
                                    seed=SEED)
    ku, kv = rmat_edges_counter_kernel(SCALE, count, 0, edge_factor=EF,
                                       seed=SEED)
    assert np.array_equal(su, np.asarray(ju))
    assert np.array_equal(sv, np.asarray(jv))
    assert np.array_equal(su, np.asarray(ku))
    assert np.array_equal(sv, np.asarray(kv))


def test_counter_offset_slices():
    """A slice at an arbitrary offset equals that window of the full
    stream (the property the per-device slicing depends on)."""
    full_u, full_v = rmat_edges_counter(SCALE, EF, seed=SEED)
    u, v = rmat_edges_counter(SCALE, EF, seed=SEED, start=777, count=333)
    assert np.array_equal(u, full_u[777:1110])
    assert np.array_equal(v, full_v[777:1110])
    ku, kv = rmat_edges_counter_kernel(SCALE, 333, 777, edge_factor=EF,
                                       seed=SEED)
    assert np.array_equal(np.asarray(ku), full_u[777:1110])
    assert np.array_equal(np.asarray(kv), full_v[777:1110])


@pytest.mark.parametrize("p", [1, 2, 4, 7])
def test_counter_shard_count_independent(p):
    """Concatenating p per-shard slices reproduces the full stream for
    ANY p — shard k of p is reproducible independent of p."""
    m = EF << SCALE
    full_u, full_v = rmat_edges_counter(SCALE, EF, seed=SEED)
    m_per = -(-m // p)
    parts = [rmat_edges_counter(SCALE, EF, seed=SEED, start=k * m_per,
                                count=min(m_per, m - k * m_per))
             for k in range(p)]
    assert np.array_equal(np.concatenate([a for a, _ in parts]), full_u)
    assert np.array_equal(np.concatenate([b for _, b in parts]), full_v)


def test_rmat_graph_generator_arg():
    legacy = rmat_graph(SCALE, edge_factor=EF, seed=SEED)
    again = rmat_graph(SCALE, edge_factor=EF, seed=SEED,
                       generator="numpy")
    assert np.array_equal(legacy.src, again.src)   # pinned graphs intact
    counter = rmat_graph(SCALE, edge_factor=EF, seed=SEED,
                         generator="counter")
    assert counter.m_input == legacy.m_input
    assert not np.array_equal(legacy.src, counter.src)  # distinct streams
    with pytest.raises(ValueError):
        rmat_graph(SCALE, edge_factor=EF, generator="bogus")


def _single_device_mesh():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]), ("data",)), \
        Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_p1_build_parity_1d():
    """Device build at p=1 is bit-identical to the host builder on the
    counter-generated edge list: every device array, every capacity."""
    from repro.graph.dist_build import BuildSpec, dist_build_1d
    from repro.graph.formats import build_blocked_1d
    mesh1, _ = _single_device_mesh()
    spec = BuildSpec(scale=SCALE, edge_factor=EF, seed=SEED)
    gd, info = dist_build_1d(spec, 1, mesh1, align=32, cap_pad=32)
    edges = rmat_graph(SCALE, edge_factor=EF, seed=SEED,
                       generator="counter")
    gh = build_blocked_1d(edges, 1, align=32, cap_pad=32)
    assert (gd.cap, gd.cap_nzc, gd.maxdeg_col, gd.m, gd.m_input) == \
        (gh.cap, gh.cap_nzc, gh.maxdeg_col, gh.m, gh.m_input)
    ha = gh.device_arrays()
    for k, v in gd.device_arrays().items():
        assert np.array_equal(np.asarray(v), ha[k]), k
    assert info["m"] == gh.m and info["build_teps"] > 0


def test_p1_build_parity_2d():
    from repro.graph.dist_build import BuildSpec, dist_build_2d
    from repro.graph.formats import build_blocked
    _, mesh2 = _single_device_mesh()
    spec = BuildSpec(scale=SCALE, edge_factor=EF, seed=SEED)
    gd, _ = dist_build_2d(spec, 1, 1, mesh2, align=32, cap_pad=32)
    edges = rmat_graph(SCALE, edge_factor=EF, seed=SEED,
                       generator="counter")
    gh = build_blocked(edges, 1, 1, align=32, cap_pad=32)
    assert (gd.cap, gd.cap_seg, gd.maxdeg_col, gd.m) == \
        (gh.cap, gh.cap_seg, gh.maxdeg_col, gh.m)
    ha = gh.device_arrays()
    for k, v in gd.device_arrays().items():
        assert np.array_equal(np.asarray(v), ha[k]), k


def test_route_overflow_is_loud():
    """Starving the routing buckets must raise, never truncate edges."""
    from repro.graph.dist_build import BuildSpec, dist_build_1d
    mesh1, _ = _single_device_mesh()
    spec = BuildSpec(scale=SCALE, edge_factor=EF, seed=SEED)
    with pytest.raises(RuntimeError, match="route_slack"):
        dist_build_1d(spec, 1, mesh1, align=32, cap_pad=32,
                      route_slack=0.01)


def test_build_spec_validation():
    from repro.graph.dist_build import BuildSpec
    with pytest.raises(ValueError, match="int32"):
        BuildSpec(scale=31).validate()
    with pytest.raises(ValueError, match="uint32"):
        BuildSpec(scale=30, edge_factor=8).validate()
    BuildSpec(scale=18).validate()


def test_build_wire_closed_forms():
    from repro.core import comm_model
    assert comm_model.build_route_1d_words(1000, 4) == \
        pytest.approx(2 * 1000 * 3 / 4)
    assert comm_model.build_route_2d_words(1000, 2, 2) == \
        pytest.approx(2 * 1000 * (0.5 + 0.5))
    # padded volume dominates the measured minimum
    cap = comm_model.plan_cap_route(1000, 4)
    assert comm_model.build_route_padded_words(4, cap) >= \
        comm_model.build_route_1d_words(1000, 4)
    assert 0 < comm_model.rmat_strip_skew(16) < 1


# ---------------------------------------------------------------------------
# datasets.py de-clamping
# ---------------------------------------------------------------------------


def test_edges_for_small_path_unchanged():
    from repro.graph.datasets import _edges_for
    s, d = _edges_for(512, 4096, seed=0)
    assert s.size == 4096 and d.size == 4096
    assert s.max() < 512 and d.max() < 512


def test_edges_for_large_scale_uses_counter_not_clamp():
    """A scale-17 request previously clamped to scale 16 silently; now
    it comes from the counter stream at the TRUE scale."""
    from repro.graph.datasets import _edges_for
    n_nodes, n_edges = 1 << 17, 4096
    s, d = _edges_for(n_nodes, n_edges, seed=0)
    assert s.size == n_edges
    su, _ = rmat_edges_counter(17, 1, seed=0, start=0, count=n_edges)
    assert np.array_equal(s, (su % n_nodes).astype(np.int32))


def test_edges_for_impossible_request_raises():
    from repro.graph.datasets import _edges_for
    with pytest.raises(ValueError, match="dist_build"):
        _edges_for(1 << 31, 1 << 36, seed=0)
