"""Per-arch smoke tests: REDUCED configs of the same family, one
forward/train step on CPU, asserting shapes + no NaNs.  (Full configs are
exercised only via the dry-run per the instructions.)"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (GNNShape, get_config, list_archs, reduced)
from repro.graph.datasets import build_gnn_batch
from repro.models import autoint as ai
from repro.models import gnn as gnn_mod
from repro.models import mace as mace_mod
from repro.models import transformer as tf
from repro.models.common import ShardCtx
from repro.optim.adamw import AdamW

CTX = ShardCtx(mesh=None)
KEY = jax.random.PRNGKey(0)

LM_ARCHS = ["stablelm-3b", "smollm-135m", "starcoder2-7b",
            "qwen3-moe-30b-a3b", "mixtral-8x22b"]


def _reduced_lm(arch):
    cfg = get_config(arch)
    kw = dict(n_layers=2, d_model=64, d_ff=128, vocab=211, d_head=16)
    if cfg.n_heads % 4 == 0:
        kw.update(n_heads=4, n_kv_heads=max(cfg.n_kv_heads * 4 // cfg.n_heads, 1))
    else:
        kw.update(n_heads=3, n_kv_heads=1)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, n_experts=4, top_k=2,
                                        d_ff_expert=32)
    if cfg.swa_window:
        kw["swa_window"] = 8
    return reduced(cfg, **kw)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_smoke(arch):
    cfg = _reduced_lm(arch)
    p = tf.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 17), 0, cfg.vocab)
    opt = AdamW(lr=1e-3)
    ost = opt.init(p)

    def step(p, ost, t):
        loss, g = jax.value_and_grad(
            lambda p_: tf.lm_loss(p_, t[:, :-1], t[:, 1:], cfg, CTX,
                                  seq_chunk=8))(p)
        p, ost = opt.update(g, ost, p)
        return p, ost, loss

    p, ost, loss = jax.jit(step)(p, ost, toks)
    assert np.isfinite(float(loss)) and float(loss) > 0
    # decode step shape
    cache = tf.init_kv_cache(cfg, 2, 32)
    cache, logits = tf.decode_step(p, cache, toks[:, :1], jnp.int32(0),
                                   cfg, CTX)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


GNN_ARCHS = ["gin-tu", "gat-cora", "meshgraphnet"]


@pytest.mark.parametrize("arch", GNN_ARCHS)
@pytest.mark.parametrize("shape_name", ["full_graph_sm", "molecule"])
def test_gnn_arch_smoke(arch, shape_name):
    cfg = get_config(arch)
    shape = next(s for s in cfg.shapes if s.name == shape_name)
    b = build_gnn_batch(cfg, shape, reduce_to=16, seed=1)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    d_in = b["x"].shape[1]
    init, apply = gnn_mod.build_gnn_apply(cfg, d_in, cfg.n_classes)
    p = init(KEY)

    def loss_fn(p):
        out = apply(p, b)
        if shape.kind == "batched":
            ng = int(b["labels"].shape[0])
            return gnn_mod.graph_readout_xent(out, b["graph_ids"],
                                              b["labels"], ng)
        if arch == "meshgraphnet":
            return jnp.mean((out[:, :3] - b["targets"]) ** 2)
        return gnn_mod.node_xent(out, b["labels"],
                                 jnp.ones(out.shape[0]))

    loss, g = jax.jit(jax.value_and_grad(loss_fn))(p)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_mace_smoke_and_equivariance():
    cfg = reduced(get_config("mace"), d_hidden=16)
    shape = GNNShape("tiny", 20, 40, kind="full")
    b = build_gnn_batch(cfg, shape, seed=3)
    p = mace_mod.init_mace(cfg, KEY, n_species=8)
    args = (jnp.asarray(b["species"]), jnp.asarray(b["pos"]),
            jnp.asarray(b["senders"]), jnp.asarray(b["receivers"]),
            jnp.asarray(b["edge_mask"]), jnp.asarray(b["graph_ids"]), 1)
    e0 = mace_mod.mace_energy(p, cfg, *args)
    assert np.isfinite(np.asarray(e0)).all()
    # E(3) invariance: random rotation + translation leaves energy fixed
    rng = np.random.default_rng(0)
    A = rng.normal(size=(3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    pos2 = b["pos"] @ Q.T + rng.normal(size=(1, 3))
    e1 = mace_mod.mace_energy(p, cfg, args[0], jnp.asarray(pos2.astype(
        np.float32)), *args[2:])
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e0), rtol=2e-4)
    # gradient (forces) flow
    g = jax.grad(lambda pos: mace_mod.mace_energy(
        p, cfg, args[0], pos, *args[2:]).sum())(jnp.asarray(b["pos"]))
    assert np.isfinite(np.asarray(g)).all()


def test_autoint_smoke():
    cfg = reduced(get_config("autoint"), n_sparse=8, embed_dim=8,
                  n_attn_layers=2, n_heads=2, d_attn=8,
                  vocab_sizes=tuple([50] * 8), mlp_hidden=(32,))
    p = ai.init_params(cfg, KEY)
    idx = jax.random.randint(KEY, (16, 8), 0, 50)
    labels = jnp.asarray(np.random.default_rng(0).integers(0, 2, 16),
                         jnp.float32)
    loss, g = jax.jit(jax.value_and_grad(
        lambda p_: ai.bce_loss(p_, cfg, idx, labels, CTX)))(p)
    assert np.isfinite(float(loss))
    # retrieval scoring: 1 query x many candidates, batched dot
    u = ai.user_tower(p, cfg, idx[:1], CTX)
    cand = jax.random.normal(KEY, (1000, u.shape[-1]))
    s = ai.retrieval_scores(u, cand, CTX)
    assert s.shape == (1, 1000) and np.isfinite(np.asarray(s)).all()


def test_all_archs_registered():
    archs = set(list_archs())
    want = {"stablelm-3b", "smollm-135m", "starcoder2-7b",
            "qwen3-moe-30b-a3b", "mixtral-8x22b", "mace", "gin-tu",
            "gat-cora", "meshgraphnet", "autoint", "bfs-rmat",
            "bfs-rmat-csr", "bfs-rmat-topdown"}
    assert want <= archs, want - archs


def test_sampler_tree_shapes():
    from repro.graph.sampler import khop_sample
    rng = np.random.default_rng(0)
    n = 200
    deg = rng.integers(0, 8, n)
    rp = np.zeros(n + 1, np.int32)
    rp[1:] = np.cumsum(deg)
    ci = rng.integers(0, n, int(rp[-1])).astype(np.int32)
    seeds = jnp.asarray(rng.integers(0, n, 16), jnp.int32)
    out = jax.jit(lambda k, s: khop_sample(k, jnp.asarray(rp),
                                           jnp.asarray(ci), s, (5, 3)))(
        KEY, seeds)
    assert out["node_ids"].shape == (16 + 80 + 240,)
    assert out["senders"].shape == out["receivers"].shape == (320,)
    # receivers always point at earlier layers (tree property)
    assert (np.asarray(out["receivers"]) < 16 + 80).all()
    assert (np.asarray(out["senders"]) >= 16).all()
