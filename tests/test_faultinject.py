"""Self-healing under injected faults: GraphStore shard corruption ->
quarantine + bit-identical regeneration, orphaned tmp-dir sweeps, the
bounded replan-retry drivers (route_slack in dist_build, cap_x in
run_bfs_healed), BuildSpec-driven elastic repartitioning, straggler
wiring, and (slow) the full seeded fault-matrix CLI on forced host
devices."""
import glob
import importlib.util
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.ckpt.elastic import repartition_graph
from repro.ckpt.graph_store import GraphStore, shard_crc32
from repro.configs.base import BFSConfig
from repro.core.engine import plan_bfs, run_bfs_healed
from repro.graph.dist_build import (BuildSpec, dist_build, dist_build_1d,
                                    regen_shard)
from repro.graph.formats import build_blocked, build_blocked_1d
from repro.graph.rmat import rmat_graph
from repro.launch.mesh import make_local_mesh, make_local_mesh_1d
from repro.runtime.faultinject import (corrupt_shard, undersize_cap,
                                       undersize_route_slack)
from repro.runtime.retry import CapacityOverflow, RetryAttempt, escalate
from repro.runtime.straggler import StragglerMonitor

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SPEC = BuildSpec(scale=8, edge_factor=8, seed=3)


def _build(decomp="1ds"):
    mesh = make_local_mesh(1, 1) if decomp == "2d" \
        else make_local_mesh_1d(1)
    grid = (1, 1)
    g, info = dist_build(SPEC, decomp, mesh, grid, align=32, cap_pad=32)
    return g, info, mesh


def _arrays(g):
    return {k: np.asarray(v) for k, v in g.device_arrays().items()}


# ---------------------------------------------------------------------------
# retry primitives
# ---------------------------------------------------------------------------


def test_escalate_doubles_and_clamps():
    assert escalate(32) == 64
    assert escalate(32, factor=4) == 128
    assert escalate(96, ceiling=128) == 128
    assert escalate(128, ceiling=128) == 128


def test_capacity_overflow_carries_history():
    hist = [RetryAttempt(1, "cap_x", 32, "overflow", {"levels": [2]}),
            RetryAttempt(2, "cap_x", 64, "ok", {})]
    e = CapacityOverflow("bucket overflow", cap_name="cap_x",
                         cap_value=64, history=hist)
    assert "escalation history" in str(e)
    assert "attempt 1: cap_x=32 -> overflow" in str(e)
    assert e.history == tuple(hist)
    assert e.history_json()[0]["outcome"] == "overflow"
    plain = CapacityOverflow("no history")
    assert "escalation" not in str(plain)


# ---------------------------------------------------------------------------
# store corruption -> quarantine + regeneration
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("decomp,mode", [("1ds", "flip"),
                                         ("1d", "truncate"),
                                         ("2d", "flip"),
                                         ("2d", "truncate")])
def test_corrupted_shard_quarantined_and_regenerated(tmp_path, decomp,
                                                     mode):
    g, _, _ = _build(decomp)
    store = GraphStore(str(tmp_path))
    store.save_graph("g", g, spec=SPEC)
    path = corrupt_shard(store, "g", seed=2, mode=mode)
    loaded = store.load_graph("g", expect_spec=SPEC)
    rep = store.last_load_report
    assert [r["shard"] for r in rep["repaired"]] == [0]
    assert os.path.exists(path + ".quarantined")
    want = _arrays(g)
    got = _arrays(loaded)
    assert set(got) == set(want)
    for k in want:
        assert np.array_equal(want[k], got[k]), (decomp, mode, k)
    # the repaired file is clean: a second load repairs nothing
    store.load_graph("g", expect_spec=SPEC)
    assert store.last_load_report["repaired"] == []


def test_repair_disabled_raises(tmp_path):
    g, _, _ = _build("1ds")
    store = GraphStore(str(tmp_path))
    store.save_graph("g", g, spec=SPEC)
    corrupt_shard(store, "g", seed=2, mode="flip")
    with pytest.raises(RuntimeError, match="repair disabled"):
        store.load_graph("g", expect_spec=SPEC, repair=False)


def test_repair_without_spec_raises(tmp_path):
    g, _, _ = _build("1ds")
    store = GraphStore(str(tmp_path))
    store.save_graph("g", g)                  # no BuildSpec in the meta
    corrupt_shard(store, "g", seed=2, mode="flip")
    with pytest.raises(RuntimeError, match="spec"):
        store.load_graph("g")


def test_regen_shard_matches_saved_crc(tmp_path):
    """regen_shard reproduces the device-built shard bit-for-bit — the
    CRC equality the repair path refuses to publish without."""
    for decomp in ("1ds", "2d"):
        g, _, _ = _build(decomp)
        store = GraphStore(str(tmp_path))
        store.save_graph(f"g-{decomp}", g, spec=SPEC)
        gdir = os.path.join(str(tmp_path), "graphs", f"g-{decomp}")
        sdir = sorted(glob.glob(os.path.join(gdir, "step_*")))[-1]
        with open(os.path.join(sdir, "meta.json")) as f:
            meta = json.load(f)
        arrs = regen_shard(SPEC, meta["graph_kind"], g.part, 0,
                           json.loads(meta["scalars"]),
                           json.loads(meta["fields"]))
        assert shard_crc32(arrs) == meta["shard_crc32"][0]


def test_tmp_dirs_swept_on_open(tmp_path):
    g, _, _ = _build("1ds")
    store = GraphStore(str(tmp_path))
    store.save_graph("g", g, spec=SPEC)
    orphan = os.path.join(str(tmp_path), "graphs", "g",
                          ".tmp_interrupted")
    os.makedirs(orphan)
    with open(os.path.join(orphan, "shard_00000.npz"), "wb") as f:
        f.write(b"partial")
    store2 = GraphStore(str(tmp_path))
    assert not os.path.exists(orphan)
    assert store2.swept == [orphan]
    store2.load_graph("g", expect_spec=SPEC)   # untouched by the sweep


# ---------------------------------------------------------------------------
# bounded replan-retry: route_slack (build) and cap_x (traversal)
# ---------------------------------------------------------------------------


def test_dist_build_heals_route_overflow():
    mesh = make_local_mesh_1d(1)
    with pytest.raises(CapacityOverflow, match="route_slack"):
        dist_build_1d(SPEC, 1, mesh, route_slack=0.3)
    g, info = dist_build(SPEC, "1d", mesh, 1, route_slack=0.3)
    log = info["retry_log"]
    assert [e["outcome"] for e in log] == ["overflow", "overflow", "ok"]
    assert [e["cap_value"] for e in log] == [0.3, 0.6, 1.2]
    ref, _ = dist_build_1d(SPEC, 1, mesh, route_slack=1.2)
    want, got = _arrays(ref), _arrays(g)
    for k in want:
        assert np.array_equal(want[k], got[k]), k


def test_dist_build_clean_first_attempt_logs_nothing():
    g, info, _ = _build("1ds")
    assert info["retry_log"] == []


def test_dist_build_exhaustion_reraises_with_history():
    mesh = make_local_mesh_1d(1)
    with pytest.raises(CapacityOverflow, match="escalation history") as ei:
        dist_build(SPEC, "1d", mesh, 1, route_slack=0.001,
                   max_attempts=2)
    assert len(ei.value.history) == 2
    assert "route_slack" in str(ei.value)


def test_run_bfs_healed_clean_plan_empty_log():
    g, _, mesh = _build("1ds")
    cfg = BFSConfig(decomposition="1ds", instrument=False,
                    direction_optimizing=False)
    h = run_bfs_healed(g, cfg, mesh, 5)
    assert h.retry_log == []
    assert not h.plan.cfg.instrument          # fast program, not probe
    base = plan_bfs(g, cfg, mesh).compile().run(5)
    assert np.array_equal(h.result.parents, base.parents)


def test_run_bfs_healed_non_1ds_single_attempt():
    g, _, mesh = _build("2d")
    cfg = BFSConfig(decomposition="2d", instrument=False)
    h = run_bfs_healed(g, cfg, mesh, 5, validate=True)
    assert h.retry_log == []
    assert h.result.validation.ok


def test_undersize_helpers_seeded():
    assert undersize_cap(512, 3) == undersize_cap(512, 3)
    assert 32 <= undersize_cap(512, 3) < 512
    assert undersize_cap(512, 3) % 32 == 0
    s = undersize_route_slack(3)
    assert s == undersize_route_slack(3) and 0.2 <= s < 0.45


# ---------------------------------------------------------------------------
# elastic repartitioning from a BuildSpec
# ---------------------------------------------------------------------------


def test_repartition_from_spec_matches_host_reblock():
    """BuildSpec-driven repartitioning lands the same blocked graph a
    host re-block of the same edge stream produces (p=1 parity, both
    strip and checkerboard targets)."""
    edges = rmat_graph(SPEC.scale, SPEC.edge_factor, seed=SPEC.seed,
                       generator="counter")
    g1 = repartition_graph(spec=SPEC, mesh=make_local_mesh_1d(1),
                           pr=1, pc=1, decomposition="1ds",
                           align=32, cap_pad=32)
    h1 = build_blocked_1d(edges, 1, align=32, cap_pad=32)
    g2 = repartition_graph(spec=SPEC, mesh=make_local_mesh(1, 1),
                           pr=1, pc=1, decomposition="2d",
                           align=32, cap_pad=32)
    h2 = build_blocked(edges, 1, 1, align=32, cap_pad=32)
    for dev, host in ((g1, h1), (g2, h2)):
        want, got = _arrays(host), _arrays(dev)
        for k in got:
            if k in want:
                assert np.array_equal(want[k], got[k]), k


def test_repartition_argument_errors():
    with pytest.raises(ValueError, match="mesh"):
        repartition_graph(spec=SPEC)
    with pytest.raises(ValueError, match="EdgeList or a"):
        repartition_graph()


# ---------------------------------------------------------------------------
# straggler wiring
# ---------------------------------------------------------------------------


def test_run_many_feeds_straggler_monitor():
    g, _, mesh = _build("1ds")
    eng = plan_bfs(g, BFSConfig(decomposition="1ds",
                                instrument=False), mesh).compile()
    mon = StragglerMonitor(min_samples=2, factor=1e-9)
    res = eng.run_many([5, 6, 7, 8], monitor=mon)
    assert len(res) == 4
    # with a zero deadline every post-warmup root is an "event"
    assert len(mon.events) == 2
    assert [e[0] for e in mon.events] == [2, 3]


def test_worker_monitor_plumbing():
    spec = importlib.util.spec_from_file_location(
        "bench_worker", os.path.join(_ROOT, "benchmarks", "worker.py"))
    worker = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(worker)
    assert worker._monitor_from({}) is None
    mon = worker._monitor_from({"straggler": {"min_samples": 1,
                                              "factor": 2.0}})
    assert mon.min_samples == 1 and mon.factor == 2.0
    mon.observe(0, 0.01)
    mon.observe(1, 10.0)
    blk = worker._monitor_block(mon)
    assert blk["straggler_events"][0]["step"] == 1
    assert blk["straggler_deadline_s"] == pytest.approx(mon.deadline)
    assert worker._monitor_block(None) == {}


# ---------------------------------------------------------------------------
# the full seeded matrix, multi-device (slow subprocess lane)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fault_matrix_cli_multidevice(tmp_path):
    """The CI faults lane end-to-end on 4 forced host devices: 100%
    kill rate on every injected corruption class, cap_x and route_slack
    escalations actually escalate, store shards regenerate."""
    out = str(tmp_path / "faults.json")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.runtime.faultinject",
         "--devices", "4", "--scale", "9", "--seed", "0",
         "--json", out],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    rep = json.load(open(out))
    assert rep["ok"] and len(rep["cases"]) == 22
    names = {c["name"] for c in rep["cases"]}
    for d in ("1d", "1ds", "2d"):
        assert f"clean/{d}" in names
        for kind in ("flip_bit", "phantom_parent", "level_skew",
                     "orphan_leaf", "drop_subrange"):
            assert f"kill/{d}/{kind}" in names
    by = {c["name"]: c for c in rep["cases"]}
    # escalations really escalated (scale 9 / 4 strips overflows both)
    assert by["heal/cap_x"]["detail"]["retry_log"][-1]["outcome"] == "ok"
    assert len(by["heal/cap_x"]["detail"]["retry_log"]) >= 2
    assert by["heal/route_slack"]["detail"]["retry_log"][-1]["outcome"] \
        == "ok"
    assert by["store/1ds/flip"]["detail"]["repaired"]
    assert by["store/2d/truncate"]["detail"]["repaired"]
