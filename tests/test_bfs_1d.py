"""1D row-decomposition BFS: oracle parity, partition/format invariants,
dispatch errors, and the 16-device subprocess acceptance case."""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import BFSConfig, get_config
from repro.core import comm_model
from repro.core.bfs import run_bfs
from repro.core.partition import make_partition, make_partition_1d
from repro.core.ref import bfs_depths, depths_from_parents, validate_parents
from repro.graph.formats import build_blocked, build_blocked_1d
from repro.graph.rmat import preprocess, rmat_graph
from repro.launch.mesh import make_local_mesh_1d

_HERE = os.path.dirname(__file__)


# ---------------------------------------------------------------------------
# Partition + format invariants
# ---------------------------------------------------------------------------


@given(st.integers(1, 5000), st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_partition_1d_layout(n, p):
    part = make_partition_1d(n, p, align=32)
    assert part.n % (part.p * 32) == 0
    assert part.chunk * part.p == part.n
    assert part.decomposition == "1d"
    v = np.arange(part.n)
    i, off = part.owner(v)
    assert np.array_equal(i * part.chunk + off, v)
    blocks = part.vec_to_blocks(v)
    assert blocks.shape == (p, part.chunk)
    assert np.array_equal(part.blocks_to_vec(blocks), v[:n])


@given(st.integers(1, 2000), st.integers(1, 6), st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_partition_1d_2d_padding_agrees(n, pr, pc):
    """The unified-API contract: 1D over p=pr*pc and 2D over (pr, pc) pad
    to the same n, so depth arrays are comparable element-for-element."""
    p1 = make_partition_1d(n, pr * pc, align=32)
    p2 = make_partition(n, pr, pc, align=32)
    assert p1.n == p2.n and p1.chunk == p2.chunk


@pytest.mark.parametrize("p", [1, 2, 4])
def test_blocked_1d_roundtrip(p):
    e = rmat_graph(9, edge_factor=8, seed=4)
    g = build_blocked_1d(e, p, align=32, cap_pad=32)
    part = g.part
    got = set()
    for i in range(p):
        k = int(g.nnz[i])
        # top-down orientation: global source, local dest
        for t in range(k):
            got.add((int(g.edge_src[i, t]),
                     int(g.row_idx[i, t]) + i * part.chunk))
        # CSR orientation covers the same edges with consistent pointers
        assert g.row_ptr[i, -1] == k
        rows = np.repeat(np.arange(part.chunk),
                         np.diff(g.row_ptr[i]).astype(np.int64))
        assert np.array_equal(rows, g.edge_dst[i, :k])
        csr_edges = set(zip(g.col_idx[i, :k].tolist(),
                            (rows + i * part.chunk).tolist()))
        assert csr_edges == {(u, v) for u, v in got
                             if i * part.chunk <= v < (i + 1) * part.chunk}
    assert got == set(zip(e.src.tolist(), e.dst.tolist()))
    # out-degrees concatenate to the global degree vector
    deg = np.bincount(e.src, minlength=part.n)
    assert np.array_equal(g.deg_A.reshape(-1), deg)


# ---------------------------------------------------------------------------
# Oracle parity (single device, property-based)
# ---------------------------------------------------------------------------


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_bfs_1d_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 60))
    m = int(rng.integers(1, 4 * n))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    e = preprocess(src, dst, n, symmetrize=True)
    if e.m == 0:
        return
    root = int(e.src[0])
    g = build_blocked_1d(e, 1, align=32, cap_pad=32)
    cfg = BFSConfig(decomposition="1d",
                    direction_optimizing=bool(rng.integers(0, 2)))
    res = run_bfs(g, root, cfg, make_local_mesh_1d(1))
    ok, msg = validate_parents(n, e.src, e.dst, root, res.parents)
    assert ok, msg
    d = bfs_depths(n, e.src, e.dst, root)
    assert np.array_equal(depths_from_parents(n, res.parents, root), d)


def test_bfs_1d_registered_configs():
    cfg = get_config("bfs-rmat-1d")
    assert cfg.decomposition == "1d" and cfg.direction_optimizing
    e = rmat_graph(8, edge_factor=8, seed=1)
    g = build_blocked_1d(e, 1, align=32, cap_pad=32)
    root = int(e.src[0])
    res = run_bfs(g, root, cfg, make_local_mesh_1d(1))
    ok, msg = validate_parents(e.n, e.src, e.dst, root, res.parents)
    assert ok, msg
    assert res.counters["edges_examined"] > 0


def test_dispatch_rejects_mismatched_graph():
    e = rmat_graph(8, edge_factor=8, seed=1)
    g1 = build_blocked_1d(e, 1, align=32, cap_pad=32)
    g2 = build_blocked(e, 1, 1, align=32, cap_pad=32)
    mesh = make_local_mesh_1d(1)
    with pytest.raises(TypeError):
        run_bfs(g2, 0, BFSConfig(decomposition="1d"), mesh)
    with pytest.raises(TypeError):
        run_bfs(g1, 0, BFSConfig(), mesh)


def test_comm_model_1d_forms():
    # p=1 moves nothing; volume grows linearly in levels and ~p
    assert comm_model.expand_1d_words(1 << 20, 1, 5) == 0.0
    assert (comm_model.expand_1d_words(1 << 20, 16, 10)
            == 2 * comm_model.expand_1d_words(1 << 20, 16, 5))
    assert comm_model.topdown_1d_words(1000, 1) == 0.0
    assert comm_model.topdown_1d_words(1000, 16) == 2.0 * 1000 * 15 / 16


# ---------------------------------------------------------------------------
# Multi-device acceptance case (subprocess, 16 forced host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_distributed_bfs_1d_matches_2d():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    main = os.path.join(_HERE, "_dist_bfs_main.py")
    r = subprocess.run([sys.executable, main, "16", "oned"],
                       capture_output=True, text=True, timeout=1200,
                       env=env)
    assert r.returncode == 0, f"oned failed:\n{r.stdout}\n{r.stderr}"
    assert "OK oned" in r.stdout
