"""Per-kernel allclose vs pure-jnp oracles: spmsv gather + bottom-up
sub-step, swept over shapes and frontier densities (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.frontier import INT_INF, pack_bits
from repro.kernels.bottomup.ops import bottomup_substep as bu_kernel
from repro.kernels.bottomup.ref import bottomup_substep as bu_ref
from repro.kernels.spmsv import ops as spmsv_ops
from repro.kernels.spmsv.ref import spmsv_dense


def _random_block(rng, nc, nr, density):
    """Random CSC block + matching CSR orientation arrays."""
    mask = rng.random((nr, nc)) < density
    v, u = np.nonzero(mask)
    order = np.lexsort((v, u))                       # CSC: by (u, v)
    u_c, v_c = u[order], v[order]
    col_ptr = np.zeros(nc + 1, np.int32)
    np.add.at(col_ptr, u_c + 1, 1)
    col_ptr = np.cumsum(col_ptr).astype(np.int32)
    order_r = np.lexsort((u, v))                     # CSR: by (v, u)
    u_r, v_r = u[order_r], v[order_r]
    row_ptr = np.zeros(nr + 1, np.int32)
    np.add.at(row_ptr, v_r + 1, 1)
    row_ptr = np.cumsum(row_ptr).astype(np.int32)
    return (col_ptr, v_c.astype(np.int32), u_c.astype(np.int32),
            row_ptr, u_r.astype(np.int32))


@pytest.mark.parametrize("nc,nr,density", [
    (64, 64, 0.05), (128, 64, 0.2), (32, 96, 0.5), (256, 128, 0.01),
])
@pytest.mark.parametrize("fdensity", [0.0, 0.1, 1.0])
def test_spmsv_kernel_matches_dense(nc, nr, density, fdensity):
    rng = np.random.default_rng(nc + nr + int(100 * (density + fdensity)))
    col_ptr, row_idx, edge_src, _, _ = _random_block(rng, nc, nr, density)
    nnz = int(col_ptr[-1])
    f_cj = jnp.asarray(rng.random(nc) < fdensity)
    col_offset = jnp.int32(1000)
    want = spmsv_dense(jnp.asarray(edge_src), jnp.asarray(row_idx),
                       jnp.int32(nnz), f_cj, nr, col_offset)
    maxdeg = max(int(np.diff(col_ptr).max()), 1)
    ridx = jnp.pad(jnp.asarray(row_idx), (0, 256))
    got = spmsv_ops.spmsv_block_csr(jnp.asarray(col_ptr), ridx, f_cj, nr,
                                    col_offset, cap_f=nc, maxdeg=maxdeg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # DCSC path: build compressed pointers and require identical output
    deg = np.diff(col_ptr)
    nzcols = np.flatnonzero(deg)
    jc = np.full(max(len(nzcols), 1) + 3, nc, np.int32)
    cp = np.zeros(jc.size + 1, np.int32)
    jc[:len(nzcols)] = nzcols
    cp[:len(nzcols)] = col_ptr[nzcols]
    cp[len(nzcols):] = nnz
    got2 = spmsv_ops.spmsv_block_dcsc(
        jnp.asarray(jc), jnp.asarray(cp), jnp.int32(len(nzcols)), ridx,
        f_cj, nr, col_offset, cap_f=nc, maxdeg=maxdeg)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(want))


@pytest.mark.parametrize("chunk,n", [(32, 64), (64, 256), (96, 32)])
@pytest.mark.parametrize("fdensity", [0.0, 0.2, 1.0])
def test_spmsv_strip_kernel_matches_dense(chunk, n, fdensity):
    """The 1D strip kernel (global column ids, bitmap test inside the
    kernel, col_offset structurally 0) must match the dense oracle."""
    rng = np.random.default_rng(chunk + n + int(10 * fdensity))
    m = 4 * chunk
    u = np.sort(rng.integers(0, n, m)).astype(np.int32)   # global sources
    v = rng.integers(0, chunk, m).astype(np.int32)        # local dests
    order = np.lexsort((v, u))
    u, v = u[order], v[order]
    f = rng.random(n) < fdensity
    f_words = pack_bits(jnp.asarray(f))
    want = spmsv_dense(jnp.asarray(u), jnp.asarray(v), jnp.int32(m),
                       jnp.asarray(f), chunk, jnp.int32(0))
    # strip DCSC over the sorted edges
    cols, first = np.unique(u, return_index=True)
    nzc = len(cols)
    cap_nzc = nzc + 5
    jc = np.full(cap_nzc, n, np.int32)
    cp = np.full(cap_nzc + 1, m, np.int32)
    jc[:nzc], cp[:nzc] = cols, first
    maxdeg = int(np.diff(np.append(first, m)).max())
    got = spmsv_ops.spmsv_strip_dcsc(
        jnp.asarray(jc), jnp.asarray(cp), jnp.int32(nzc),
        jnp.pad(jnp.asarray(v), (0, 256)), f_words, chunk, maxdeg=maxdeg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("chunk,nc", [(32, 64), (128, 128), (256, 32)])
@pytest.mark.parametrize("fdensity,cdensity", [
    (0.0, 0.0), (0.3, 0.0), (0.3, 0.5), (1.0, 0.9), (1.0, 1.0),
])
def test_bottomup_kernel_matches_ref(chunk, nc, fdensity, cdensity):
    rng = np.random.default_rng(chunk + nc + int(10 * (fdensity + cdensity)))
    # a segment of `chunk` rows with random degrees
    deg = rng.integers(0, 9, chunk)
    rp = np.zeros(chunk + 1, np.int32)
    rp[1:] = np.cumsum(deg)
    n_edges = int(rp[-1])
    cap_seg = ((n_edges + 127) // 128) * 128 + 128
    ue = np.zeros(cap_seg, np.int32)
    ue[:n_edges] = rng.integers(0, nc, n_edges)
    f = rng.random(nc) < fdensity
    f_words = pack_bits(jnp.asarray(f))
    cvec = (rng.random(chunk) < cdensity).astype(np.int32)
    col_offset, ne = jnp.int32(7 * nc), jnp.int32(n_edges)
    want = bu_ref(jnp.asarray(rp), jnp.asarray(ue), f_words,
                  jnp.asarray(cvec), col_offset, ne)
    got = bu_kernel(jnp.asarray(rp), jnp.pad(jnp.asarray(ue), (0, 512)),
                    f_words, jnp.asarray(cvec), col_offset, ne,
                    rt=min(128, chunk))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_bottomup_kernel_property(seed):
    rng = np.random.default_rng(seed)
    chunk = 32 * int(rng.integers(1, 5))
    nc = 32 * int(rng.integers(1, 6))
    deg = rng.integers(0, 6, chunk)
    rp = np.zeros(chunk + 1, np.int32)
    rp[1:] = np.cumsum(deg)
    n_edges = int(rp[-1])
    cap_seg = max(((n_edges + 127) // 128) * 128, 128)
    ue = np.zeros(cap_seg, np.int32)
    ue[:n_edges] = rng.integers(0, nc, n_edges)
    f = rng.random(nc) < rng.random()
    f_words = pack_bits(jnp.asarray(f))
    cvec = (rng.random(chunk) < rng.random()).astype(np.int32)
    args = (jnp.asarray(rp), jnp.asarray(ue), f_words, jnp.asarray(cvec),
            jnp.int32(0), jnp.int32(n_edges))
    want = bu_ref(*args)
    got = bu_kernel(args[0], jnp.pad(args[1], (0, 512)), *args[2:], rt=32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # invariants: completed rows never get parents; parents are in frontier
    out = np.asarray(got)
    assert (out[cvec == 1] == INT_INF).all()
    disc = np.flatnonzero(out != INT_INF)
    assert all(f[out[d]] for d in disc)
