"""Unit tests for ``engine.hlo_collective_counts`` against committed
dump fixtures — the counter every perf budget (test_perf_guard, lint
rule R4, the bench trajectory) stands on.

Three fixtures under tests/fixtures/hlo/ (see regen.py there):

  * probe.stablehlo.txt — lowered StableHLO: the underscore
    ``"stablehlo.all_reduce"(...)`` spellings;
  * probe.compiled.txt — compiled CPU HLO: hyphenated
    ``all-reduce(...)`` spellings, tuple-shaped all-to-all, operand
    references like ``%all-to-all.2)`` that must not count;
  * tpu_async.hlo.txt — hand-written TPU-style dump: async
    ``-start``/``-done`` pairs (one op each, not two, and never the
    intermediate ``-done``), ``reduce-scatter``, and collective names
    embedded in ``metadata={op_name="..."}`` strings, which the quote
    guard in ``_COLLECTIVE_OP_RE`` must NOT count (an earlier regex
    scanned across the quoted op_name and over-counted fusions whose
    provenance mentioned a collective).
"""
import os
import re

from repro.core.engine import hlo_collective_counts

_FIX = os.path.join(os.path.dirname(__file__), "fixtures", "hlo")


def _read(name):
    with open(os.path.join(_FIX, name)) as fh:
        return fh.read()


def test_stablehlo_spellings():
    counts = hlo_collective_counts(_read("probe.stablehlo.txt"))
    assert counts == {"all-reduce": 1, "all-gather": 1, "all-to-all": 1,
                      "collective-permute": 1, "total": 4}


def test_compiled_hlo_spellings():
    """Compiled CPU HLO: one op each; the tuple-shaped all-to-all
    result and later get-tuple-element operand references must not
    inflate the count."""
    text = _read("probe.compiled.txt")
    counts = hlo_collective_counts(text)
    assert counts == {"all-reduce": 1, "all-gather": 1, "all-to-all": 1,
                      "collective-permute": 1, "total": 4}


def test_tpu_async_pairs_count_once():
    """``all-reduce-start``/``-done`` is ONE collective; the fixture
    issues ar/ag/cp as async pairs plus a sync reduce-scatter."""
    counts = hlo_collective_counts(_read("tpu_async.hlo.txt"))
    assert counts == {"all-reduce": 1, "all-gather": 1,
                      "collective-permute": 1, "reduce-scatter": 1,
                      "total": 4}


def test_metadata_op_names_do_not_count():
    """The fixture's fusion/copy lines carry
    ``metadata={op_name=".../all-gather(fold)"}`` — provenance strings,
    not ops.  The quote guard keeps the match from scanning into them;
    scrubbing every metadata clause from the dump must not change the
    counts (if it does, metadata strings were being counted)."""
    text = _read("tpu_async.hlo.txt")
    scrubbed = re.sub(r", metadata=\{[^}]*\}", "", text)
    assert "all-gather(fold)" in text and "all-gather(fold)" not in scrubbed
    assert hlo_collective_counts(text) == hlo_collective_counts(scrubbed)


def test_quote_guard_regression():
    """Minimal reproduction of the miscount the quote guard fixed: a
    fusion whose op_name embeds ``all-gather(``.  The pre-fix regex
    (scan ``[^=\\n]*?`` from ``=`` to the op name) crossed the quote
    and counted it."""
    line = ('  %fusion.9 = f32[8]{0} fusion(f32[8]{0} %p.0), kind=kLoop, '
            'calls=%fc, metadata={op_name="while/body/all-gather(fold)"}\n')
    assert hlo_collective_counts(line) == {"total": 0}
    buggy = re.compile(
        r"=\s*[^=\n]*?\b(all-reduce|all-gather|all-to-all|reduce-scatter|"
        r"collective-permute)(?:-start)?\(")
    assert buggy.search(line), "hazard line no longer reproduces the bug"
