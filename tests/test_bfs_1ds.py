"""Sparse-exchange 1D decomposition ("1ds"): oracle parity, the
overflow-fallback hybrid, the sparse-exchange comm-model closed forms,
cap_x planning, and the 16-device subprocess acceptance case."""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import BFSConfig, get_config
from repro.core import comm_model
from repro.core.bfs import run_bfs
from repro.core.engine import plan_bfs
from repro.core.ref import bfs_depths, depths_from_parents, validate_parents
from repro.graph.formats import build_blocked, build_blocked_1d
from repro.graph.rmat import preprocess, rmat_graph
from repro.launch.mesh import make_local_mesh, make_local_mesh_1d

_HERE = os.path.dirname(__file__)


# ---------------------------------------------------------------------------
# Oracle parity (single device, property-based; random cap_x exercises
# both the sparse path and the overflow fallback)
# ---------------------------------------------------------------------------


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_bfs_1ds_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 60))
    m = int(rng.integers(1, 4 * n))
    e = preprocess(rng.integers(0, n, m), rng.integers(0, n, m), n,
                   symmetrize=True)
    if e.m == 0:
        return
    root = int(e.src[0])
    g = build_blocked_1d(e, 1, align=32, cap_pad=32)
    cfg = BFSConfig(decomposition="1ds",
                    direction_optimizing=bool(rng.integers(0, 2)))
    cap_x = int(rng.choice([0, 32, g.part.chunk]))
    res = run_bfs(g, root, cfg, make_local_mesh_1d(1), cap_x=cap_x)
    ok, msg = validate_parents(n, e.src, e.dst, root, res.parents)
    assert ok, msg
    d = bfs_depths(n, e.src, e.dst, root)
    assert np.array_equal(depths_from_parents(n, res.parents, root), d)


def test_bfs_1ds_registered_config():
    cfg = get_config("bfs-rmat-1ds")
    assert cfg.decomposition == "1ds" and cfg.direction_optimizing
    e = rmat_graph(8, edge_factor=8, seed=1)
    g = build_blocked_1d(e, 1, align=32, cap_pad=32)
    root = int(e.src[0])
    res = run_bfs(g, root, cfg, make_local_mesh_1d(1))
    ok, msg = validate_parents(e.n, e.src, e.dst, root, res.parents)
    assert ok, msg
    assert res.counters["edges_examined"] > 0


# ---------------------------------------------------------------------------
# Three-way parity on the same fixed R-MAT graph
# ---------------------------------------------------------------------------


def test_parity_1ds_vs_1d_vs_2d():
    """Single-device candidate-min semantics are identical across the
    three decompositions, so the parent arrays (not just depths) must
    agree — and 1ds must leave the 1D-absent wire phases at zero."""
    e = rmat_graph(8, edge_factor=8, seed=4)
    g1 = build_blocked_1d(e, 1, align=32, cap_pad=32)
    g2 = build_blocked(e, 1, 1, align=32, cap_pad=32)
    root = int(np.flatnonzero(e.out_degrees())[0])
    r1 = run_bfs(g1, root, BFSConfig(decomposition="1d"),
                 make_local_mesh_1d(1))
    rs = run_bfs(g1, root, BFSConfig(decomposition="1ds"),
                 make_local_mesh_1d(1))
    r2 = run_bfs(g2, root, BFSConfig(), make_local_mesh(1, 1))
    assert np.array_equal(rs.parents, r1.parents)
    d2 = depths_from_parents(e.n, r2.parents, root)
    assert np.array_equal(depths_from_parents(e.n, rs.parents, root), d2)
    assert rs.n_levels == r1.n_levels
    for k in ("wire_transpose", "wire_fold", "wire_rotate", "wire_updates"):
        assert rs.counters[k] == 0.0, k


# ---------------------------------------------------------------------------
# Overflow fallback
# ---------------------------------------------------------------------------


def test_overflow_falls_back_to_dense_bitmap():
    """With buckets far smaller than the mid-search frontier, the sparse
    path would TRUNCATE ids; the pmax-guarded fallback must take the
    dense bitmap on those levels instead, leaving the tree exact."""
    e = rmat_graph(9, edge_factor=8, seed=7)
    g = build_blocked_1d(e, 1, align=32, cap_pad=32)
    root = int(np.flatnonzero(e.out_degrees())[0])
    cfg = BFSConfig(decomposition="1ds", direction_optimizing=False)
    tiny = run_bfs(g, root, cfg, make_local_mesh_1d(1), cap_x=32)
    # the frontier really does exceed the buckets at some level
    assert tiny.level_stats[: tiny.n_levels, 0].max() > 32
    ok, msg = validate_parents(e.n, e.src, e.dst, root, tiny.parents)
    assert ok, msg
    big = run_bfs(g, root, cfg, make_local_mesh_1d(1), cap_x=g.part.chunk)
    assert np.array_equal(tiny.parents, big.parents)
    assert tiny.n_levels == big.n_levels


def test_batch_level_stats_match_single_runs():
    """run_batch reports each root's own per-level stats; at pods=1 they
    must be bit-identical to the single-root program's (the per-slice
    heuristic regression proper needs >1 pod — the ``podheur``
    subprocess case in tests/_dist_bfs_main.py pins that)."""
    e = rmat_graph(8, edge_factor=8, seed=4)
    g = build_blocked_1d(e, 1, align=32, cap_pad=32)
    roots = np.flatnonzero(e.out_degrees() > 0)[:2]
    eng = plan_bfs(g, BFSConfig(decomposition="1ds"),
                   make_local_mesh_1d(1, pods=1)).compile()
    batch = eng.run_batch(roots)
    for i, r in enumerate(roots):
        single = eng.run(int(r))
        assert np.array_equal(batch.level_stats[i], single.level_stats), r
        assert batch.n_levels[i] == single.n_levels


# ---------------------------------------------------------------------------
# Comm-model closed forms + cap_x planning
# ---------------------------------------------------------------------------


def test_sparse_exchange_closed_forms():
    n, p = 1 << 20, 16
    # the dense whole-search form is n_levels copies of the level form
    assert comm_model.expand_1d_words(n, p, 7) \
        == 7 * comm_model.expand_1d_level_words(n, p)
    # p=1 moves nothing in either encoding
    assert comm_model.expand_1d_level_words(n, 1) == 0.0
    assert comm_model.sparse_expand_1d_words(1000.0, 1) == 0.0
    # sparse wins below the n/64 crossover, loses above it
    assert comm_model.sparse_expand_1d_words(n / 64 - 1, p) \
        < comm_model.expand_1d_level_words(n, p)
    assert comm_model.sparse_expand_1d_words(n / 64 + 1, p) \
        > comm_model.expand_1d_level_words(n, p)
    # the hybrid model switches on bucket overflow
    cap = 128
    assert comm_model.hybrid_expand_1d_level_words(cap, 500.0, n, p, cap) \
        == comm_model.sparse_expand_1d_words(500.0, p)
    assert comm_model.hybrid_expand_1d_level_words(cap + 1, 500.0, n, p, cap) \
        == comm_model.expand_1d_level_words(n, p)


def test_compressed_exchange_closed_forms():
    n, p = 1 << 20, 16
    chunk = n // p
    bits = comm_model.codec_bits(chunk)
    assert bits == 16 and comm_model.codec_bits(1024) == 10
    assert comm_model.codec_bits(1) == 1  # degenerate chunk still packs
    # bucket layout: count word + ceil(cap*bits/32) packed words
    assert comm_model.codec_packed_words(32, 10) == 10
    assert comm_model.codec_bucket_words(32, 10) == 11
    # packed ids cost bits/64 of a raw id word, plus the count words
    n_f = 1000.0
    packed = comm_model.compressed_expand_1d_words(n_f, p, bits)
    assert packed == (p - 1) * (n_f * bits + 32 * p) / 64
    assert packed < comm_model.sparse_expand_1d_words(n_f, p)
    # p=1 ships nothing in the compressed encoding either
    assert comm_model.compressed_expand_1d_words(n_f, 1, bits) == 0.0
    # the crossover moves out: sparse stays cheaper than the bitmap
    # well past n/64 ids once each id costs only ``bits`` bits
    above_raw_crossover = n / 64 * 2.0
    assert comm_model.sparse_expand_1d_words(above_raw_crossover, p) \
        > comm_model.expand_1d_level_words(n, p)
    assert comm_model.compressed_expand_1d_words(
        above_raw_crossover, p, bits) < comm_model.expand_1d_level_words(n, p)
    # hybrid model takes the compressed form when bits are given
    assert comm_model.hybrid_expand_1d_level_words(
        10, n_f, n, p, 128, bits=bits) == packed
    # padded-buffer form: p * (p-1) encoded buckets at 1/2 word per u32
    assert comm_model.compressed_expand_padded_words(32, p, 10) \
        == p * (p - 1) * 11 / 2
    assert comm_model.compressed_expand_padded_words(32, p, 10) \
        < comm_model.sparse_expand_padded_words(32, p)


def test_chunked_exchange_closed_forms():
    """The software-pipelined expand's wire forms: dense chunking moves
    latency, never bytes; packed chunking trades narrower offsets for
    C-fold count words; the collective budgets scale with C."""
    n, p = 1 << 20, 16
    chunk = n // p
    # chunked dense == unchunked dense, for every admissible C
    for c in (1, 2, 4, 32):
        assert comm_model.chunked_expand_1d_level_words(n, p, c) \
            == comm_model.expand_1d_level_words(n, p)
    with pytest.raises(ValueError, match="does not divide"):
        comm_model.chunked_expand_1d_level_words(n, p, 3)
    with pytest.raises(ValueError, match=">= 1"):
        comm_model.chunked_expand_1d_level_words(n, p, 0)
    # packed chunked form: ids at codec_bits(chunk/C), one count word
    # per sub-bucket per owner
    n_f, c = 1000.0, 4
    bits_c = comm_model.codec_bits(chunk // c)
    assert comm_model.compressed_expand_1d_words(n_f, p, bits_c, c) \
        == (p - 1) * (n_f * bits_c + 32 * p * c) / 64
    # narrower offsets save 2 bits/id here; the extra count words cost
    # 32*(c-1) u32s per owner — net must stay below the raw exchange
    assert comm_model.compressed_expand_1d_words(n_f, p, bits_c, c) \
        < comm_model.sparse_expand_1d_words(n_f, p)
    # collective budgets scale with C: 1d td = C, 1ds td = 2C (C
    # execute), bottom-up untouched; 2d bu ring doubles its permutes
    budget = comm_model.level_collective_budget
    assert budget("1d", "td", p, expand_chunks=4) == 4
    assert budget("1d", "bu", p, expand_chunks=4) \
        == budget("1d", "bu", p)
    assert budget("1ds", "td", p, codec="packed", expand_chunks=4) == 8
    assert budget("1ds", "bu", p, expand_chunks=4) \
        == budget("1ds", "bu", p)
    pc = 4
    assert budget("2d", "bu", pc, expand_chunks=2) \
        == budget("2d", "bu", pc) + (pc - 1)
    assert budget("2d", "td", pc, "alltoall", expand_chunks=2) \
        == budget("2d", "td", pc, "alltoall")
    with pytest.raises(ValueError, match="expand_chunks"):
        budget("1d", "td", p, expand_chunks=0)


def test_plan_cap_x_bounds():
    n, p = 1 << 20, 16
    cap = comm_model.plan_cap_x(n, p, m=8 * n)
    chunk = n // p
    assert 32 <= cap <= chunk and cap % 32 == 0
    # crossover term dominates on big sparse graphs: ~n/(64p)
    assert abs(cap - n // (64 * p)) <= 32
    # degree headroom is per BUCKET (a level-1 frontier spreads over all
    # p owners): the planned global admission p*cap_x stays within
    # bucket granularity of the n/64 dense/sparse crossover, so a
    # fitting sparse level never ships much more than the bitmap
    assert p * comm_model.plan_cap_x(n, p, m=64 * n) <= max(n // 64, 32 * p)
    # never exceeds the owned chunk, even on tiny graphs
    assert comm_model.plan_cap_x(64, 2, m=1000) <= 32
    # the m=0 default collapse is now a refused plan, not silent headroom
    # loss (satellite bugfix): the degree-stat term needs real edges
    with pytest.raises(ValueError, match="edge count"):
        comm_model.plan_cap_x(n, p, m=0)
    with pytest.raises(ValueError, match="edge count"):
        comm_model.plan_cap_x(n, p, m=-5)
    # bits-aware crossover: cheaper per-id wire admits larger buckets
    bits = comm_model.codec_bits(n // p)
    assert comm_model.plan_cap_x(n, p, m=8 * n, bits=bits) \
        >= comm_model.plan_cap_x(n, p, m=8 * n)
    assert abs(comm_model.plan_cap_x(n, p, m=8 * n, bits=bits)
               - n // (bits * p)) <= 32
    # the static padded buffer form: p buckets to p-1 peers each
    assert comm_model.sparse_expand_padded_words(32, 16) == 16 * 15 * 32
    assert comm_model.sparse_expand_padded_words(32, 1) == 0.0
    # engine planning: plan_bfs derives cap_x from the graph when unset,
    # bits-aware under the default packed codec
    e = rmat_graph(8, edge_factor=8, seed=1)
    g = build_blocked_1d(e, 1, align=32, cap_pad=32)
    plan = plan_bfs(g, BFSConfig(decomposition="1ds"), make_local_mesh_1d(1))
    assert plan.statics.cap_x == comm_model.plan_cap_x(
        g.part.n, g.part.p, int(g.m),
        bits=comm_model.codec_bits(g.part.chunk))
    plan_raw = plan_bfs(g, BFSConfig(decomposition="1ds",
                                     frontier_codec="none"),
                        make_local_mesh_1d(1))
    assert plan_raw.statics.cap_x \
        == comm_model.plan_cap_x(g.part.n, g.part.p, int(g.m))
    plan2 = plan_bfs(g, BFSConfig(decomposition="1ds"),
                     make_local_mesh_1d(1), cap_x=64)
    assert plan2.statics.cap_x == 64
    # unknown codecs are refused at plan time, not deep in the step
    with pytest.raises(ValueError, match="frontier codec"):
        plan_bfs(g, BFSConfig(decomposition="1ds",
                              frontier_codec="varint"),
                 make_local_mesh_1d(1))


def test_measured_wire_matches_sparse_model_single_device():
    """p=1 ships nothing: every level's measured expand words must be 0
    in 1ds (and the per-level stats column must exist and be used)."""
    e = rmat_graph(8, edge_factor=8, seed=4)
    g = build_blocked_1d(e, 1, align=32, cap_pad=32)
    root = int(np.flatnonzero(e.out_degrees())[0])
    r = run_bfs(g, root, BFSConfig(decomposition="1ds"),
                make_local_mesh_1d(1))
    assert r.level_stats.shape[1] == 5
    assert r.counters["wire_expand"] == 0.0
    assert (r.level_stats[: r.n_levels, 3] == 1).all()
    assert (r.level_stats[r.n_levels:, 3] == 0).all()
    assert (r.level_stats[:, 4] == 0).all()


# ---------------------------------------------------------------------------
# Multi-device acceptance case (subprocess, 16 forced host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_distributed_bfs_1ds_acceptance():
    """Scale-14 R-MAT on 16 strips: measured "1ds" wire_expand within 2x
    of comm_model.topdown_1d_words, the first two levels beating the
    dense bitmap, depth parity with "1d"/"2d", and the hybrid fallback
    (see tests/_dist_bfs_main.py mode "onedsparse")."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    main = os.path.join(_HERE, "_dist_bfs_main.py")
    r = subprocess.run([sys.executable, main, "16", "onedsparse"],
                       capture_output=True, text=True, timeout=1800,
                       env=env)
    assert r.returncode == 0, f"onedsparse failed:\n{r.stdout}\n{r.stderr}"
    assert "OK onedsparse" in r.stdout
