"""Subprocess entry for the HLO collective-count perf guard.

Since the PR 9 linter, the case table and the lowering helpers live in
``repro.analysis.registry`` (the R4 budget-drift rule) — this entry
just forces the host devices, runs ``collect_counts()`` over the
registry-enumerated schedule cases (lowering only, never compiling or
running), and prints the counts as JSON for tests/test_perf_guard.py
to assert budgets against.

Run as:  python tests/_perf_guard_main.py
"""
import json
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.registry import collect_counts  # noqa: E402


def main():
    print(json.dumps(collect_counts()))


if __name__ == "__main__":
    main()
