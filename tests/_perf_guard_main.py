"""Subprocess entry for the HLO collective-count perf guard.

Lowers (never compiles or runs) each decomposition's per-level step
bodies and whole-search programs on 8 forced host devices, with
``instrument`` on and off, and prints the collective-op counts as JSON
for tests/test_perf_guard.py to assert budgets against.

Run as:  python tests/_perf_guard_main.py
"""
import json
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs.base import BFSConfig  # noqa: E402
from repro.core import steps, steps_1d, steps_1d_sparse  # noqa: E402
from repro.core.compat import shard_map  # noqa: E402
from repro.core.engine import hlo_collective_counts, plan_bfs  # noqa: E402
from repro.graph.formats import build_blocked, build_blocked_1d  # noqa: E402
from repro.graph.rmat import rmat_graph  # noqa: E402
from repro.launch.mesh import make_local_mesh, make_local_mesh_1d  # noqa: E402

_STEPS = {
    "2d": (steps.topdown_level, steps.bottomup_level),
    "1d": (steps_1d.topdown_level_1d, steps_1d.bottomup_level_1d),
    "1ds": (steps_1d_sparse.topdown_level_1ds,
            steps_1d_sparse.bottomup_level_1ds),
}


def _sds(a):
    a = np.asarray(a)
    return jax.ShapeDtypeStruct(a.shape, a.dtype)


def search_counts(graph, cfg, mesh, plan):
    """Collective counts of the lowered whole-search program."""
    arrs = {k: _sds(v) for k, v in graph.device_arrays().items()
            if k in plan.keys}
    txt = plan.build_fn().lower(arrs, jnp.int32(0)).as_text()
    return hlo_collective_counts(txt)


def level_counts(graph, cfg, mesh, plan, which):
    """Collective counts of ONE lowered level step body (td or bu) —
    the per-level schedule minus the loop's fused reduction.  The
    fast-path ``lv`` context is threaded as a replicated input; the
    instrumented step gets lv=None, exactly as _search_loop calls it."""
    args = plan.level_args()
    nax = plan.entry.n_axes
    td, bu = _STEPS[cfg.decomposition]
    step = td if which == "td" else bu
    sq = (0,) * nax

    ctr_keys = steps.COUNTER_KEYS if args.instrument else ()

    def fn(garr, pi, front, over):
        gl = {k: v[sq] for k, v in garr.items()}
        lv = None if args.instrument else {"over": over}
        pi2, f2, ctr = step(gl, pi[sq], front[sq], args, lv)
        # ctr must stay a live output or the counter psums get DCE'd —
        # the whole point is counting what the instrumented level pays
        return pi2.reshape((1,) * nax + pi2.shape), dict(ctr)

    spec = P(*plan.axes)
    gspec = {k: spec for k in plan.keys}
    mapped = shard_map(fn, mesh=mesh,
                      in_specs=(gspec, spec, spec, P()),
                      out_specs=(spec, {k: P() for k in ctr_keys}),
                      check_vma=False)
    arrs = {k: _sds(v) for k, v in graph.device_arrays().items()
            if k in plan.keys}
    part = plan.part
    pi = jax.ShapeDtypeStruct(arrs["deg_A"].shape, np.int32)
    fr = jax.ShapeDtypeStruct(arrs["deg_A"].shape, np.bool_)
    txt = jax.jit(mapped).lower(arrs, pi, fr,
                                jnp.zeros((), bool)).as_text()
    return hlo_collective_counts(txt)


def main():
    e = rmat_graph(9, edge_factor=8, seed=3)
    g2 = build_blocked(e, 2, 4, align=32, cap_pad=32)
    g1 = build_blocked_1d(e, 8, align=32, cap_pad=32)
    out = {"pc": 4, "p": 8}
    cases = [
        ("2d_alltoall", "2d", dict(fold_mode="alltoall")),
        ("2d_reduce", "2d", dict(fold_mode="reduce")),
        ("2d_bitmap", "2d", dict(fold_mode="bitmap")),
        ("2d_compact", "2d", dict(fold_mode="alltoall",
                                  compact_updates=True)),
        ("1d", "1d", {}),
        ("1ds", "1ds", {}),                      # packed codec (default)
        ("1ds_raw", "1ds", dict(frontier_codec="none")),
        # software-pipelined expand: chunk the 1d/1ds top-down gather,
        # pipeline the 2d bottom-up ring (R/G split).  The scale-9 p=8
        # strips pack to 2 words, so 2 is the only chunking this graph
        # admits — enough to pin the C-proportional budgets.
        ("1d_c2", "1d", dict(expand_chunks=2)),
        ("1ds_c2", "1ds", dict(expand_chunks=2)),
        ("2d_pipe", "2d", dict(fold_mode="alltoall", expand_chunks=2)),
    ]
    for name, decomp, kw in cases:
        g = g2 if decomp == "2d" else g1
        mesh = make_local_mesh(2, 4) if decomp == "2d" \
            else make_local_mesh_1d(8)
        row = {}
        for label, instr in (("fast", False), ("instrumented", True)):
            cfg = BFSConfig(decomposition=decomp, instrument=instr, **kw)
            plan = plan_bfs(g, cfg, mesh)
            row[label] = {
                "search": search_counts(g, cfg, mesh, plan),
                "td": level_counts(g, cfg, mesh, plan, "td"),
                "bu": level_counts(g, cfg, mesh, plan, "bu"),
            }
        out[name] = row
    print(json.dumps(out))


if __name__ == "__main__":
    main()
