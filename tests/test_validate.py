"""Sharded Graph500 parent-tree validation (repro.core.validate).

Two halves:

* **clean matrix** — every registered decomposition x storage x
  instrument combo produces a parent array the device validator accepts,
  and the verdict agrees with the host oracle (``core.ref``) on roots
  both reachable-rich and nearly isolated.
* **mutation kill matrix** — every seeded fault class from
  ``runtime.faultinject`` (bit-flipped parent, phantom parent, level
  skew, orphaned reachable vertex, dropped sub-bucket) is flagged, in
  every decomposition, with the violation landing on the right check.
"""
import numpy as np
import pytest

from repro.configs.base import BFSConfig
from repro.core import decomp
from repro.core import ref
from repro.core import validate as V
from repro.core.engine import plan_bfs
from repro.core.validate import (CHECKS, ValidationError, ValidationReport,
                                 report_from_counts)
from repro.graph.formats import build_blocked, build_blocked_1d
from repro.graph.rmat import rmat_graph
from repro.launch.mesh import make_local_mesh, make_local_mesh_1d
from repro.runtime.faultinject import (PARENT_FAULTS, InjectionError,
                                       inject_parents)

ROOT = 5


@pytest.fixture(scope="module")
def fixed_graph():
    e = rmat_graph(8, edge_factor=8, seed=4)
    return (e, build_blocked_1d(e, 1, align=32, cap_pad=32,
                                with_col_ptr=True),
            build_blocked(e, 1, 1, align=32, cap_pad=32))


def _mesh_for(d):
    return make_local_mesh(1, 1) if d == "2d" else make_local_mesh_1d(1)


def _graph_for(d, g1, g2):
    return g2 if d == "2d" else g1


@pytest.fixture(scope="module")
def engines(fixed_graph):
    e, g1, g2 = fixed_graph
    out = {}
    for d in decomp.registered_decompositions():
        cfg = BFSConfig(decomposition=d, instrument=False)
        out[d] = plan_bfs(_graph_for(d, g1, g2), cfg,
                          _mesh_for(d)).compile()
    return out


# ---------------------------------------------------------------------------
# clean matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", decomp.registered_decompositions())
@pytest.mark.parametrize("storage", ["csr", "dcsc"])
@pytest.mark.parametrize("instrument", [False, True])
def test_clean_run_validates(fixed_graph, d, storage, instrument):
    e, g1, g2 = fixed_graph
    cfg = BFSConfig(decomposition=d, storage=storage,
                    instrument=instrument)
    eng = plan_bfs(_graph_for(d, g1, g2), cfg, _mesh_for(d)).compile()
    res = eng.run(ROOT, validate=True)
    rep = res.validation
    assert rep.ok and rep.root == ROOT
    assert not any(rep.violations.values())
    # device verdict agrees with the host oracle
    ok, msg = ref.validate_parents(e.n, e.src, e.dst, ROOT, res.parents)
    assert ok, msg
    assert rep.n_tree == int(np.sum(res.parents >= 0))


def test_posthoc_host_array_validates(fixed_graph, engines):
    e, g1, g2 = fixed_graph
    for d, eng in engines.items():
        parents = eng.run(ROOT).parents
        rep = V.validate_parents(eng, ROOT, parents)
        assert rep.ok, (d, rep.summary())
        # padded (n,) layout accepted too
        full = np.full(eng.plan.part.n, -1, np.int64)
        full[: e.n] = parents
        assert V.validate_parents(eng, ROOT, full).ok


def test_isolated_root_validates(fixed_graph, engines):
    """A root with no edges yields a single-vertex tree — still valid."""
    e, g1, g2 = fixed_graph
    deg = np.zeros(e.n, np.int64)
    np.add.at(deg, e.src, 1)
    lonely = int(np.argmin(deg))
    if deg[lonely] > 0:
        pytest.skip("seed graph has no isolated vertex")
    for d, eng in engines.items():
        res = eng.run(lonely, validate=True)
        assert res.validation.n_tree == 1, d


def test_run_validate_raises_on_bad_tree(fixed_graph, engines):
    eng = engines["2d"]
    good = eng.run(ROOT).parents
    bad, _ = inject_parents("phantom_parent", good, ROOT, seed=1,
                            n=fixed_graph[0].n, src=fixed_graph[0].src,
                            dst=fixed_graph[0].dst)
    with pytest.raises(ValidationError, match="INVALID parent tree"):
        rep = V.validate_parents(eng, ROOT, bad)
        if not rep.ok:
            raise ValidationError(rep)


def test_validate_rejects_wrong_length(engines):
    eng = engines["1d"]
    with pytest.raises(ValueError, match="entries"):
        V.validate_parents(eng, ROOT, np.zeros(7, np.int64))


# ---------------------------------------------------------------------------
# mutation kill matrix
# ---------------------------------------------------------------------------

# every fault class must trip AT LEAST these checks (faults can cascade
# into extra violations — e.g. a phantom parent also skews levels)
_EXPECT = {
    "flip_bit": {"tree_edge_missing", "parent_chain_broken",
                 "reach_mismatch", "level_span", "root_self_parent"},
    "phantom_parent": {"tree_edge_missing"},
    "level_skew": {"level_span", "parent_chain_broken"},
    "orphan_leaf": {"reach_mismatch"},
    "drop_subrange": {"reach_mismatch", "parent_chain_broken"},
}


@pytest.mark.parametrize("d", decomp.registered_decompositions())
@pytest.mark.parametrize("kind", PARENT_FAULTS)
def test_injected_fault_is_flagged(fixed_graph, engines, d, kind):
    e, _, _ = fixed_graph
    eng = engines[d]
    good = eng.run(ROOT).parents
    for seed in range(3):                # three independent schedules
        bad, info = inject_parents(kind, good, ROOT, seed, n=e.n,
                                   src=e.src, dst=e.dst,
                                   chunk=eng.plan.part.chunk)
        assert not np.array_equal(bad, good)
        rep = V.validate_parents(eng, ROOT, bad)
        assert not rep.ok, (d, kind, seed, info)
        hit = {k for k, v in rep.violations.items() if v}
        assert hit & _EXPECT[kind], (d, kind, seed, info, rep.violations)
        # the host oracle agrees the mutation is invalid
        ok, _ = ref.validate_parents(e.n, e.src, e.dst, ROOT,
                                     bad[: e.n])
        assert not ok, (d, kind, seed, info)


def test_injection_is_deterministic(fixed_graph, engines):
    e, _, _ = fixed_graph
    good = engines["1ds"].run(ROOT).parents
    for kind in PARENT_FAULTS:
        a, ia = inject_parents(kind, good, ROOT, 7, n=e.n, src=e.src,
                               dst=e.dst, chunk=64)
        b, ib = inject_parents(kind, good, ROOT, 7, n=e.n, src=e.src,
                               dst=e.dst, chunk=64)
        assert ia == ib and np.array_equal(a, b), kind


def test_injector_refuses_degenerate_tree():
    src = np.array([0, 1], np.int64)
    dst = np.array([1, 0], np.int64)
    parents = np.array([0, 0, -1, -1], np.int64)
    with pytest.raises(InjectionError):
        # a 2-vertex path has no same-level edge to skew
        inject_parents("level_skew", parents, 0, 0, n=4, src=src, dst=dst)


# ---------------------------------------------------------------------------
# report plumbing
# ---------------------------------------------------------------------------


def test_report_from_counts_roundtrip():
    rep = report_from_counts(3, np.array([0, 0, 0, 0, 0, 17]))
    assert rep == ValidationReport(3, True, dict.fromkeys(CHECKS, 0), 17)
    assert "valid parent tree" in rep.summary()
    bad = report_from_counts(3, np.array([1, 0, 2, 0, 0, 17]))
    assert not bad.ok
    assert "root_self_parent=1" in bad.summary()
    assert bad.to_json()["violations"]["parent_chain_broken"] == 2
