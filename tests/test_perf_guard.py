"""HLO collective-count regression guard for the per-level pipeline.

The latency analysis (paper §6, Buluc & Madduri's 1D/2D cost models)
says thin-frontier BFS levels are bound by the collective COUNT, not
volume — so the compiled schedule is a perf artifact in its own right.
This test lowers each decomposition's level bodies and whole-search
programs (subprocess, 8 forced host devices, lowering only — no XLA
compile) with ``instrument`` on and off.

Since the PR 9 linter, the case table and budgets have ONE source of
truth: ``repro.analysis.registry.budget_cases()`` — the cross product
of every registered entry's ``schedule_dims`` (the R4 budget-drift
rule).  Registering a new decomposition (or adding a schedule dim) is
what adds its coverage here; no case list to update.  On top of the
enumerated budgets this file keeps the previously pinned values as
explicit regression assertions:

  * the ISSUE headline numbers (2D top-down <= 4 with the alltoall
    fold, 2D bottom-up <= pc + 3), so future PRs cannot silently
    re-bloat the fast path;
  * "one fused scalar reduction per level": the fast whole-search
    program carries exactly 2 all-reduces (startup + loop body; the
    compact-updates / bitmap-fold overflow pmax adds 1);
  * the acceptance ratio: fast-path collectives <= half the
    instrumented count per 2D top-down level.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis.registry import budget_cases, case_name

_HERE = os.path.dirname(__file__)
_MAIN = os.path.join(_HERE, "_perf_guard_main.py")

# legacy spellings -> canonical registry case names, so the pinned
# regression assertions below read like the schedules they pin
_2D_ALLTOALL = case_name("2d", {"fold_mode": "alltoall"})
_2D_REDUCE = case_name("2d", {"fold_mode": "reduce"})
_2D_BITMAP = case_name("2d", {"fold_mode": "bitmap"})
_2D_COMPACT = case_name("2d", {"compact_updates": True})
_2D_PIPE = case_name("2d", {"expand_chunks": 2})
_1D = case_name("1d", {})
_1D_C2 = case_name("1d", {"expand_chunks": 2})
_1DS_PACKED = case_name("1ds", {"frontier_codec": "packed"})
_1DS_RAW = case_name("1ds", {"frontier_codec": "none"})
_1DS_C2 = case_name("1ds", {"frontier_codec": "packed",
                            "expand_chunks": 2})


@pytest.fixture(scope="module")
def counts():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, _MAIN], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"perf guard lowering failed:\n{r.stderr}"
    return json.loads(r.stdout.splitlines()[-1])


def test_enumeration_covers_every_registered_case(counts):
    """The subprocess lowered exactly the registry enumeration — a new
    entry or schedule dim shows up here without touching this file."""
    expected = {c.name for c in budget_cases()}
    got = set(counts) - {"pc", "p", "validators"}
    assert got == expected, (sorted(got ^ expected))
    assert len(expected) >= 18


def test_fast_level_budgets(counts):
    """Instrument-off level bodies stay within the published budgets
    for EVERY enumerated schedule case (rule R4's exact check)."""
    pc, p = counts["pc"], counts["p"]
    for case in budget_cases():
        b = case.budgets(pc, p)
        fast = counts[case.name]["fast"]
        for mode in ("td", "bu"):
            assert fast[mode]["total"] <= b[mode], (
                case.name, mode, fast[mode], b[mode])
    # the ISSUE-pinned headline numbers: 2D top-down <= 4 with the
    # paper-faithful alltoall fold, bottom-up <= pc + 3
    assert counts[_2D_ALLTOALL]["fast"]["td"]["total"] <= 4
    assert counts[_2D_ALLTOALL]["fast"]["bu"]["total"] <= pc + 3


def test_fast_search_single_fused_reduction(counts):
    """The fast whole-search program spends exactly one fused vector
    psum per level: 2 all-reduce ops in the program text (startup +
    while body), +1 for the compact-updates overflow pmax."""
    for name in (_2D_ALLTOALL, _2D_REDUCE, _1D, _1DS_PACKED, _1DS_RAW,
                 _1D_C2, _1DS_C2, _2D_PIPE):
        ar = counts[name]["fast"]["search"].get("all-reduce", 0)
        assert ar <= 2, (name, counts[name]["fast"]["search"])
    # the compact-update and bitmap-fold overflow pmaxes add one each
    assert counts[_2D_COMPACT]["fast"]["search"].get("all-reduce", 0) <= 3
    assert counts[_2D_BITMAP]["fast"]["search"].get("all-reduce", 0) <= 3


def test_fast_at_most_half_of_instrumented(counts):
    """Acceptance: instrument=False collectives per compiled 2D
    top-down level are <= half the instrumented count with the
    paper-faithful alltoall fold, and the whole search program shrinks
    at least as much (the ring-reduce fold's pc-1 data ppermutes exist
    in both modes, so its level ratio is asserted strictly-less)."""
    fast_td = counts[_2D_ALLTOALL]["fast"]["td"]["total"]
    inst_td = counts[_2D_ALLTOALL]["instrumented"]["td"]["total"]
    assert fast_td * 2 <= inst_td, (fast_td, inst_td)
    for name in (_2D_ALLTOALL, _2D_REDUCE):
        fast_s = counts[name]["fast"]["search"]["total"]
        inst_s = counts[name]["instrumented"]["search"]["total"]
        assert fast_s * 2 <= inst_s, (name, fast_s, inst_s)
        assert (counts[name]["fast"]["td"]["total"]
                < counts[name]["instrumented"]["td"]["total"]), name


def test_instrumented_keeps_counter_reductions(counts):
    """Sanity check on the guard itself: the instrumented level bodies
    still pay their counter psums (if this drops to the fast-path
    count, the lowering DCE'd the counters and the budgets above are
    vacuous)."""
    for name in (_2D_ALLTOALL, _1D, _1DS_PACKED, _1DS_RAW):
        inst = counts[name]["instrumented"]["td"]
        fast = counts[name]["fast"]["td"]
        assert inst.get("all-reduce", 0) >= 3, (name, inst)
        assert inst["total"] > fast["total"], (name, inst, fast)


def test_validator_collective_budget(counts):
    """The Graph500 parent-tree validator stays within its published
    collective budget for every registered decomposition: gathers to
    replicate the candidate parents (1 for strips, 2 for the 2D grid)
    plus 2 all-reduces (tree-edge-existence OR + the fused verdict
    psum).  A validator that starts shipping edges or depths would blow
    this immediately."""
    from repro.core.comm_model import validate_collective_budget

    vals = counts["validators"]
    assert set(vals) == {c.decomposition for c in budget_cases()}
    for name, got in vals.items():
        budget = validate_collective_budget(name)
        assert got.get("all-gather", 0) <= budget["all-gather"], (name, got)
        assert got.get("all-reduce", 0) <= budget["all-reduce"], (name, got)
        assert got["total"] <= budget["total"], (name, got, budget)
        # and it must actually DO the replication + verdict reduction
        assert got.get("all-gather", 0) >= 1, (name, got)
        assert got.get("all-reduce", 0) >= 1, (name, got)


def test_packed_codec_same_schedule(counts):
    """The codec compresses BYTES, not the schedule: packed and raw
    "1ds" must lower to identical collective counts in every mode."""
    assert counts[_1DS_PACKED] == counts[_1DS_RAW], (
        counts[_1DS_PACKED], counts[_1DS_RAW])
