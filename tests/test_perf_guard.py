"""HLO collective-count regression guard for the per-level pipeline.

The latency analysis (paper §6, Buluc & Madduri's 1D/2D cost models)
says thin-frontier BFS levels are bound by the collective COUNT, not
volume — so the compiled schedule is a perf artifact in its own right.
This test lowers each decomposition's level bodies and whole-search
programs (subprocess, 8 forced host devices, lowering only — no XLA
compile) with ``instrument`` on and off and pins:

  * the instrument-off per-level budgets from
    ``comm_model.level_collective_budget`` (e.g. 2D top-down <= 4,
    2D bottom-up <= pc + 3), so future PRs cannot silently re-bloat
    the fast path;
  * "one fused scalar reduction per level": the fast whole-search
    program carries exactly 2 all-reduces (startup + loop body; the
    compact-updates overflow pmax adds 1);
  * the acceptance ratio: fast-path collectives <= half the
    instrumented count per 2D top-down level.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.core import comm_model

_HERE = os.path.dirname(__file__)
_MAIN = os.path.join(_HERE, "_perf_guard_main.py")


@pytest.fixture(scope="module")
def counts():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, _MAIN], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"perf guard lowering failed:\n{r.stderr}"
    return json.loads(r.stdout.splitlines()[-1])


def test_fast_level_budgets(counts):
    """Instrument-off level bodies stay within the published budgets."""
    pc, p = counts["pc"], counts["p"]
    budget = comm_model.level_collective_budget
    cases = {
        "2d_alltoall": (budget("2d", "td", pc, "alltoall"),
                        budget("2d", "bu", pc)),
        "2d_reduce": (budget("2d", "td", pc, "reduce"),
                      budget("2d", "bu", pc)),
        "2d_bitmap": (budget("2d", "td", pc, "bitmap"),
                      budget("2d", "bu", pc)),
        "2d_compact": (budget("2d", "td", pc, "alltoall"),
                       budget("2d", "bu", pc, compact_updates=True)),
        "1d": (budget("1d", "td", p), budget("1d", "bu", p)),
        # the packed codec must not change the op count — the count word
        # rides inside the same allgathered bucket buffer, so the packed
        # ("1ds", the default) and raw ("1ds_raw") exchanges share one
        # explicit budget
        "1ds": (budget("1ds", "td", p, codec="packed"),
                budget("1ds", "bu", p, codec="packed")),
        "1ds_raw": (budget("1ds", "td", p, codec="none"),
                    budget("1ds", "bu", p, codec="none")),
        # pipelined expand: 1d td budget C, 1ds td 2C (C execute), 2d
        # bottom-up ring 2(pc-1) ppermutes (R/G split); bottom-up in the
        # strip decompositions keeps its single dense allgather
        "1d_c2": (budget("1d", "td", p, expand_chunks=2),
                  budget("1d", "bu", p, expand_chunks=2)),
        "1ds_c2": (budget("1ds", "td", p, codec="packed", expand_chunks=2),
                   budget("1ds", "bu", p, codec="packed", expand_chunks=2)),
        "2d_pipe": (budget("2d", "td", pc, "alltoall"),
                    budget("2d", "bu", pc, expand_chunks=2)),
    }
    for name, (td_budget, bu_budget) in cases.items():
        fast = counts[name]["fast"]
        assert fast["td"]["total"] <= td_budget, (
            name, "td", fast["td"], td_budget)
        assert fast["bu"]["total"] <= bu_budget, (
            name, "bu", fast["bu"], bu_budget)
    # the ISSUE-pinned headline numbers: 2D top-down <= 4 with the
    # paper-faithful alltoall fold, bottom-up <= pc + 3
    assert counts["2d_alltoall"]["fast"]["td"]["total"] <= 4
    assert counts["2d_alltoall"]["fast"]["bu"]["total"] <= pc + 3


def test_fast_search_single_fused_reduction(counts):
    """The fast whole-search program spends exactly one fused vector
    psum per level: 2 all-reduce ops in the program text (startup +
    while body), +1 for the compact-updates overflow pmax."""
    for name in ("2d_alltoall", "2d_reduce", "1d", "1ds", "1ds_raw",
                 "1d_c2", "1ds_c2", "2d_pipe"):
        ar = counts[name]["fast"]["search"].get("all-reduce", 0)
        assert ar <= 2, (name, counts[name]["fast"]["search"])
    # the compact-update and bitmap-fold overflow pmaxes add one each
    assert counts["2d_compact"]["fast"]["search"].get("all-reduce", 0) <= 3
    assert counts["2d_bitmap"]["fast"]["search"].get("all-reduce", 0) <= 3


def test_fast_at_most_half_of_instrumented(counts):
    """Acceptance: instrument=False collectives per compiled 2D
    top-down level are <= half the instrumented count with the
    paper-faithful alltoall fold, and the whole search program shrinks
    at least as much (the ring-reduce fold's pc-1 data ppermutes exist
    in both modes, so its level ratio is asserted strictly-less)."""
    fast_td = counts["2d_alltoall"]["fast"]["td"]["total"]
    inst_td = counts["2d_alltoall"]["instrumented"]["td"]["total"]
    assert fast_td * 2 <= inst_td, (fast_td, inst_td)
    for name in ("2d_alltoall", "2d_reduce"):
        fast_s = counts[name]["fast"]["search"]["total"]
        inst_s = counts[name]["instrumented"]["search"]["total"]
        assert fast_s * 2 <= inst_s, (name, fast_s, inst_s)
        assert (counts[name]["fast"]["td"]["total"]
                < counts[name]["instrumented"]["td"]["total"]), name


def test_instrumented_keeps_counter_reductions(counts):
    """Sanity check on the guard itself: the instrumented level bodies
    still pay their counter psums (if this drops to the fast-path
    count, the lowering DCE'd the counters and the budgets above are
    vacuous)."""
    for name in ("2d_alltoall", "1d", "1ds", "1ds_raw"):
        inst = counts[name]["instrumented"]["td"]
        fast = counts[name]["fast"]["td"]
        assert inst.get("all-reduce", 0) >= 3, (name, inst)
        assert inst["total"] > fast["total"], (name, inst, fast)


def test_packed_codec_same_schedule(counts):
    """The codec compresses BYTES, not the schedule: packed and raw
    "1ds" must lower to identical collective counts in every mode."""
    assert counts["1ds"] == counts["1ds_raw"], (
        counts["1ds"], counts["1ds_raw"])
