"""Substrate tests: checkpoint atomicity + resume determinism, elastic
resharding, straggler detection, gradient compression, optimizer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.optim.adamw import AdamW, SGDM, global_norm
from repro.optim.grad_compress import (ef_init, int8_dequantize,
                                       int8_quantize, topk_compress,
                                       topk_decompress)
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.trainer import Trainer


def _toy_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)), "b": jnp.zeros((8,))}


def test_checkpoint_roundtrip_and_retention(tmp_path):
    d = str(tmp_path / "ck")
    s = _toy_state()
    for step in (10, 20, 30, 40):
        ckpt.save(d, step, s, meta={"cfg": "x"}, keep=2)
    assert ckpt.latest_step(d) == 40
    steps = sorted(os.listdir(d))
    assert len(steps) == 2                      # retention pruned
    got, meta = ckpt.restore(d, 40, s, expect_meta={"cfg": "x"})
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        ckpt.restore(d, 40, s, expect_meta={"cfg": "y"})


def test_trainer_resume_bit_identical(tmp_path):
    """Interrupted-and-resumed run == uninterrupted run (fault tolerance)."""
    opt = SGDM(lr=0.05)

    def make_step():
        def step(state, batch):
            p, o = state
            def loss_fn(p):
                pred = batch["x"] @ p["w"] + p["b"]
                return jnp.mean((pred - batch["y"]) ** 2)
            loss, g = jax.value_and_grad(loss_fn)(p)
            p, o = opt.update(g, o, p)
            return (p, o), {"loss": loss}
        return jax.jit(step)

    def make_batch(step):
        rng = np.random.default_rng((7, step))
        x = rng.normal(size=(4, 8)).astype(np.float32)
        return {"x": jnp.asarray(x),
                "y": jnp.asarray(x.sum(1, keepdims=True) * 0.1)}

    p0 = _toy_state(3)
    s0 = (p0, opt.init(p0))
    t_full = Trainer(make_step(), make_batch, str(tmp_path / "a"),
                     ckpt_every=100)
    full, _ = t_full.run(s0, 10, resume=False)

    t_int = Trainer(make_step(), make_batch, str(tmp_path / "b"),
                    ckpt_every=5)
    t_int.run(s0, 5, resume=False)              # "crash" after 5 steps
    resumed, _ = Trainer(make_step(), make_batch, str(tmp_path / "b"),
                         ckpt_every=5).run(s0, 10, resume=True)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_straggler_detection():
    mon = StragglerMonitor(window=20, factor=3.0, min_samples=5)
    for i in range(10):
        assert not mon.observe(i, 0.1 + 0.001 * i)
    assert mon.observe(10, 1.0)                 # 10x p95 -> event
    assert len(mon.events) == 1 and mon.events[0][0] == 10
    assert mon.deadline is not None


def test_topk_error_feedback_lossless_over_time():
    """Error feedback: everything eventually transmitted (sum of
    decompressed grads == sum of true grads)."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,))
                          .astype(np.float32))}
    st = ef_init(g)
    acc = jnp.zeros((64,))
    T = 60
    for _ in range(T):
        vals, idxs, st = topk_compress(g, st, ratio=0.05)
        dec = topk_decompress(vals, idxs, g)
        acc = acc + dec["w"]
    # exact error-feedback identity: transmitted + residual == T * grad
    np.testing.assert_allclose(
        np.asarray(acc + st.residual["w"]), T * np.asarray(g["w"]),
        rtol=1e-4, atol=1e-4)
    # and the residual is bounded (nothing is lost forever)
    assert float(jnp.abs(st.residual["w"]).max()) < T * float(
        jnp.abs(g["w"]).max())


def test_int8_quantization_error_bounded():
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(128, 4))
                          .astype(np.float32))}
    q, s = int8_quantize(g)
    back = int8_dequantize(q, s, g)
    err = np.abs(np.asarray(back["w"]) - np.asarray(g["w"])).max()
    scale = float(np.abs(np.asarray(g["w"])).max()) / 127
    assert err <= scale * 0.5 + 1e-7


def test_adamw_descends_quadratic():
    opt = AdamW(lr=0.05, weight_decay=0.0, warmup_steps=1,
                schedule="constant")
    p = {"w": jnp.ones((16,)) * 3.0}
    st = opt.init(p)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(p))
    for _ in range(100):
        g = jax.grad(loss)(p)
        p, st = opt.update(g, st, p)
    assert float(loss(p)) < 0.05 * l0
    assert float(global_norm(p)) < float(global_norm({"w": jnp.ones((16,)) * 3}))
