"""Sequential-oracle tests: Alg. 1 (top-down) vs Alg. 2 (bottom-up)
produce identical reachability/depths on random graphs (hypothesis)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.ref import (bfs_bottomup, bfs_depths, bfs_topdown,
                            depths_from_parents, validate_parents)
from repro.graph.rmat import preprocess, rmat_graph


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_topdown_equals_bottomup(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 40))
    m = int(rng.integers(1, 4 * n))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    e = preprocess(src, dst, n, symmetrize=bool(rng.integers(0, 2)))
    if e.m == 0:
        return
    root = int(e.src[0])
    p_td = bfs_topdown(n, e.src, e.dst, root)
    p_bu = bfs_bottomup(n, e.src, e.dst, root)
    d = bfs_depths(n, e.src, e.dst, root)
    assert np.array_equal(p_td >= 0, d >= 0)
    assert np.array_equal(p_bu >= 0, d >= 0)
    for p in (p_td, p_bu):
        ok, msg = validate_parents(n, e.src, e.dst, root, p)
        assert ok, msg
        assert np.array_equal(depths_from_parents(n, p, root), d)


def test_rmat_shape_and_skew():
    e = rmat_graph(10, edge_factor=8, seed=2)
    assert e.n == 1024
    assert e.m > 0 and e.m_input == 8 * 1024
    deg = e.out_degrees()
    # R-MAT must be skewed: max degree far above mean
    assert deg.max() > 4 * deg.mean()
    # symmetric after preprocessing
    key = set(zip(e.src.tolist(), e.dst.tolist()))
    assert all((d, s) in key for s, d in list(key)[:500])


def test_validate_catches_bad_tree():
    e = rmat_graph(8, edge_factor=8, seed=2)
    root = int(e.src[0])
    p = bfs_topdown(e.n, e.src, e.dst, root)
    bad = p.copy()
    v = int(np.flatnonzero((bad >= 0) & (np.arange(e.n) != root))[0])
    bad[v] = v  # self-parent on a non-root vertex: invalid tree edge
    ok, _ = validate_parents(e.n, e.src, e.dst, root, bad)
    assert not ok
