"""Subprocess: 2D-partitioned SpMM (paper fold/expand, sum semiring) must
equal the single-device segment_sum oracle."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.spmm import spmm_2d  # noqa: E402
from repro.graph.formats import build_blocked  # noqa: E402
from repro.graph.rmat import rmat_graph  # noqa: E402
from repro.launch.mesh import make_local_mesh  # noqa: E402


def main():
    e = rmat_graph(10, edge_factor=8, seed=11)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(e.n, 8)).astype(np.float32)
    # oracle: out[v] = sum over edges u->v of x[u]
    want = np.zeros_like(x)
    np.add.at(want, e.dst, x[e.src])
    for pr, pc in [(4, 4), (2, 8), (8, 2), (1, 16), (16, 1)]:
        g = build_blocked(e, pr, pc, align=32, cap_pad=32)
        mesh = make_local_mesh(pr, pc)
        got = spmm_2d(g, x, mesh)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        print(f"spmm {pr}x{pc} ok")
    print("OK spmm")


if __name__ == "__main__":
    main()
