"""The append-only bench-regression guard
(benchmarks/check_bench_regression.py): unit cases over synthetic
trajectories plus a live run against the committed BENCH_bfs.json."""
import json
import os
import subprocess
import sys

_HERE = os.path.dirname(__file__)
_ROOT = os.path.join(_HERE, "..")
sys.path.insert(0, os.path.join(_ROOT, "benchmarks"))

from check_bench_regression import check_points  # noqa: E402


def _point(**mins):
    """{name: (fast_min, inst_min)} -> one bench point."""
    return {"decompositions": {
        name: {"fast": {"traverse_min_s": f},
               "instrumented": {"traverse_min_s": i}}
        for name, (f, i) in mins.items()}}


def test_clean_within_threshold():
    data = {"points": [_point(**{"1d": (0.20, 0.22)}),
                       _point(**{"1d": (0.24, 0.26)})]}   # +20% < 25%
    assert check_points(data) == []


def test_regression_detected_per_mode():
    data = {"points": [_point(**{"1d": (0.20, 0.22), "2d": (0.30, 0.33)}),
                       _point(**{"1d": (0.27, 0.22), "2d": (0.30, 0.45)})]}
    msgs = check_points(data)
    assert len(msgs) == 2
    assert any("1d/fast" in m for m in msgs)
    assert any("2d/instrumented" in m for m in msgs)


def test_tolerates_renamed_and_missing_decomps():
    """Variant names drift across points (point 0's "1ds" split into
    "1ds-raw"/"1ds-packed"); only pairs present in BOTH points count."""
    data = {"points": [_point(**{"1ds": (0.20, 0.22), "1d": (0.2, 0.2)}),
                       _point(**{"1ds-raw": (9.0, 9.0),
                                 "1d": (0.21, 0.21)})]}
    assert check_points(data) == []


def test_single_point_and_empty_are_clean():
    assert check_points({"points": []}) == []
    assert check_points({"points": [_point(**{"1d": (0.2, 0.2)})]}) == []


def test_threshold_is_configurable():
    data = {"points": [_point(**{"1d": (0.20, 0.20)}),
                       _point(**{"1d": (0.23, 0.20)})]}   # +15%
    assert check_points(data, threshold=0.25) == []
    assert len(check_points(data, threshold=0.10)) == 1


def test_committed_bench_file_passes():
    """CI gate: the repo's own trajectory must be clean — the newest
    appended point may not regress >25% vs its predecessor."""
    path = os.path.join(_ROOT, "BENCH_bfs.json")
    r = subprocess.run(
        [sys.executable,
         os.path.join(_ROOT, "benchmarks", "check_bench_regression.py"),
         path],
        capture_output=True, text=True, timeout=60)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "bench guard clean" in r.stdout
    # and the guard actually compared something once >= 2 points exist
    if len(json.load(open(path)).get("points", [])) >= 2:
        assert "->" in r.stdout
