"""Subprocess entry for multi-device distributed-BFS tests.

Run as:  python tests/_dist_bfs_main.py <n_devices> <mode>
(sets XLA_FLAGS *before* importing jax, so pytest's process keeps 1 dev).
"""
import os
import sys

n_dev = int(sys.argv[1])
mode = sys.argv[2]
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.configs.base import BFSConfig  # noqa: E402
from repro.core.bfs import run_bfs  # noqa: E402
from repro.core.ref import depths_from_parents, validate_parents  # noqa: E402
from repro.graph.formats import build_blocked, build_blocked_1d  # noqa: E402
from repro.graph.rmat import rmat_graph  # noqa: E402
from repro.launch.mesh import make_local_mesh, make_local_mesh_1d  # noqa: E402


def check(edges, pr, pc, cfg, local_mode="dense", roots=(5,)):
    g = build_blocked(edges, pr, pc, align=32, cap_pad=32)
    mesh = make_local_mesh(pr, pc)
    deg = edges.out_degrees()
    for root in roots:
        root = int(root) if deg[int(root)] > 0 else int(np.flatnonzero(deg)[0])
        res = run_bfs(g, root, cfg, mesh, local_mode=local_mode)
        ok, msg = validate_parents(edges.n, edges.src, edges.dst, root,
                                   res.parents)
        assert ok, (pr, pc, cfg.fold_mode, cfg.direction_optimizing,
                    local_mode, msg)
    return res


def main():
    if mode == "grids":
        edges = rmat_graph(9, edge_factor=8, seed=3)
        for pr, pc in [(2, 2), (1, 4), (4, 1), (2, 1), (4, 4), (2, 8),
                       (8, 2), (1, 16), (16, 1)]:
            if pr * pc > n_dev:
                continue
            for fold in ("alltoall", "reduce"):
                for diro in (False, True):
                    check(edges, pr, pc,
                          BFSConfig(fold_mode=fold, direction_optimizing=diro))
        print("OK grids")
    elif mode == "kernel":
        edges = rmat_graph(9, edge_factor=8, seed=5)
        for storage in ("csr", "dcsc"):
            check(edges, 2, 2, BFSConfig(storage=storage),
                  local_mode="kernel")
        print("OK kernel")
    elif mode == "counters":
        edges = rmat_graph(12, edge_factor=16, seed=1)
        pr = pc = 4
        r_td = check(edges, pr, pc, BFSConfig(direction_optimizing=False),
                     roots=(1,))
        r_do = check(edges, pr, pc, BFSConfig(direction_optimizing=True),
                     roots=(1,))
        u = lambda r: sum(v for k, v in r.counters.items()
                          if k.startswith("use_"))
        # the paper's claim: direction-optimizing sends ~an order of
        # magnitude less useful data and examines far fewer edges
        assert u(r_do) < 0.5 * u(r_td), (u(r_do), u(r_td))
        assert (r_do.counters["edges_useful"]
                < 0.3 * r_td.counters["edges_useful"]), (
            r_do.counters["edges_useful"], r_td.counters["edges_useful"])
        # bottom-up was actually used in the middle levels
        modes = r_do.level_stats[: r_do.n_levels, 2]
        assert modes.max() == 1.0 and modes[0] == 0.0
        print("OK counters")
    elif mode == "optimized":
        # beyond-paper variants must stay oracle-valid.  NOTE: only the
        # runtime configs (capacity fallbacks compiled in) are validated;
        # the *_pure variants are roofline-lowering artifacts that drop
        # over-capacity winners by design (EXPERIMENTS.md §Perf).
        import dataclasses as dc
        from repro.configs.base import get_config
        edges = rmat_graph(11, edge_factor=16, seed=2)
        i2_rt = dc.replace(get_config("bfs-rmat-i2"), fold_mode="bitmap")
        for cfg in (get_config("bfs-rmat-opt-rt"), i2_rt):
            check(edges, 4, 4, cfg, roots=(3, 500))
            check(edges, 2, 8, cfg, roots=(3,))
        print("OK optimized")
    elif mode == "oned":
        # the tentpole acceptance case: on >=3 R-MAT scales under a
        # 16-strip mesh, the 1D decomposition must (a) produce valid
        # trees, (b) match the 2D depths exactly, and (c) report
        # wire_expand equal to the comm_model closed form (and no
        # fold/transpose wire at all — those phases don't exist in 1D).
        from repro.core import comm_model
        p = n_dev
        for scale, diro in ((9, True), (10, False), (11, True)):
            edges = rmat_graph(scale, edge_factor=8, seed=scale)
            deg = edges.out_degrees()
            root = int(np.flatnonzero(deg)[0])
            g1 = build_blocked_1d(edges, p, align=32, cap_pad=32)
            r1 = run_bfs(g1, root,
                         BFSConfig(decomposition="1d",
                                   direction_optimizing=diro),
                         make_local_mesh_1d(p))
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       root, r1.parents)
            assert ok, (scale, msg)
            g2 = build_blocked(edges, 4, 4, align=32, cap_pad=32)
            r2 = run_bfs(g2, root,
                         BFSConfig(direction_optimizing=diro),
                         make_local_mesh(4, 4))
            d1 = depths_from_parents(edges.n, r1.parents, root)
            d2 = depths_from_parents(edges.n, r2.parents, root)
            assert np.array_equal(d1, d2), (scale, int((d1 != d2).sum()))
            want = comm_model.expand_1d_words(g1.part.n, p, r1.n_levels)
            got = r1.counters["wire_expand"]
            assert got > 0 and abs(got - want) <= 1e-5 * want, (got, want)
            for k in ("wire_transpose", "wire_fold", "wire_rotate",
                      "wire_updates"):
                assert r1.counters[k] == 0.0, (k, r1.counters[k])
        # LocalOps acceptance: the 1D strip kernels (CSR gather and the
        # strip-DCSC Pallas SpMSV) must match the serial oracle and the
        # 2D depths on the same graph
        edges = rmat_graph(9, edge_factor=8, seed=9)
        root = int(np.flatnonzero(edges.out_degrees())[0])
        g1 = build_blocked_1d(edges, p, align=32, cap_pad=32,
                              with_col_ptr=True)
        g2 = build_blocked(edges, 4, 4, align=32, cap_pad=32)
        r2 = run_bfs(g2, root, BFSConfig(), make_local_mesh(4, 4))
        d2 = depths_from_parents(edges.n, r2.parents, root)
        for storage in ("dcsc", "csr"):
            r1 = run_bfs(g1, root,
                         BFSConfig(decomposition="1d", storage=storage),
                         make_local_mesh_1d(p), local_mode="kernel")
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       root, r1.parents)
            assert ok, (storage, msg)
            d1 = depths_from_parents(edges.n, r1.parents, root)
            assert np.array_equal(d1, d2), (storage, int((d1 != d2).sum()))
        print("OK oned")
    elif mode == "multiroot":
        edges = rmat_graph(10, edge_factor=8, seed=9)
        rng = np.random.default_rng(0)
        deg = edges.out_degrees()
        roots = rng.choice(np.flatnonzero(deg > 0), size=8, replace=False)
        check(edges, 2, 2, BFSConfig(), roots=roots)
        print("OK multiroot")
    elif mode == "multipod":
        # pod-axis batched multi-source BFS through the engine, in BOTH
        # decompositions (a named ROADMAP scenario): graph replicated
        # per pod, roots sharded, level loops in lockstep.  Legacy
        # make_multiroot_bfs_fn path also exercised for compat.
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.bfs import make_multiroot_bfs_fn
        from repro.core.engine import plan_bfs
        edges = rmat_graph(10, edge_factor=8, seed=9)
        deg = edges.out_degrees()
        roots = np.flatnonzero(deg > 0)[:8].astype(np.int32)

        # 2D checkerboard under 2 pods x (2 x 2): 8 devices
        pods, pr, pc = 2, 2, 2
        g = build_blocked(edges, pr, pc, align=32, cap_pad=32)
        devs = np.asarray(jax.devices()[: pods * pr * pc]).reshape(
            pods, pr, pc)
        mesh3 = jax.sharding.Mesh(devs, ("pod", "data", "model"))
        eng2 = plan_bfs(g, BFSConfig(), mesh3).compile()
        b2 = eng2.run_batch(roots)       # 4 searches per pod
        for i, root in enumerate(roots):
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       int(root), b2.parents[i])
            assert ok, ("2d", i, msg)

        # 1D row strips under 2 pods x 8 strips: all 16 devices; depths
        # must match the 2D batch root-for-root
        g1 = build_blocked_1d(edges, 8, align=32, cap_pad=32)
        devs1 = np.asarray(jax.devices()[:16]).reshape(2, 8)
        mesh1 = jax.sharding.Mesh(devs1, ("pod", "data"))
        eng1 = plan_bfs(g1, BFSConfig(decomposition="1d"), mesh1).compile()
        b1 = eng1.run_batch(roots)
        for i, root in enumerate(roots):
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       int(root), b1.parents[i])
            assert ok, ("1d", i, msg)
            d1 = depths_from_parents(edges.n, b1.parents[i], int(root))
            d2 = depths_from_parents(edges.n, b2.parents[i], int(root))
            assert np.array_equal(d1, d2), (i, int((d1 != d2).sum()))

        # legacy builder still works over the registry path
        fn, keys = make_multiroot_bfs_fn(mesh3, g.part, BFSConfig(),
                                         g.cap_seg, n_roots=pods,
                                         maxdeg=g.maxdeg_col)
        arrs = g.device_arrays()
        sh = NamedSharding(mesh3, P("data", "model"))
        gdev = {k: jax.device_put(np.asarray(arrs[k]), sh) for k in keys}
        pis, levels = fn(gdev, jax.device_put(
            roots[:pods], NamedSharding(mesh3, P("pod"))))
        pis = np.asarray(pis)            # (pr, pc, n_roots, chunk)
        for r in range(pods):
            pi = pis[:, :, r, :].reshape(g.part.n)[: g.part.n_orig]
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       int(roots[r]), pi)
            assert ok, (r, msg)
        print("OK multipod")
    else:
        raise SystemExit(f"unknown mode {mode}")


if __name__ == "__main__":
    main()
