"""Subprocess entry for multi-device distributed-BFS tests.

Run as:  python tests/_dist_bfs_main.py <n_devices> <mode>
(sets XLA_FLAGS *before* importing jax, so pytest's process keeps 1 dev).
"""
import os
import sys

n_dev = int(sys.argv[1])
mode = sys.argv[2]
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.configs.base import BFSConfig  # noqa: E402
from repro.core.bfs import run_bfs  # noqa: E402
from repro.core.ref import depths_from_parents, validate_parents  # noqa: E402
from repro.graph.formats import build_blocked, build_blocked_1d  # noqa: E402
from repro.graph.rmat import rmat_graph  # noqa: E402
from repro.launch.mesh import make_local_mesh, make_local_mesh_1d  # noqa: E402


def check(edges, pr, pc, cfg, local_mode="dense", roots=(5,)):
    g = build_blocked(edges, pr, pc, align=32, cap_pad=32)
    mesh = make_local_mesh(pr, pc)
    deg = edges.out_degrees()
    for root in roots:
        root = int(root) if deg[int(root)] > 0 else int(np.flatnonzero(deg)[0])
        res = run_bfs(g, root, cfg, mesh, local_mode=local_mode)
        ok, msg = validate_parents(edges.n, edges.src, edges.dst, root,
                                   res.parents)
        assert ok, (pr, pc, cfg.fold_mode, cfg.direction_optimizing,
                    local_mode, msg)
    return res


def main():
    if mode == "grids":
        edges = rmat_graph(9, edge_factor=8, seed=3)
        for pr, pc in [(2, 2), (1, 4), (4, 1), (2, 1), (4, 4), (2, 8),
                       (8, 2), (1, 16), (16, 1)]:
            if pr * pc > n_dev:
                continue
            for fold in ("alltoall", "reduce"):
                for diro in (False, True):
                    check(edges, pr, pc,
                          BFSConfig(fold_mode=fold, direction_optimizing=diro))
        print("OK grids")
    elif mode == "kernel":
        edges = rmat_graph(9, edge_factor=8, seed=5)
        for storage in ("csr", "dcsc"):
            check(edges, 2, 2, BFSConfig(storage=storage),
                  local_mode="kernel")
        print("OK kernel")
    elif mode == "counters":
        edges = rmat_graph(12, edge_factor=16, seed=1)
        pr = pc = 4
        r_td = check(edges, pr, pc, BFSConfig(direction_optimizing=False),
                     roots=(1,))
        r_do = check(edges, pr, pc, BFSConfig(direction_optimizing=True),
                     roots=(1,))
        u = lambda r: sum(v for k, v in r.counters.items()
                          if k.startswith("use_"))
        # the paper's claim: direction-optimizing sends ~an order of
        # magnitude less useful data and examines far fewer edges
        assert u(r_do) < 0.5 * u(r_td), (u(r_do), u(r_td))
        assert (r_do.counters["edges_useful"]
                < 0.3 * r_td.counters["edges_useful"]), (
            r_do.counters["edges_useful"], r_td.counters["edges_useful"])
        # bottom-up was actually used in the middle levels
        modes = r_do.level_stats[: r_do.n_levels, 2]
        assert modes.max() == 1.0 and modes[0] == 0.0
        print("OK counters")
    elif mode == "optimized":
        # beyond-paper variants must stay oracle-valid.  NOTE: only the
        # runtime configs (capacity fallbacks compiled in) are validated;
        # the *_pure variants are roofline-lowering artifacts that drop
        # over-capacity winners by design (EXPERIMENTS.md §Perf).
        import dataclasses as dc
        from repro.configs.base import get_config
        edges = rmat_graph(11, edge_factor=16, seed=2)
        i2_rt = dc.replace(get_config("bfs-rmat-i2"), fold_mode="bitmap")
        for cfg in (get_config("bfs-rmat-opt-rt"), i2_rt):
            check(edges, 4, 4, cfg, roots=(3, 500))
            check(edges, 2, 8, cfg, roots=(3,))
        print("OK optimized")
    elif mode == "oned":
        # the tentpole acceptance case: on >=3 R-MAT scales under a
        # 16-strip mesh, the 1D decomposition must (a) produce valid
        # trees, (b) match the 2D depths exactly, and (c) report
        # wire_expand equal to the comm_model closed form (and no
        # fold/transpose wire at all — those phases don't exist in 1D).
        from repro.core import comm_model
        p = n_dev
        for scale, diro in ((9, True), (10, False), (11, True)):
            edges = rmat_graph(scale, edge_factor=8, seed=scale)
            deg = edges.out_degrees()
            root = int(np.flatnonzero(deg)[0])
            g1 = build_blocked_1d(edges, p, align=32, cap_pad=32)
            r1 = run_bfs(g1, root,
                         BFSConfig(decomposition="1d",
                                   direction_optimizing=diro),
                         make_local_mesh_1d(p))
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       root, r1.parents)
            assert ok, (scale, msg)
            g2 = build_blocked(edges, 4, 4, align=32, cap_pad=32)
            r2 = run_bfs(g2, root,
                         BFSConfig(direction_optimizing=diro),
                         make_local_mesh(4, 4))
            d1 = depths_from_parents(edges.n, r1.parents, root)
            d2 = depths_from_parents(edges.n, r2.parents, root)
            assert np.array_equal(d1, d2), (scale, int((d1 != d2).sum()))
            want = comm_model.expand_1d_words(g1.part.n, p, r1.n_levels)
            got = r1.counters["wire_expand"]
            assert got > 0 and abs(got - want) <= 1e-5 * want, (got, want)
            for k in ("wire_transpose", "wire_fold", "wire_rotate",
                      "wire_updates"):
                assert r1.counters[k] == 0.0, (k, r1.counters[k])
        # LocalOps acceptance: the 1D strip kernels (CSR gather and the
        # strip-DCSC Pallas SpMSV) must match the serial oracle and the
        # 2D depths on the same graph
        edges = rmat_graph(9, edge_factor=8, seed=9)
        root = int(np.flatnonzero(edges.out_degrees())[0])
        g1 = build_blocked_1d(edges, p, align=32, cap_pad=32,
                              with_col_ptr=True)
        g2 = build_blocked(edges, 4, 4, align=32, cap_pad=32)
        r2 = run_bfs(g2, root, BFSConfig(), make_local_mesh(4, 4))
        d2 = depths_from_parents(edges.n, r2.parents, root)
        for storage in ("dcsc", "csr"):
            r1 = run_bfs(g1, root,
                         BFSConfig(decomposition="1d", storage=storage),
                         make_local_mesh_1d(p), local_mode="kernel")
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       root, r1.parents)
            assert ok, (storage, msg)
            d1 = depths_from_parents(edges.n, r1.parents, root)
            assert np.array_equal(d1, d2), (storage, int((d1 != d2).sum()))
        print("OK oned")
    elif mode == "onedsparse":
        # the "1ds" tentpole acceptance: on 16 strips the sparse
        # owner-directed exchange must (a) produce valid trees matching
        # the 1d/2d depths, (b) measure wire_expand within 2x of the
        # Buluc & Madduri closed form topdown_1d_words when the buckets
        # never overflow, (c) beat the dense bitmap on the first two
        # (small-frontier) levels, and (d) never ship MORE than the
        # bitmap when the planned hybrid capacity is in force.
        from repro.core import comm_model
        p = n_dev
        for scale, diro in ((9, True), (10, False)):
            edges = rmat_graph(scale, edge_factor=8, seed=scale)
            deg = edges.out_degrees()
            root = int(np.flatnonzero(deg)[0])
            g1 = build_blocked_1d(edges, p, align=32, cap_pad=32)
            cfg_s = BFSConfig(decomposition="1ds",
                              direction_optimizing=diro)
            rs = run_bfs(g1, root, cfg_s, make_local_mesh_1d(p))
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       root, rs.parents)
            assert ok, (scale, msg)
            r1 = run_bfs(g1, root,
                         BFSConfig(decomposition="1d",
                                   direction_optimizing=diro),
                         make_local_mesh_1d(p))
            g2 = build_blocked(edges, 4, 4, align=32, cap_pad=32)
            r2 = run_bfs(g2, root,
                         BFSConfig(direction_optimizing=diro),
                         make_local_mesh(4, 4))
            ds = depths_from_parents(edges.n, rs.parents, root)
            assert np.array_equal(
                ds, depths_from_parents(edges.n, r1.parents, root)), scale
            assert np.array_equal(
                ds, depths_from_parents(edges.n, r2.parents, root)), scale
            for k in ("wire_transpose", "wire_fold", "wire_rotate",
                      "wire_updates"):
                assert rs.counters[k] == 0.0, (k, rs.counters[k])

        # codec + sieve acceptance: parents bit-identical with the
        # packed codec and the visited sieve on vs off, across local
        # modes (dense / Pallas kernel), storages (csr / dcsc) and both
        # instrument modes, on 16 strips
        from repro.core.engine import plan_bfs
        edges = rmat_graph(10, edge_factor=8, seed=10)
        root = int(np.flatnonzero(edges.out_degrees())[0])
        gk = build_blocked_1d(edges, p, align=32, cap_pad=32,
                              with_col_ptr=True)
        base = None
        for codec in ("none", "packed"):
            for lm in ("dense", "kernel"):
                for storage in ("csr", "dcsc"):
                    for instr in (True, False):
                        r = plan_bfs(
                            gk, BFSConfig(decomposition="1ds",
                                          storage=storage,
                                          frontier_codec=codec,
                                          instrument=instr),
                            make_local_mesh_1d(p),
                            local_mode=lm).compile().run(root)
                        if base is None:
                            base = r.parents
                            ok, msg = validate_parents(
                                edges.n, edges.src, edges.dst, root,
                                r.parents)
                            assert ok, msg
                        assert np.array_equal(r.parents, base), (
                            codec, lm, storage, instr)
                        if not instr:
                            assert r.counters == {}, (codec, lm, storage)

        # (b)+(c): scale-14, pure top-down, overflow disabled
        # (cap_x = chunk), a typical low-degree root.  The raw-id runs
        # pin the UNCOMPRESSED closed forms, so codec="none" here; the
        # packed counterpart follows below.
        edges = rmat_graph(14, edge_factor=4, seed=14)
        deg = edges.out_degrees()
        root = int(np.flatnonzero((deg > 0) & (deg <= 32))[0])
        g1 = build_blocked_1d(edges, p, align=32, cap_pad=32)
        cfg = BFSConfig(decomposition="1ds", direction_optimizing=False,
                        frontier_codec="none")
        r = run_bfs(g1, root, cfg, make_local_mesh_1d(p),
                    cap_x=g1.part.chunk)
        ok, msg = validate_parents(edges.n, edges.src, edges.dst, root,
                                   r.parents)
        assert ok, msg
        got = r.counters["wire_expand"]
        want = comm_model.topdown_1d_words(edges.m, p)
        assert 0.5 * want <= got <= 2.0 * want, (got, want)
        # per-level measured words (stats col 4): every non-overflow
        # level matches the sparse closed form on that level's frontier
        sizes = r.level_stats[: r.n_levels, 0]
        wires = r.level_stats[: r.n_levels, 4]
        model = np.array([comm_model.sparse_expand_1d_words(s, p)
                          for s in sizes])
        assert np.allclose(wires, model, rtol=1e-5), (wires, model)
        # the first two levels beat the dense bitmap by a wide margin
        dense_lvl = comm_model.expand_1d_level_words(g1.part.n, p)
        assert wires[0] < dense_lvl and wires[1] < dense_lvl, (
            wires[:2], dense_lvl)
        # ... while the dense "1d" run pays dense_lvl on EVERY level
        r1 = run_bfs(g1, root, BFSConfig(decomposition="1d",
                                         direction_optimizing=False),
                     make_local_mesh_1d(p))
        assert np.allclose(r1.level_stats[: r1.n_levels, 4], dense_lvl)
        assert np.array_equal(r1.parents, r.parents)

        # (d): the planned hybrid cap never ships an overflowing sparse
        # level — every level's words are either the sparse form (fits)
        # or exactly the dense bitmap (fallback), totalling no more than
        # a small factor of the pure-dense volume
        rh = run_bfs(g1, root, cfg, make_local_mesh_1d(p))
        assert np.array_equal(rh.parents, r.parents)
        wires_h = rh.level_stats[: rh.n_levels, 4]
        sizes_h = rh.level_stats[: rh.n_levels, 0]
        for s, w in zip(sizes_h, wires_h):
            sparse_w = comm_model.sparse_expand_1d_words(s, p)
            assert (abs(w - sparse_w) <= 1e-5 * max(sparse_w, 1)
                    or abs(w - dense_lvl) <= 1e-5 * dense_lvl), (s, w)
        assert wires_h.sum() <= r1.counters["wire_expand"] + 1e-3, (
            wires_h.sum(), r1.counters["wire_expand"])

        # packed-codec acceptance on the same pinned scale-14/p=16
        # config: parents unchanged, every level's measured words match
        # the compressed closed form (fit) or the dense bitmap
        # (fallback), and the TOTAL wire_expand is strictly below the
        # raw-id hybrid baseline above (the PR 5 figure)
        bits = comm_model.codec_bits(g1.part.chunk)
        cfg_p = BFSConfig(decomposition="1ds",
                          direction_optimizing=False)  # packed default
        rp = run_bfs(g1, root, cfg_p, make_local_mesh_1d(p))
        assert np.array_equal(rp.parents, r.parents)
        wires_p = rp.level_stats[: rp.n_levels, 4]
        sizes_p = rp.level_stats[: rp.n_levels, 0]
        n_sparse_p = 0
        for s, w in zip(sizes_p, wires_p):
            packed_w = comm_model.compressed_expand_1d_words(s, p, bits)
            if abs(w - packed_w) <= 1e-5 * max(packed_w, 1):
                n_sparse_p += 1
            else:
                assert abs(w - dense_lvl) <= 1e-5 * dense_lvl, (s, w)
        # the bits-aware plan admits more sparse levels than the raw one
        n_sparse_raw = sum(
            1 for s, w in zip(sizes_h, wires_h)
            if abs(w - comm_model.sparse_expand_1d_words(s, p))
            <= 1e-5 * max(comm_model.sparse_expand_1d_words(s, p), 1))
        assert n_sparse_p >= n_sparse_raw, (n_sparse_p, n_sparse_raw)
        # and the headline: packed total strictly below the raw total
        assert wires_p.sum() < wires_h.sum(), (
            wires_p.sum(), wires_h.sum())
        print("codec totals: packed", float(wires_p.sum()),
              "raw", float(wires_h.sum()),
              "dense", float(dense_lvl * r1.n_levels))
        print("OK onedsparse")
    elif mode == "podheur":
        # per-slice direction heuristic regression: two pod-batched
        # roots of different eccentricity must switch modes on their
        # OWN frontier sizes — the batched program's per-root
        # level_stats (n_f, m_f, mode) must be bit-identical to each
        # root's single-root run.  (The old loop state fed the
        # cross-pod pmax'd n_f back into the go_td heuristic, so the
        # pod with the smaller frontier switched on its lockstep
        # partner's numbers — and its stats recorded them.)  Runs in
        # the sparse-exchange "1ds" entry, doubling as the multi-device
        # run_batch coverage for the third registry entry.
        import jax
        from repro.core.engine import plan_bfs
        assert n_dev >= 8
        edges = rmat_graph(9, edge_factor=8, seed=9)
        deg = edges.out_degrees()
        roots = np.flatnonzero(deg > 0)[:8].astype(np.int32)
        g1 = build_blocked_1d(edges, 4, align=32, cap_pad=32)
        devs = np.asarray(jax.devices()[:8]).reshape(2, 4)
        mesh = jax.sharding.Mesh(devs, ("pod", "data"))
        eng = plan_bfs(g1, BFSConfig(decomposition="1ds"), mesh).compile()
        singles = [eng.run(int(r)) for r in roots]
        diff = [(i, j) for i in range(len(roots))
                for j in range(i + 1, len(roots))
                if not np.array_equal(singles[i].level_stats,
                                      singles[j].level_stats)]
        assert diff, "need two roots with different frontier trajectories"
        # prefer different eccentricity: the searches must also switch
        # back to top-down / terminate at different levels
        a, b = max(diff, key=lambda ij: abs(singles[ij[0]].n_levels
                                            - singles[ij[1]].n_levels))
        pair = np.array([roots[a], roots[b]], dtype=np.int32)
        bp = eng.run_batch(pair)         # one root per pod, in lockstep
        for i, j in enumerate((a, b)):
            s = singles[j]
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       int(pair[i]), bp.parents[i])
            assert ok, (i, msg)
            # lockstep trip count = the slower search's level count
            assert bp.n_levels[i] == max(singles[a].n_levels,
                                         singles[b].n_levels)
            got = bp.level_stats[i][: s.n_levels, :3]
            want = s.level_stats[: s.n_levels, :3]
            assert np.array_equal(got, want), (
                int(pair[i]), got[:, (0, 2)], want[:, (0, 2)])
            # levels past this root's own search stay empty
            assert (bp.level_stats[i][s.n_levels:, 0] == 0).all()
        print("OK podheur")
    elif mode == "fastpath":
        # instrument=False acceptance on 16 devices, all three
        # decompositions: the latency-lean program (one fused scalar
        # reduction per level, batched bottom-up update exchange,
        # counters compiled out) must return bit-identical parents to
        # the instrumented program and oracle-valid trees, including
        # under direction switching and the compact-update exchange.
        from repro.core.engine import plan_bfs
        edges = rmat_graph(10, edge_factor=8, seed=9)
        deg = edges.out_degrees()
        roots = np.flatnonzero(deg > 0)[:3]
        g1 = build_blocked_1d(edges, n_dev, align=32, cap_pad=32)
        g2 = build_blocked(edges, 4, 4, align=32, cap_pad=32)
        cases = [("1d", g1, make_local_mesh_1d(n_dev), {}),
                 ("1ds", g1, make_local_mesh_1d(n_dev), {}),
                 ("2d", g2, make_local_mesh(4, 4), {}),
                 ("2d", g2, make_local_mesh(4, 4),
                  {"fold_mode": "alltoall", "compact_updates": True})]
        for decomp, g, mesh, kw in cases:
            ref = plan_bfs(g, BFSConfig(decomposition=decomp, **kw),
                           mesh).compile()
            fast = plan_bfs(g, BFSConfig(decomposition=decomp,
                                         instrument=False, **kw),
                            mesh).compile()
            # the fast program really is leaner: at most 2 all-reduces
            # survive in the compiled search (the fused init + loop
            # reductions; compact updates add their overflow pmax) vs
            # the instrumented counter schedule
            cf = fast.collective_counts()
            ci = ref.collective_counts()
            ar_budget = 3 if kw.get("compact_updates") else 2
            assert cf.get("all-reduce", 0) <= ar_budget, (decomp, kw, cf)
            assert cf["total"] < ci["total"], (decomp, kw, cf, ci)
            for root in roots:
                ri = ref.run(int(root))
                rf = fast.run(int(root))
                ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                           int(root), rf.parents)
                assert ok, (decomp, kw, int(root), msg)
                assert np.array_equal(rf.parents, ri.parents), (
                    decomp, kw, int(root))
                assert rf.n_levels == ri.n_levels, (decomp, kw, int(root))
                # fast runs carry NO counters — zeros here would read
                # as measured wire volumes in mode-mixing aggregates
                assert rf.counters == {}

        # pod-batched fast path: the fused lockstep pmax (and, for 2d,
        # the sync_modes decision riding it as go_bu / 1-go_td) only
        # executes under a pod axis — cross-check run_batch parents
        # against the single-root fast program in both families
        import jax
        pair = roots[:2].astype(np.int32)
        g2s = build_blocked(edges, 2, 2, align=32, cap_pad=32)
        pods2d = jax.sharding.Mesh(
            np.asarray(jax.devices()[:8]).reshape(2, 2, 2),
            ("pod", "data", "model"))
        pods1d = jax.sharding.Mesh(
            np.asarray(jax.devices()[:8]).reshape(2, 4), ("pod", "data"))
        g1s = build_blocked_1d(edges, 4, align=32, cap_pad=32)
        for decomp, g, mesh in (("1ds", g1s, pods1d), ("2d", g2s, pods2d)):
            eng = plan_bfs(g, BFSConfig(decomposition=decomp,
                                        instrument=False), mesh).compile()
            bp = eng.run_batch(pair)
            for i, root in enumerate(pair):
                single = eng.run(int(root))
                ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                           int(root), bp.parents[i])
                assert ok, ("batch", decomp, int(root), msg)
                assert np.array_equal(bp.parents[i], single.parents), (
                    "batch", decomp, int(root))
        print("OK fastpath")
    elif mode == "pipelined":
        # software-pipelined expand acceptance on 16 devices: for every
        # decomposition x expand_chunks in {2, 4}, the chunked program
        # must return BIT-IDENTICAL parents to expand_chunks=1 (the
        # chunked gather reorders the exchange, never the
        # (select-source, min) semiring result), keep the identical
        # per-level direction-mode sequence when instrumented, and the
        # instrument=False fast path must agree too.  Scale 11 over 16
        # strips packs each strip to 4 words, so 4 is the deepest
        # chunking this mesh admits.
        from repro.core.engine import plan_bfs
        edges = rmat_graph(11, edge_factor=8, seed=11)
        deg = edges.out_degrees()
        roots = [int(r) for r in np.flatnonzero(deg > 0)[:2]]
        g1 = build_blocked_1d(edges, n_dev, align=32, cap_pad=32)
        g2 = build_blocked(edges, 4, 4, align=32, cap_pad=32)
        cases = [("1d", g1, make_local_mesh_1d(n_dev), {}),
                 ("1ds", g1, make_local_mesh_1d(n_dev), {}),
                 ("1ds", g1, make_local_mesh_1d(n_dev),
                  {"frontier_codec": "none"}),
                 ("2d", g2, make_local_mesh(4, 4), {})]
        for decomp, g, mesh, kw in cases:
            ref = plan_bfs(g, BFSConfig(decomposition=decomp, **kw),
                           mesh).compile()
            refs = [ref.run(r) for r in roots]
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       roots[0], refs[0].parents)
            assert ok, (decomp, kw, msg)
            for ec in (2, 4):
                eng = plan_bfs(g, BFSConfig(decomposition=decomp,
                                            expand_chunks=ec, **kw),
                               mesh).compile()
                fast = plan_bfs(g, BFSConfig(decomposition=decomp,
                                             expand_chunks=ec,
                                             instrument=False, **kw),
                                mesh).compile()
                for i, root in enumerate(roots):
                    r = eng.run(root)
                    rf = fast.run(root)
                    key = (decomp, kw, ec, root)
                    assert np.array_equal(r.parents, refs[i].parents), key
                    assert r.n_levels == refs[i].n_levels, key
                    # the chunked exchange must not perturb a single
                    # direction decision: stats cols (n_f, m_f, mode,
                    # used) identical; wire (col 4) may differ only for
                    # "1ds" (per-sub-range overflow -> dense fallback)
                    assert np.array_equal(
                        r.level_stats[:, :4],
                        refs[i].level_stats[:, :4]), key
                    if decomp != "1ds":
                        assert np.array_equal(
                            r.level_stats, refs[i].level_stats), key
                    assert np.array_equal(rf.parents, refs[i].parents), key
                    assert rf.n_levels == refs[i].n_levels, key
                    assert rf.counters == {}, key
        print("OK pipelined")
    elif mode == "born":
        # born-sharded graphs on 16 devices: the device-side distributed
        # build must be BIT-IDENTICAL to the host builders on the same
        # counter stream in every decomposition (arrays, capacities,
        # degree distribution, m/m_input), traverse to the same parents,
        # and a scale-18 graph must build end-to-end on device (no
        # host-side edge materialization), round-trip the graph store,
        # and traverse.
        import tempfile
        from repro.ckpt.graph_store import GraphStore, plan_bfs_from_store
        from repro.core.engine import plan_bfs
        from repro.graph.dist_build import BuildSpec, dist_build

        spec = BuildSpec(scale=10, edge_factor=16, seed=3)
        edges = rmat_graph(spec.scale, edge_factor=spec.edge_factor,
                           seed=spec.seed, generator="counter")
        gh1 = build_blocked_1d(edges, n_dev, align=32, cap_pad=32)
        gh2 = build_blocked(edges, 4, 4, align=32, cap_pad=32)
        mesh1 = make_local_mesh_1d(n_dev)
        mesh2 = make_local_mesh(4, 4)
        gd1, _ = dist_build(spec, "1d", mesh1, n_dev, align=32, cap_pad=32)
        gd2, _ = dist_build(spec, "2d", mesh2, (4, 4), align=32,
                            cap_pad=32)
        for gd, gh in ((gd1, gh1), (gd2, gh2)):
            assert gd.m == gh.m and gd.m_input == gh.m_input
            assert (gd.cap, gd.maxdeg_col) == (gh.cap, gh.maxdeg_col)
            ha = gh.device_arrays()
            for k, v in gd.device_arrays().items():
                assert np.array_equal(np.asarray(v), np.asarray(ha[k])), k
        assert np.array_equal(                 # degree histogram over V
            np.bincount(np.asarray(gd1.deg_A).ravel()),
            np.bincount(np.asarray(gh1.deg_A).ravel()))
        for decomp, gd, gh, mesh in (("1d", gd1, gh1, mesh1),
                                     ("1ds", gd1, gh1, mesh1),
                                     ("2d", gd2, gh2, mesh2)):
            cfg = BFSConfig(decomposition=decomp)
            rd = plan_bfs(gd, cfg, mesh).compile().run(5)
            rh = plan_bfs(gh, cfg, mesh).compile().run(5)
            assert np.array_equal(rd.parents, rh.parents), decomp
            ok, msg = validate_parents(edges.n, edges.src, edges.dst, 5,
                                       rd.parents)
            assert ok, (decomp, msg)

        spec18 = BuildSpec(scale=18, edge_factor=16, seed=1)
        g18, info = dist_build(spec18, "1d", mesh1, n_dev)
        assert info["m"] > spec18.m_input      # symmetrized unique edges
        store = GraphStore(tempfile.mkdtemp())
        store.save_graph("s18", g18, spec=spec18)
        plan = plan_bfs_from_store(
            store, "s18", BFSConfig(decomposition="1d", instrument=False),
            mesh1, expect_spec=spec18)
        res = plan.compile(store=store).run(
            int(np.argmax(np.asarray(g18.deg_A).ravel())))
        assert int((res.parents >= 0).sum()) > spec18.n // 4
        print("OK born")
    elif mode == "multiroot":
        edges = rmat_graph(10, edge_factor=8, seed=9)
        rng = np.random.default_rng(0)
        deg = edges.out_degrees()
        roots = rng.choice(np.flatnonzero(deg > 0), size=8, replace=False)
        check(edges, 2, 2, BFSConfig(), roots=roots)
        print("OK multiroot")
    elif mode == "multipod":
        # pod-axis batched multi-source BFS through the engine, in BOTH
        # decompositions (a named ROADMAP scenario): graph replicated
        # per pod, roots sharded, level loops in lockstep.  Legacy
        # make_multiroot_bfs_fn path also exercised for compat.
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.bfs import make_multiroot_bfs_fn
        from repro.core.engine import plan_bfs
        edges = rmat_graph(10, edge_factor=8, seed=9)
        deg = edges.out_degrees()
        roots = np.flatnonzero(deg > 0)[:8].astype(np.int32)

        # 2D checkerboard under 2 pods x (2 x 2): 8 devices
        pods, pr, pc = 2, 2, 2
        g = build_blocked(edges, pr, pc, align=32, cap_pad=32)
        devs = np.asarray(jax.devices()[: pods * pr * pc]).reshape(
            pods, pr, pc)
        mesh3 = jax.sharding.Mesh(devs, ("pod", "data", "model"))
        eng2 = plan_bfs(g, BFSConfig(), mesh3).compile()
        b2 = eng2.run_batch(roots)       # 4 searches per pod
        for i, root in enumerate(roots):
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       int(root), b2.parents[i])
            assert ok, ("2d", i, msg)

        # 1D row strips under 2 pods x 8 strips: all 16 devices; depths
        # must match the 2D batch root-for-root
        g1 = build_blocked_1d(edges, 8, align=32, cap_pad=32)
        devs1 = np.asarray(jax.devices()[:16]).reshape(2, 8)
        mesh1 = jax.sharding.Mesh(devs1, ("pod", "data"))
        eng1 = plan_bfs(g1, BFSConfig(decomposition="1d"), mesh1).compile()
        b1 = eng1.run_batch(roots)
        for i, root in enumerate(roots):
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       int(root), b1.parents[i])
            assert ok, ("1d", i, msg)
            d1 = depths_from_parents(edges.n, b1.parents[i], int(root))
            d2 = depths_from_parents(edges.n, b2.parents[i], int(root))
            assert np.array_equal(d1, d2), (i, int((d1 != d2).sum()))

        # legacy builder still works over the registry path
        fn, keys = make_multiroot_bfs_fn(mesh3, g.part, BFSConfig(),
                                         g.cap_seg, n_roots=pods,
                                         maxdeg=g.maxdeg_col)
        arrs = g.device_arrays()
        sh = NamedSharding(mesh3, P("data", "model"))
        gdev = {k: jax.device_put(np.asarray(arrs[k]), sh) for k in keys}
        pis, levels, _ = fn(gdev, jax.device_put(
            roots[:pods], NamedSharding(mesh3, P("pod"))))
        pis = np.asarray(pis)            # (pr, pc, n_roots, chunk)
        for r in range(pods):
            pi = pis[:, :, r, :].reshape(g.part.n)[: g.part.n_orig]
            ok, msg = validate_parents(edges.n, edges.src, edges.dst,
                                       int(roots[r]), pi)
            assert ok, (r, msg)
        print("OK multipod")
    else:
        raise SystemExit(f"unknown mode {mode}")


if __name__ == "__main__":
    main()
