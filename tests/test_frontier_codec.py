"""Frontier-codec + pack_ids/unpack_ids boundary coverage.

Three layers, innermost out:

  * ``frontier.pack_ids``/``unpack_ids`` boundary cases the sparse
    exchange depends on — a frontier of EXACTLY cap_x ids (the overflow
    predicate is ``>``, not ``>=``), the last slot of a chunk, and
    all-sentinel buckets roundtripping to an empty bitmap;
  * the packed codec (``kernels/frontier_codec``): property roundtrip,
    Pallas-kernel vs jnp-oracle bit-parity, count-word clamping;
  * ``sparse_exchange_1d`` at p=1: exact-capacity levels stay sparse,
    and the visited-bitmap sieve demonstrably strips already-discovered
    vertices from a deliberately dirty frontier (in the BFS loop the
    frontier is always fresh, so the sieve is invisible there — this is
    where its behavior is actually observable).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import comm_model
from repro.core.compat import shard_map
from repro.core.frontier import pack_ids, unpack_bits, unpack_ids
from repro.core.steps_1d_sparse import sparse_exchange_1d
from repro.graph.formats import build_blocked_1d
from repro.graph.rmat import rmat_graph
from repro.launch.mesh import make_local_mesh_1d
from repro.kernels.frontier_codec import ops as codec_ops
from repro.kernels.frontier_codec import ref as codec_ref


# ---------------------------------------------------------------------------
# pack_ids / unpack_ids boundaries (satellite coverage)
# ---------------------------------------------------------------------------


def test_pack_ids_exactly_cap_no_loss():
    """cap set bits fill the buffer exactly — no sentinel, no drop (the
    exchange's overflow predicate is n_local > cap_x, so == cap_x must
    go sparse and be lossless)."""
    chunk, cap = 128, 32
    idx = np.sort(np.random.default_rng(0).choice(chunk, cap, replace=False))
    mask = np.zeros(chunk, bool)
    mask[idx] = True
    ids = np.asarray(pack_ids(jnp.asarray(mask), cap, 1000, 9999))
    assert np.array_equal(ids, 1000 + idx)
    assert not (ids == 9999).any()


def test_pack_ids_last_slot_of_chunk():
    """The final vertex of the chunk (off == chunk-1) must survive the
    off < chunk sentinel comparison — an off-by-one there would silently
    drop exactly the last slot."""
    chunk, cap = 128, 8
    mask = np.zeros(chunk, bool)
    mask[chunk - 1] = True
    ids = np.asarray(pack_ids(jnp.asarray(mask), cap, 0, -1))
    assert ids[0] == chunk - 1
    assert (ids[1:] == -1).all()
    # and it roundtrips through the scatter into the last bitmap slot
    words = unpack_ids(jnp.asarray(ids), chunk)
    back = np.asarray(unpack_bits(words))
    assert back[chunk - 1] and back.sum() == 1


def test_all_sentinel_bucket_roundtrips_empty():
    """A bucket of nothing but sentinels (empty frontier, or a peer with
    no discoveries) must scatter to an all-zero bitmap — mode="drop"
    discards every out-of-range id."""
    n, cap = 256, 16
    ids = jnp.full((cap,), n, jnp.int32)          # the pack_ids sentinel
    assert not np.asarray(unpack_ids(ids, n)).any()
    empty = pack_ids(jnp.zeros((64,), bool), cap, 0, n)
    assert (np.asarray(empty) == n).all()
    assert not np.asarray(unpack_ids(empty, n)).any()


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip_under_cap(seed):
    rng = np.random.default_rng(seed)
    chunk = 32 * int(rng.integers(1, 8))
    cap = int(rng.integers(1, chunk + 1))
    k = int(rng.integers(0, cap + 1))
    idx = np.sort(rng.choice(chunk, k, replace=False))
    mask = np.zeros(chunk, bool)
    mask[idx] = True
    ids = pack_ids(jnp.asarray(mask), cap, 0, chunk)
    back = unpack_bits(unpack_ids(ids, chunk))
    assert np.array_equal(np.asarray(back), mask)


# ---------------------------------------------------------------------------
# Packed codec: roundtrip + Pallas/oracle parity
# ---------------------------------------------------------------------------


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_codec_roundtrip_property(seed):
    """encode -> concat buckets -> decode recovers exactly the live ids
    of every bucket (rebased by owner), sentinels elsewhere — for random
    chunk sizes, capacities, and fills, Pallas bit-identical to the
    oracle."""
    rng = np.random.default_rng(seed)
    chunk = 32 * int(rng.integers(1, 40))
    cap = int(rng.integers(1, min(chunk, 160) + 1))
    p = int(rng.choice([1, 2, 4, 8]))
    n = chunk * p
    bufs, want = [], []
    for k in range(p):
        cnt = int(rng.integers(0, cap + 1))
        off = np.sort(rng.choice(chunk, cnt, replace=False)).astype(np.int32)
        offp = np.concatenate([off, np.full(cap - cnt, chunk, np.int32)])
        e_ref = codec_ref.encode_offsets(jnp.asarray(offp), jnp.int32(cnt),
                                         chunk)
        e_ker = codec_ops.encode_offsets(jnp.asarray(offp), jnp.int32(cnt),
                                         chunk)
        assert np.array_equal(np.asarray(e_ref), np.asarray(e_ker))
        assert int(np.asarray(e_ref)[0]) == cnt     # count word is first
        bufs.append(np.asarray(e_ref))
        want.append(k * chunk + off)
    recv = jnp.asarray(np.concatenate(bufs))
    d_ref = np.asarray(codec_ref.decode_buckets(recv, chunk, cap, n))
    d_ker = np.asarray(codec_ops.decode_buckets(recv, chunk, cap, n, p))
    assert np.array_equal(d_ref, d_ker)
    live = d_ref[d_ref < n]
    assert np.array_equal(np.sort(live), np.sort(np.concatenate(want)))
    # decoded buffer is (p, cap) bucket-major: slots past count are n
    rows = d_ref.reshape(p, cap)
    for k in range(p):
        cnt = int(bufs[k][0])
        assert (rows[k][cnt:] == n).all()


def test_codec_buffer_layout_and_count_clamp():
    chunk, cap = 1024, 32
    bits = comm_model.codec_bits(chunk)
    w = comm_model.codec_packed_words(cap, bits)
    off = jnp.arange(cap, dtype=jnp.int32)
    buf = codec_ref.encode_offsets(off, jnp.int32(cap), chunk)
    assert buf.shape == (1 + w,) and buf.dtype == jnp.uint32
    # an over-large count word (corrupt input) clamps to cap on encode
    buf2 = codec_ref.encode_offsets(off, jnp.int32(cap + 100), chunk)
    assert int(np.asarray(buf2)[0]) == cap
    # encoded buckets really are smaller than raw id buckets
    assert (1 + w) < cap  # u32 words vs cap i32 id slots


# ---------------------------------------------------------------------------
# sparse_exchange_1d: exact capacity + the observable sieve
# ---------------------------------------------------------------------------


def _exchange(front, part, cap_x, visited=None, codec="none",
              use_kernel=False):
    """Run the exchange in a p=1 shard_map; returns (bitmap bool[n],
    over bool)."""
    mesh = make_local_mesh_1d(1)

    def body(f, v):
        f_words, wire, over = sparse_exchange_1d(
            f[0], "data", cap_x, part, instrument=True,
            visited=None if visited is None else v[0],
            codec=codec, use_kernel=use_kernel)
        return f_words[None], over.reshape(1)

    v_in = np.zeros_like(front) if visited is None else visited
    words, over = shard_map(
        body, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data")), check_vma=False)(front, v_in)
    return (np.asarray(unpack_bits(jnp.asarray(words[0]))),
            bool(np.asarray(over)[0]))


@pytest.fixture(scope="module")
def part1():
    e = rmat_graph(8, edge_factor=8, seed=4)
    return build_blocked_1d(e, 1, align=32, cap_pad=32).part


@pytest.mark.parametrize("codec", ["none", "packed"])
def test_exchange_exactly_cap_stays_sparse(part1, codec):
    """== cap_x send bits must take the sparse branch (predicate is >)
    and reproduce the frontier exactly; cap_x+1 overflows to dense —
    and BOTH produce the same bitmap."""
    cap = 32
    rng = np.random.default_rng(1)
    for extra in (0, 1):
        idx = np.sort(rng.choice(part1.chunk, cap + extra, replace=False))
        front = np.zeros((1, part1.chunk), bool)
        front[0, idx] = True
        bitmap, over = _exchange(front, part1, cap, codec=codec)
        assert over == bool(extra)
        assert np.array_equal(bitmap[: part1.chunk], front[0])


@pytest.mark.parametrize("codec,use_kernel",
                         [("none", False), ("packed", False),
                          ("packed", True)])
def test_sieve_strips_visited_from_dirty_frontier(part1, codec, use_kernel):
    """With a deliberately DIRTY frontier (re-listing already-visited
    vertices — never produced by the BFS loop, which is why parents stay
    bit-identical there), the sieve must remove the visited bits from
    the exchanged bitmap and from the overflow count."""
    cap = 32
    rng = np.random.default_rng(2)
    idx = np.sort(rng.choice(part1.chunk, 48, replace=False))
    front = np.zeros((1, part1.chunk), bool)
    front[0, idx] = True
    visited = np.zeros((1, part1.chunk), bool)
    visited[0, idx[:20]] = True                  # 20 stale re-listings
    # unsieved: 48 > cap -> dense fallback, all 48 bits ship
    bitmap, over = _exchange(front, part1, cap, codec=codec,
                             use_kernel=use_kernel)
    assert over and bitmap[: part1.chunk].sum() == 48
    # sieved: 28 live bits fit the buckets -> sparse, visited bits gone
    bitmap, over = _exchange(front, part1, cap, visited=visited,
                             codec=codec, use_kernel=use_kernel)
    assert not over
    want = front[0] & ~visited[0]
    assert np.array_equal(bitmap[: part1.chunk], want)
    assert bitmap[: part1.chunk].sum() == 28


def test_sieve_excludes_frontier_itself(part1):
    """visited masks built as (pi != -1) & ~front keep the frontier: a
    visited mask that (wrongly) included frontier vertices would zero
    the exchange.  Guard the exchange-level contract: visited ∩ front
    is removed, so callers MUST exclude the frontier — exactly what
    topdown_level_1ds does."""
    front = np.zeros((1, part1.chunk), bool)
    front[0, :8] = True
    visited = front.copy()                       # pathological caller
    bitmap, _ = _exchange(front, part1, 32, visited=visited)
    assert not bitmap.any()
