"""Regenerate the lowered/compiled HLO fixture dumps.

    python tests/fixtures/hlo/regen.py

Writes ``probe.stablehlo.txt`` (lowered StableHLO: the
``stablehlo.all_reduce`` / ``"stablehlo.all_to_all"(...)`` spellings)
and ``probe.compiled.txt`` (compiled CPU HLO: the hyphenated
``all-reduce(...)`` spellings, tuple-shaped all-to-all, operand
references like ``%all-to-all.2)`` that must NOT count) from one probe
program issuing exactly one collective of each lowerable kind.

``tpu_async.hlo.txt`` is hand-written (we have no TPU compiler in the
test environment) and NOT regenerated here — it pins the async
``-start``/``-done`` pair spelling, ``reduce-scatter``, and the
``metadata={op_name="...all-gather(..."}`` string hazard that the
quote guard in ``engine._COLLECTIVE_OP_RE`` exists for.

The committed dumps are test fixtures, not golden compiler output: a
jax upgrade that changes the text should regenerate them and re-pin
the counts in tests/test_hlo_counts.py if a spelling genuinely moved.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

_HERE = os.path.dirname(os.path.abspath(__file__))


def build():
    mesh = Mesh(jax.devices()[:8], ("x",))

    def local(v):
        s = jax.lax.psum(v, "x")
        g = jax.lax.all_gather(v, "x", axis=0, tiled=True)
        t = jax.lax.all_to_all(v, "x", split_axis=1, concat_axis=1)
        r = jax.lax.ppermute(v, "x",
                             [(i, (i + 1) % 8) for i in range(8)])
        return s + g.sum(axis=0, keepdims=True) + t + r

    fn = jax.jit(shard_map(local, mesh=mesh, in_specs=P("x", None),
                           out_specs=P("x", None)))
    sds = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    return fn.lower(sds)


def main():
    lowered = build()
    with open(os.path.join(_HERE, "probe.stablehlo.txt"), "w") as fh:
        fh.write(lowered.as_text())
    with open(os.path.join(_HERE, "probe.compiled.txt"), "w") as fh:
        fh.write(lowered.compile().as_text())
    print("wrote probe.stablehlo.txt / probe.compiled.txt")


if __name__ == "__main__":
    main()
