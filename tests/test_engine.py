"""The plan → compile → run session API (repro.core.engine) and the
decomposition registry (repro.core.decomp): parity with the one-shot
``run_bfs`` across the full combo matrix, compile-once/ship-once
guarantees, plan-validation error paths, and pod-batched multi-source
runs in both decompositions."""
import jax
import numpy as np
import pytest

from repro.configs.base import BFSConfig
from repro.core import decomp, local_ops
from repro.core.bfs import run_bfs
from repro.core.engine import BFSEngine, plan_bfs, plan_for_part
from repro.core.partition import make_partition, make_partition_1d
from repro.core.ref import bfs_depths, depths_from_parents, validate_parents
from repro.graph.formats import build_blocked, build_blocked_1d
from repro.graph.rmat import rmat_graph
from repro.launch.mesh import make_local_mesh, make_local_mesh_1d


@pytest.fixture(scope="module")
def fixed_graph():
    e = rmat_graph(8, edge_factor=8, seed=4)
    # with_col_ptr: the matrix includes the 1d/kernel/csr cell
    return (e, build_blocked_1d(e, 1, align=32, cap_pad=32,
                                with_col_ptr=True),
            build_blocked(e, 1, 1, align=32, cap_pad=32))


def _mesh_for(d, **kw):
    return make_local_mesh(1, 1, **kw) if d == "2d" \
        else make_local_mesh_1d(1, **kw)


def _graph_for(d, g1, g2):
    return g2 if d == "2d" else g1      # 1d and 1ds share the strip format


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_decomp_registry():
    assert decomp.registered_decompositions() == ("1d", "1ds", "2d")
    with pytest.raises(ValueError, match="no decomposition registered"):
        decomp.get_decomposition("1.5d")
    for name in decomp.registered_decompositions():
        entry = decomp.get_decomposition(name)
        assert entry.n_axes == len(entry.axis_sizes(
            make_partition(64, 1, 1, align=32) if name == "2d"
            else make_partition_1d(64, 1, align=32)))


def test_unknown_decomposition_rejected_at_plan(fixed_graph):
    e, g1, g2 = fixed_graph
    with pytest.raises(ValueError, match="no decomposition registered"):
        plan_bfs(g2, BFSConfig(decomposition="3d"), make_local_mesh(1, 1))


# ---------------------------------------------------------------------------
# Parity vs run_bfs across the full combo matrix
# ---------------------------------------------------------------------------


def test_engine_parity_matrix(fixed_graph):
    """engine.run must return bit-identical parents AND counters to the
    one-shot run_bfs in every (decomposition, local_mode, storage)
    combo — the engine only changes WHEN compilation happens."""
    e, g1, g2 = fixed_graph
    root = int(np.flatnonzero(e.out_degrees())[0])
    for dc, lm, st_ in local_ops.registered_combos():
        g = _graph_for(dc, g1, g2)
        mesh = _mesh_for(dc)
        cfg = BFSConfig(decomposition=dc, storage=st_)
        ref = run_bfs(g, root, cfg, mesh, local_mode=lm)
        eng = plan_bfs(g, cfg, mesh, local_mode=lm).compile()
        res = eng.run(root)
        assert np.array_equal(res.parents, ref.parents), (dc, lm, st_)
        assert res.n_levels == ref.n_levels, (dc, lm, st_)
        assert res.counters == ref.counters, (dc, lm, st_)
        assert np.array_equal(res.level_stats, ref.level_stats), (dc, lm, st_)


def test_instrument_off_parity_matrix(fixed_graph):
    """The instrument=False fast path (one fused scalar reduction per
    level, counters/level_stats compiled out) must return bit-identical
    parents and level counts to the instrumented program in every
    (decomposition, local_mode, storage) combo; an uninstrumented run
    carries NO counters (not zeros that read as measurements) and
    all-zero stats."""
    e, g1, g2 = fixed_graph
    root = int(np.flatnonzero(e.out_degrees())[0])
    for dc, lm, st_ in local_ops.registered_combos():
        g = _graph_for(dc, g1, g2)
        mesh = _mesh_for(dc)
        ref = plan_bfs(g, BFSConfig(decomposition=dc, storage=st_), mesh,
                       local_mode=lm).compile().run(root)
        eng = plan_bfs(g, BFSConfig(decomposition=dc, storage=st_,
                                    instrument=False),
                       mesh, local_mode=lm).compile()
        assert eng.instrument is False
        res = eng.run(root)
        assert np.array_equal(res.parents, ref.parents), (dc, lm, st_)
        assert res.n_levels == ref.n_levels, (dc, lm, st_)
        assert res.counters == {}, (dc, lm, st_)
        assert not res.level_stats.any(), (dc, lm, st_)


def test_instrument_off_direction_switching(fixed_graph):
    """The fast path reads the direction heuristics off the previous
    level's fused reduction — the mode sequence must still match the
    instrumented program's level_stats (asserted via identical depths
    AND identical level counts on a graph that actually switches)."""
    e, g1, g2 = fixed_graph
    root = int(np.flatnonzero(e.out_degrees())[0])
    for diro in (False, True):
        cfg_i = BFSConfig(direction_optimizing=diro)
        cfg_f = BFSConfig(direction_optimizing=diro, instrument=False)
        ri = plan_bfs(g2, cfg_i, make_local_mesh(1, 1)).compile().run(root)
        rf = plan_bfs(g2, cfg_f, make_local_mesh(1, 1)).compile().run(root)
        assert np.array_equal(ri.parents, rf.parents), diro
        assert ri.n_levels == rf.n_levels, diro
    # with diropt the instrumented run really used bottom-up somewhere
    modes = ri.level_stats[: ri.n_levels, 2]
    assert modes.max() == 1.0


@pytest.mark.parametrize("ec", [2, 4])
def test_pipelined_expand_parity_matrix(fixed_graph, ec):
    """expand_chunks > 1 (the software-pipelined expand) must return
    bit-identical parents to the unpipelined program in every
    decomposition x local_mode x storage combo (plus the raw-id "1ds"
    codec), instrumented AND fast — chunking reorders the gather, never
    the (select-source, min) semiring result.  Instrumented runs must
    also keep the identical per-level mode sequence."""
    e, g1, g2 = fixed_graph
    root = int(np.flatnonzero(e.out_degrees())[0])
    cases = [(dc, lm, st_, None) for dc, lm, st_
             in local_ops.registered_combos()]
    cases += [("1ds", "dense", "csr", "none")]
    for dc, lm, st_, codec in cases:
        g = _graph_for(dc, g1, g2)
        mesh = _mesh_for(dc)
        kw = {} if codec is None else {"frontier_codec": codec}
        ref = plan_bfs(g, BFSConfig(decomposition=dc, storage=st_, **kw),
                       mesh, local_mode=lm).compile().run(root)
        res = plan_bfs(g, BFSConfig(decomposition=dc, storage=st_,
                                    expand_chunks=ec, **kw),
                       mesh, local_mode=lm).compile().run(root)
        key = (dc, lm, st_, codec, ec)
        assert np.array_equal(res.parents, ref.parents), key
        assert res.n_levels == ref.n_levels, key
        # identical direction decisions: stats cols (n_f, m_f, mode,
        # used); wire_expand (col 4) may legitimately differ for "1ds"
        # (per-sub-range overflow can flip a level to the dense
        # fallback) and the 2d ring pays its extra G-chain permutes
        assert np.array_equal(res.level_stats[:, :4],
                              ref.level_stats[:, :4]), key
        if dc != "1ds":
            assert np.array_equal(res.level_stats, ref.level_stats), key
        resf = plan_bfs(g, BFSConfig(decomposition=dc, storage=st_,
                                     expand_chunks=ec, instrument=False,
                                     **kw),
                        mesh, local_mode=lm).compile().run(root)
        assert np.array_equal(resf.parents, ref.parents), key
        assert resf.n_levels == ref.n_levels, key
        assert resf.counters == {}, key


# ---------------------------------------------------------------------------
# Compile-once / ship-once
# ---------------------------------------------------------------------------


def test_run_many_compiles_once_ships_once(fixed_graph, monkeypatch):
    """The acceptance bar: over >=4 roots, exactly one jit trace and one
    graph shipment (one device_put per shipped key, all during
    compile(), none during run)."""
    e, g1, g2 = fixed_graph
    roots = np.flatnonzero(e.out_degrees() > 0)[:4]
    assert len(roots) >= 4
    puts = []
    real_put = jax.device_put
    monkeypatch.setattr(jax, "device_put",
                        lambda *a, **kw: puts.append(1) or real_put(*a, **kw))
    plan = plan_bfs(g2, BFSConfig(), make_local_mesh(1, 1))
    eng = plan.compile()
    assert len(puts) == len(plan.keys)          # graph shipped exactly once
    assert eng.trace_count == 1                 # one jit trace at compile()
    ref = [run_bfs(g2, int(r), BFSConfig(), make_local_mesh(1, 1))
           for r in roots]
    n_puts_after_compile = len(puts)
    results = eng.run_many(roots)
    assert len(puts) == n_puts_after_compile    # no re-shipping per root
    assert eng.trace_count == 1                 # no re-tracing per root
    for got, want, r in zip(results, ref, roots):
        assert np.array_equal(got.parents, want.parents), int(r)
        assert got.counters == want.counters, int(r)
        assert got.n_levels == want.n_levels, int(r)


# ---------------------------------------------------------------------------
# Plan-validation error paths
# ---------------------------------------------------------------------------


def test_plan_rejects_mismatched_graph(fixed_graph):
    e, g1, g2 = fixed_graph
    with pytest.raises(TypeError, match="does not match"):
        plan_bfs(g2, BFSConfig(decomposition="1d"), make_local_mesh_1d(1))
    with pytest.raises(TypeError, match="does not match"):
        plan_bfs(g1, BFSConfig(), make_local_mesh(1, 1))


def test_plan_rejects_mismatched_partition():
    part1 = make_partition_1d(256, 1, align=32)
    with pytest.raises(TypeError, match="needs a Partition2D"):
        plan_for_part(part1, BFSConfig(), make_local_mesh(1, 1), cap_seg=32)


def test_plan_rejects_mesh_geometry_mismatch():
    e = rmat_graph(8, edge_factor=8, seed=1)
    g = build_blocked_1d(e, 2, align=32, cap_pad=32)   # 2 strips...
    with pytest.raises(ValueError, match="mesh axis"):
        plan_bfs(g, BFSConfig(decomposition="1d"),
                 make_local_mesh_1d(1))                # ...1-device mesh
    part = make_partition(256, 1, 1, align=32)
    with pytest.raises(ValueError, match="mesh has no"):
        plan_for_part(part, BFSConfig(), make_local_mesh(1, 1),
                      cap_seg=32, row_axis="nope")


def test_plan_rejects_missing_cap_seg():
    part = make_partition(256, 1, 1, align=32)
    with pytest.raises(ValueError, match="cap_seg"):
        plan_for_part(part, BFSConfig(), make_local_mesh(1, 1))


def test_plan_rejects_missing_kernel_arrays():
    e = rmat_graph(8, edge_factor=8, seed=1)
    g = build_blocked_1d(e, 1, align=32, cap_pad=32)   # no col_ptr
    with pytest.raises(ValueError, match="lacks arrays"):
        plan_bfs(g, BFSConfig(decomposition="1d", storage="csr"),
                 make_local_mesh_1d(1), local_mode="kernel")


def test_engine_requires_concrete_graph():
    part = make_partition(256, 1, 1, align=32)
    plan = plan_for_part(part, BFSConfig(), make_local_mesh(1, 1), cap_seg=32)
    with pytest.raises(ValueError, match="no graph attached"):
        BFSEngine(plan)


def test_plan_rejects_missing_cap_x():
    """Graph-less "1ds" plans must pass cap_x explicitly (plan_bfs
    derives it from the graph degree stats)."""
    part = make_partition_1d(256, 1, align=32)
    with pytest.raises(ValueError, match="cap_x"):
        plan_for_part(part, BFSConfig(decomposition="1ds"),
                      make_local_mesh_1d(1))
    with pytest.raises(ValueError, match="exceeds the owned chunk"):
        plan_for_part(part, BFSConfig(decomposition="1ds"),
                      make_local_mesh_1d(1), cap_x=part.chunk + 32)
    plan_for_part(part, BFSConfig(decomposition="1ds"),
                  make_local_mesh_1d(1), cap_x=32)   # explicit cap is fine


def test_plan_rejects_bad_expand_chunks():
    """The software-pipelined expand needs expand_chunks >= 1, dividing
    the strip's packed word count (1d/1ds) and cap_x (1ds) — a ragged
    sub-chunk would silently mis-align the owner-major gather layout,
    so the plan must fail loudly instead."""
    part = make_partition_1d(256, 1, align=32)     # chunk=256 -> 8 words
    with pytest.raises(ValueError, match="expand_chunks"):
        plan_for_part(part, BFSConfig(decomposition="1d",
                                      expand_chunks=0),
                      make_local_mesh_1d(1))
    with pytest.raises(ValueError, match="does not divide the per-device"):
        plan_for_part(part, BFSConfig(decomposition="1d",
                                      expand_chunks=3),
                      make_local_mesh_1d(1))
    with pytest.raises(ValueError, match="does not divide the per-device"):
        plan_for_part(part, BFSConfig(decomposition="1ds",
                                      expand_chunks=16),
                      make_local_mesh_1d(1), cap_x=32)
    with pytest.raises(ValueError, match="does not divide cap_x"):
        plan_for_part(part, BFSConfig(decomposition="1ds",
                                      expand_chunks=4),
                      make_local_mesh_1d(1), cap_x=34)
    # divisors of both are fine, in every decomposition
    for dc, kw in (("1d", {}), ("1ds", dict(cap_x=32))):
        plan_for_part(part, BFSConfig(decomposition=dc, expand_chunks=4),
                      make_local_mesh_1d(1), **kw)
    part2 = make_partition(256, 1, 1, align=32)
    plan_for_part(part2, BFSConfig(expand_chunks=2), make_local_mesh(1, 1),
                  cap_seg=32)                      # 2d: any >= 1


# ---------------------------------------------------------------------------
# Root validation at the engine boundary
# ---------------------------------------------------------------------------


def test_engine_rejects_out_of_range_roots():
    """Graphs are padded up to p*chunk: a root in the ghost range (or
    negative) used to silently traverse nothing and return an all-empty
    parents array.  run/run_many/run_batch must all reject it."""
    from repro.graph.rmat import preprocess
    rng = np.random.default_rng(0)
    n = 300                              # NOT a multiple of the quantum
    e = preprocess(rng.integers(0, n, 600), rng.integers(0, n, 600), n,
                   symmetrize=True)
    g1 = build_blocked_1d(e, 1, align=32, cap_pad=32)
    g2 = build_blocked(e, 1, 1, align=32, cap_pad=32)
    for dc in ("2d", "1d", "1ds"):
        g = _graph_for(dc, g1, g2)
        eng = plan_bfs(g, BFSConfig(decomposition=dc),
                       _mesh_for(dc, pods=1)).compile()
        n_orig, n_pad = g.part.n_orig, g.part.n
        assert n_pad > n_orig            # the ghost range exists
        for bad in (-1, n_orig, n_pad - 1, n_pad):
            with pytest.raises(ValueError, match="out of range"):
                eng.run(bad)
        with pytest.raises(ValueError, match="out of range"):
            eng.run_many([0, n_orig])
        with pytest.raises(ValueError, match="out of range"):
            eng.run_batch([0, n_orig])
        # in-range roots still work after the rejects
        assert eng.run(0).parents.shape == (n_orig,)


# ---------------------------------------------------------------------------
# Pod-batched multi-source runs (both decompositions)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dc", ["1d", "1ds", "2d"])
def test_run_batch_valid_multisource(fixed_graph, dc):
    """run_batch must produce valid trees with oracle depths from every
    root, in the 1D decompositions as well as 2D (the pod axis batches
    whole searches; pods=1 exercises the full program shape)."""
    e, g1, g2 = fixed_graph
    g = _graph_for(dc, g1, g2)
    roots = np.flatnonzero(e.out_degrees() > 0)[:4]
    eng = plan_bfs(g, BFSConfig(decomposition=dc),
                   _mesh_for(dc, pods=1)).compile()
    batch = eng.run_batch(roots)
    assert batch.parents.shape == (len(roots), e.n)
    assert batch.level_stats.shape == (len(roots), decomp.MAX_LEVELS, 5)
    for i, r in enumerate(roots):
        ok, msg = validate_parents(e.n, e.src, e.dst, int(r),
                                   batch.parents[i])
        assert ok, (dc, int(r), msg)
        d = bfs_depths(e.n, e.src, e.dst, int(r))
        assert np.array_equal(
            depths_from_parents(e.n, batch.parents[i], int(r)), d), (dc, r)
        assert batch.n_levels[i] >= d[d >= 0].max()
    # batched program compiled once, cached for repeat calls
    n_traces = eng.trace_count
    eng.run_batch(roots)
    assert eng.trace_count == n_traces


def test_run_batch_errors(fixed_graph):
    e, g1, g2 = fixed_graph
    eng = plan_bfs(g2, BFSConfig(), make_local_mesh(1, 1)).compile()
    with pytest.raises(ValueError, match="no 'pod' axis"):
        eng.run_batch([0, 1])
    eng_p = plan_bfs(g2, BFSConfig(), make_local_mesh(1, 1, pods=1)).compile()
    with pytest.raises(ValueError, match="do not split evenly"):
        eng_p.run_batch([])


# ---------------------------------------------------------------------------
# Compat wrappers still honour the registry
# ---------------------------------------------------------------------------


def test_make_bfs_fn_1d_overrides_decomposition():
    """make_bfs_fn_1d must build the 1D program even when handed a cfg
    whose decomposition field still says 2d (pre-engine behavior)."""
    from repro.core.bfs import make_bfs_fn_1d
    part = make_partition_1d(256, 1, align=32)
    _, keys = make_bfs_fn_1d(make_local_mesh_1d(1), part,
                             BFSConfig(decomposition="2d"))
    assert "seg_ptr" not in keys          # 1D key set, not 2D


def test_compat_builders_accept_cap_x():
    """The legacy builders must be able to build "1ds" programs — cap_x
    has no graph to be planned from there, so they pass it through."""
    import jax
    from repro.core.bfs import make_bfs_fn, make_multiroot_bfs_fn
    part = make_partition_1d(256, 1, align=32)
    _, keys = make_bfs_fn(make_local_mesh_1d(1), part,
                          BFSConfig(decomposition="1ds"), cap_x=32)
    assert "edge_src" in keys
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("pod", "data"))
    _, keys = make_multiroot_bfs_fn(mesh, part,
                                    BFSConfig(decomposition="1ds"),
                                    cap_seg=0, n_roots=1, cap_x=32)
    assert "edge_src" in keys


def test_cfg_decomposition_read_directly(fixed_graph):
    """BFSConfig declares the field; a cfg object lacking it is a bug,
    not something the engine papers over with getattr defaults."""
    e, g1, g2 = fixed_graph

    class NotACfg:
        storage = "csr"
    with pytest.raises(AttributeError):
        plan_bfs(g2, NotACfg(), make_local_mesh(1, 1))
