"""Partition + blocked-format invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partition import make_partition
from repro.graph.formats import build_blocked
from repro.graph.rmat import rmat_graph


@given(st.integers(1, 5000), st.integers(1, 6), st.integers(1, 6))
@settings(max_examples=50, deadline=None)
def test_partition_layout_bijections(n, pr, pc):
    part = make_partition(n, pr, pc, align=32)
    assert part.n % (part.p * 32) == 0
    assert part.nr == part.chunk * pc and part.nc == part.chunk * pr
    v = np.arange(part.n)
    # layout A: chunk k = i*pc + j
    i, j, off = part.owner_A(v)
    assert np.array_equal((i * pc + j) * part.chunk + off, v)
    # layout B: chunk k = j*pr + i; gathered along i must tile C_j
    i, j, off = part.owner_B(v)
    assert np.array_equal((j * pr + i) * part.chunk + off, v)
    # transpose perm is a bijection on devices
    perm = part.transpose_perm()
    assert sorted(d for _, d in perm) == list(range(part.p))


@pytest.mark.parametrize("pr,pc", [(1, 1), (2, 2), (1, 4), (4, 1), (2, 3)])
def test_blocked_graph_roundtrip(pr, pc):
    e = rmat_graph(9, edge_factor=8, seed=4)
    g = build_blocked(e, pr, pc, align=32, cap_pad=32)
    part = g.part
    # every edge appears in exactly one block, in both orientations
    got = set()
    for i in range(pr):
        for j in range(pc):
            nnz = int(g.nnz[i, j])
            cp, ri, es = g.col_ptr[i, j], g.row_idx[i, j], g.edge_src[i, j]
            assert cp[-1] == nnz
            for k in range(nnz):
                u = int(es[k]) + j * part.nc
                v = int(ri[k]) + i * part.nr
                got.add((u, v))
            # CSC pointer consistency: edges of column u live in its segment
            deg = np.diff(cp)
            assert deg.sum() == nnz
            # CSR orientation covers the same edges
            rp, ci = g.row_ptr[i, j], g.col_idx[i, j]
            assert rp[-1] == nnz
            # DCSC compression: jc lists exactly the non-empty columns
            jc = g.jc[i, j][: int(g.nzc[i, j])]
            assert np.array_equal(jc, np.flatnonzero(deg))
    want = set(zip(e.src.tolist(), e.dst.tolist()))
    assert got == want
    # accounting identity: DCSC pointers = 2*(nzc+nzr) + 2p
    assert (g.storage_words("dcsc")["pointer_i32"]
            == 2 * int(g.nzc.sum() + g.nzr.sum()) + 2 * g.part.p)


def test_dcsc_wins_in_hypersparse_regime():
    """The paper's §5.1 asymptotics: CSR pointer storage is O(n*(pr+pc)),
    DCSC is O(m) — on a big grid with a sparse graph DCSC must win."""
    e = rmat_graph(11, edge_factor=2, seed=4)
    g = build_blocked(e, 8, 8, align=32, cap_pad=32)
    csr = g.storage_words("csr")["pointer_i32"]
    dcsc = g.storage_words("dcsc")["pointer_i32"]
    assert dcsc < csr, (dcsc, csr)
    # and the gap widens with the grid (weak form: 16x16 ratio > 8x8 ratio)
    g2 = build_blocked(e, 16, 16, align=32, cap_pad=32)
    r2 = (g2.storage_words("csr")["pointer_i32"]
          / g2.storage_words("dcsc")["pointer_i32"])
    assert r2 > csr / dcsc


def test_seg_ptr_windows():
    e = rmat_graph(9, edge_factor=8, seed=4)
    g = build_blocked(e, 2, 2, align=32, cap_pad=32)
    part = g.part
    for i in range(2):
        for j in range(2):
            sp, rp = g.seg_ptr[i, j], g.row_ptr[i, j]
            for s in range(part.pc + 1):
                assert sp[s] == rp[s * part.chunk]
            assert (np.diff(sp) <= g.cap_seg).all()
