"""Subprocess: multi-device NN-substrate checks — EP MoE vs reference,
sharded embedding lookup vs take, DP compressed training convergence,
elastic graph repartition."""
import os
import sys

n_dev = int(sys.argv[1])
mode = sys.argv[2]
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402


def main():
    if mode == "moe_ep":
        # explicit-EP MoE (all_to_all dispatch) ~= dense reference.
        # capacity drops are the only allowed divergence; with uniform
        # router logits and generous capacity_mult there are none.
        from repro.configs.base import LMConfig, MoEConfig
        from repro.models import transformer as tf
        from repro.models.common import ShardCtx
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = LMConfig(arch="t", family="moe", n_layers=1, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=16, vocab=64,
                       moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16,
                                     capacity_factor=8.0))
        key = jax.random.PRNGKey(0)
        rw = jax.random.normal(key, (32, 8)) * 0.1
        wg = jax.random.normal(key, (8, 32, 16)) * 0.2
        wu = jax.random.normal(key, (8, 32, 16)) * 0.2
        wd = jax.random.normal(key, (8, 16, 32)) * 0.2
        x = jax.random.normal(key, (64, 32))
        want = tf._moe_reference(x, rw, wg, wu, wd, cfg)
        ctx = ShardCtx(mesh=mesh)
        xs = jax.device_put(x, NamedSharding(mesh, P(("data", "model"),
                                                     None)))
        got = tf.moe_ep_shardmap(xs, rw, wg, wu, wd, cfg, ctx,
                                 capacity_mult=4.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)
        # E < tp sub-group path (tp_sub = 4/... ): 2 experts on 4 devices
        cfg2 = LMConfig(arch="t2", family="moe", n_layers=1, d_model=32,
                        n_heads=2, n_kv_heads=2, d_ff=16, vocab=64,
                        moe=MoEConfig(n_experts=2, top_k=1, d_ff_expert=16,
                                      capacity_factor=8.0))
        want2 = tf._moe_reference(x, rw[:, :2], wg[:2], wu[:2], wd[:2], cfg2)
        got2 = tf.moe_ep_shardmap(xs, rw[:, :2], wg[:2], wu[:2], wd[:2],
                                  cfg2, ctx, capacity_mult=4.0)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(want2),
                                   rtol=2e-2, atol=2e-2)
        print("OK moe_ep")
    elif mode == "embedding":
        from repro.configs.base import RecsysConfig
        from repro.models import embedding
        from repro.models.common import ShardCtx
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = RecsysConfig(arch="t", n_sparse=4, embed_dim=8,
                           n_attn_layers=1, n_heads=1, d_attn=8,
                           vocab_sizes=(100, 200, 300, 424))
        key = jax.random.PRNGKey(1)
        table = embedding.init_table(cfg, key)
        rng = np.random.default_rng(0)
        idx = jnp.asarray(rng.integers(0, 100, (16, 4)).astype(np.int32))
        rows = embedding.flat_indices(cfg, idx)
        want = jnp.take(table, rows, axis=0)
        ctx = ShardCtx(mesh=mesh)
        ts = jax.device_put(table, NamedSharding(mesh, P("model", None)))
        got = embedding.lookup(ts, rows, ctx)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        print("OK embedding")
    elif mode == "dp_compress":
        from repro.optim.adamw import SGDM
        from repro.optim.dp_step import init_dp_state, make_dp_compressed_step
        mesh = jax.make_mesh((n_dev,), ("data",))
        rng = np.random.default_rng(0)
        W = (rng.normal(size=(16, 1)) * 0.3).astype(np.float32)
        params = {"w": jnp.zeros((16, 1))}
        opt = SGDM(lr=0.02, momentum=0.8)

        def loss_fn(p, b):
            return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

        l0 = None
        results = {}
        for m in ("none", "topk", "int8"):
            step = make_dp_compressed_step(loss_fn, opt, mesh, "data",
                                           mode=m, ratio=0.25)
            state = init_dp_state(params, opt)
            sh = NamedSharding(mesh, P("data"))
            for i in range(100):
                x = rng.normal(size=(n_dev * 8, 16)).astype(np.float32)
                b = {"x": jax.device_put(jnp.asarray(x), sh),
                     "y": jax.device_put(jnp.asarray(x @ W), sh)}
                state, metrics = step(state, b)
                if i == 0 and l0 is None:
                    l0 = float(metrics["loss"])
            results[m] = float(metrics["loss"])
        assert results["none"] < 0.05 * l0, results
        assert results["int8"] < 0.05 * l0, results
        assert results["topk"] < 0.5 * l0, results  # EF converges, slower
        print("OK dp_compress")
    elif mode == "elastic_graph":
        from repro.ckpt.elastic import repartition_graph
        from repro.configs.base import BFSConfig
        from repro.core.bfs import run_bfs
        from repro.core.ref import validate_parents
        from repro.graph.rmat import rmat_graph
        from repro.launch.mesh import make_local_mesh
        edges = rmat_graph(10, edge_factor=8, seed=4)
        # run at 4x4; "lose a pod": re-block for 2x2 and rerun
        for pr, pc in ((4, 4), (2, 2)):
            g = repartition_graph(edges, pr, pc, align=32, cap_pad=32)
            res = run_bfs(g, 3, BFSConfig(), make_local_mesh(pr, pc))
            ok, msg = validate_parents(edges.n, edges.src, edges.dst, 3,
                                       res.parents)
            assert ok, (pr, pc, msg)
        print("OK elastic_graph")
    else:
        raise SystemExit(f"unknown mode {mode}")


if __name__ == "__main__":
    main()
