"""End-to-end LM training driver: any --arch, fault-tolerant loop with
checkpoint/resume, synthetic token stream.

Reduced config by default (CPU container); pass --full for the real arch.

    PYTHONPATH=src python examples/train_lm.py --arch smollm-135m \
        --steps 200 --d-model 128 --layers 4
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.data.pipeline import lm_batch
from repro.models import transformer as tf
from repro.models.common import ShardCtx
from repro.optim.adamw import AdamW
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        kw = dict(n_layers=args.layers, d_model=args.d_model,
                  d_ff=args.d_model * 4, vocab=2048, d_head=32,
                  n_heads=4, n_kv_heads=2)
        if cfg.moe is not None:
            kw["moe"] = dataclasses.replace(cfg.moe, n_experts=4, top_k=2,
                                            d_ff_expert=args.d_model)
        cfg = reduced(cfg, **kw)
    ctx = ShardCtx(mesh=None)
    opt = AdamW(lr=1e-3, total_steps=max(args.steps, 100),
                warmup_steps=min(5, args.steps), schedule="constant")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    state = (params, opt.init(params))

    @jax.jit
    def step_fn(state, batch):
        p, ost = state
        loss, g = jax.value_and_grad(
            lambda p_: tf.lm_loss(p_, batch["tokens"], batch["labels"],
                                  cfg, ctx, seq_chunk=min(args.seq, 512)))(p)
        p, ost = opt.update(g, ost, p)
        return (p, ost), {"loss": loss}

    mon = StragglerMonitor()
    trainer = Trainer(
        step_fn=step_fn,
        make_batch=lambda s: {k: jnp.asarray(v) for k, v in
                              lm_batch(cfg, args.batch, args.seq, s).items()},
        ckpt_dir=args.ckpt_dir, ckpt_every=10,
        meta={"arch": cfg.arch}, straggler=mon)
    state, log = trainer.run(state, args.steps)
    losses = [m["loss"] for m in log]
    print(f"trained {len(log)} steps; loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}; stragglers detected: {len(mon.events)}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
