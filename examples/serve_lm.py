"""Batched LM serving: dynamic batching + prefill/decode (KV cache).

    PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import transformer as tf
from repro.models.common import ShardCtx
from repro.runtime.server import Request, Server


def main():
    cfg = reduced(get_config("smollm-135m"), n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, d_head=16)
    ctx = ShardCtx(mesh=None)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    max_b, max_len = 4, 128

    @jax.jit
    def prefill_fn(tokens):
        cache = tf.init_kv_cache(cfg, max_b, max_len)
        return tf.prefill(params, tokens, cache, cfg, ctx)

    @jax.jit
    def decode_fn(cache, tok, pos):
        return tf.decode_step(params, cache, tok, pos, cfg, ctx)

    server = Server(prefill_fn, decode_fn, max_batch=max_b, bucket=32)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, rng.integers(4, 20))
                    .astype(np.int32), max_new_tokens=6) for _ in range(6)]
    done = server.serve(reqs)
    for i, r in enumerate(done):
        print(f"req{i}: prompt_len={len(r.prompt)} -> out={r.out.tolist()}")
    assert all(r.out is not None and len(r.out) == 6 for r in done)
    print("served", len(done), "requests (batched prefill+decode)")


if __name__ == "__main__":
    main()
