"""End-to-end Graph500-style driver (the paper's §7 methodology):
generate R-MAT, build the distributed graph + compile the search ONCE
(plan → compile → run, repro.core.engine), run BFS from 16 random
roots, report the harmonic-mean TEPS over pure per-root traversal time
(compile/ship reported separately), validate every tree, compare comm
volume to the §6 model.

    PYTHONPATH=src python examples/graph500_bfs.py --scale 13 --grid 2x2

Multi-device grids need forced host devices, e.g.:
    XLA_FLAGS=--xla_force_host_platform_device_count=16 \
        PYTHONPATH=src python examples/graph500_bfs.py --grid 4x4

``--decomposition 1d`` runs the paper's 1D row-strip baseline on
p = pr*pc strips of the same graph (the Eq. 2 comparison axis);
``--decomposition 1ds`` runs the sparse-exchange variant (capped
frontier-id buckets broadcast per level, dense-bitmap fallback on
overflow — Buluc & Madduri's formulation):
    ... examples/graph500_bfs.py --grid 4x4 --decomposition 1ds

``--local-mode kernel --storage dcsc`` selects the Pallas local-
discovery path with compressed pointers in either decomposition (1D =
the strip-DCSC kernel; the §5.1 CSR/DCSC axis of Fig. 6):
    ... --decomposition 1d --local-mode kernel --storage dcsc
"""
import argparse
import time

import numpy as np

from repro.configs.base import BFSConfig
from repro.core import comm_model
from repro.core.engine import plan_bfs
from repro.core.metrics import harmonic_mean, teps
from repro.core.ref import validate_parents
from repro.graph.formats import build_blocked, build_blocked_1d
from repro.graph.rmat import random_source, rmat_graph
from repro.launch.mesh import make_local_mesh, make_local_mesh_1d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--grid", default="1x1")
    ap.add_argument("--roots", type=int, default=16)
    ap.add_argument("--no-diropt", action="store_true")
    ap.add_argument("--decomposition", choices=("1d", "1ds", "2d"),
                    default="2d")
    ap.add_argument("--local-mode", choices=("dense", "kernel"),
                    default="dense")
    ap.add_argument("--storage", choices=("csr", "dcsc"), default="csr")
    ap.add_argument("--fast", action="store_true",
                    help="instrument=False: compile out counters/stats "
                         "for the latency-lean level pipeline (TEPS "
                         "runs; the comm-volume report is skipped)")
    args = ap.parse_args()
    pr, pc = map(int, args.grid.split("x"))

    edges = rmat_graph(args.scale, 16, seed=1)
    if args.decomposition in ("1d", "1ds"):
        graph = build_blocked_1d(
            edges, pr * pc, align=32,
            with_col_ptr=(args.local_mode == "kernel"
                          and args.storage == "csr"))
        mesh = make_local_mesh_1d(pr * pc)
    else:
        graph = build_blocked(edges, pr, pc, align=32)
        mesh = make_local_mesh(pr, pc)
    cfg = BFSConfig(decomposition=args.decomposition, storage=args.storage,
                    direction_optimizing=not args.no_diropt,
                    instrument=not args.fast)
    rng = np.random.default_rng(0)

    # plan + compile once; every root below is pure traversal (the §7
    # methodology: harmonic-mean TEPS must not be smeared by compilation)
    engine = plan_bfs(graph, cfg, mesh, local_mode=args.local_mode).compile()
    engine.search(0)[0].block_until_ready()    # untimed first-dispatch warmup
    print(f"compile: {engine.compile_s:.3f}s, graph ship: "
          f"{engine.ship_s:.3f}s (paid once, reused for {args.roots} roots)")

    rates, res = [], None
    for i in range(args.roots):
        root = random_source(edges, rng)
        # time the device search only; host-side result conversion and
        # validation stay outside the timed region (worker.py methodology)
        t0 = time.perf_counter()
        out = engine.search(root)
        out[0].block_until_ready()
        dt = time.perf_counter() - t0
        res = engine.to_result(out)
        ok, msg = validate_parents(edges.n, edges.src, edges.dst, root,
                                   res.parents)
        assert ok, msg
        rates.append(teps(edges.m_input, dt))
        print(f"root {root:>8}: {res.n_levels} levels, {dt*1e3:8.2f} ms, "
              f"{rates[-1]:.3e} TEPS, valid")
    print(f"\nharmonic-mean TEPS over {args.roots} roots "
          f"(traversal only): {harmonic_mean(rates):.3e}")
    if args.fast:
        # counters are compiled out of the fast program — there is no
        # comm-volume accounting to report (run without --fast for it)
        return
    useful = sum(v for k, v in res.counters.items() if k.startswith('use_'))
    if args.decomposition in ("1d", "1ds"):
        wt = comm_model.topdown_1d_words(edges.m, pr * pc)
        we = comm_model.expand_1d_words(graph.part.n, pr * pc, res.n_levels)
        # "1d" must reproduce the dense closed form exactly; "1ds" ships
        # sparse ids, so the dense volume is its per-search upper bound
        rel = "vs model" if args.decomposition == "1d" \
            else "vs dense-bitmap bound"
        print(f"useful words (last search): {useful:.3e}  "
              f"({args.decomposition} top-down model w={wt:.3e}; "
              f"wire_expand measured {res.counters['wire_expand']:.3e} "
              f"{rel} {we:.3e})")
    else:
        wt = comm_model.topdown_words(graph.part.n, edges.m, pr, pc)
        print(f"useful words (last search): {useful:.3e}  "
              f"(pure top-down model w_t={wt:.3e})")


if __name__ == "__main__":
    main()
