"""End-to-end Graph500-style driver (the paper's §7 methodology):
generate R-MAT, build the distributed graph + compile the search ONCE
(plan → compile → run, repro.core.engine), run BFS from 16 random
roots, report the harmonic-mean TEPS over pure per-root traversal time
(compile/ship reported separately), validate every tree, compare comm
volume to the §6 model.

    PYTHONPATH=src python examples/graph500_bfs.py --scale 13 --grid 2x2

Multi-device grids need forced host devices, e.g.:
    XLA_FLAGS=--xla_force_host_platform_device_count=16 \
        PYTHONPATH=src python examples/graph500_bfs.py --grid 4x4

``--decomposition 1d`` runs the paper's 1D row-strip baseline on
p = pr*pc strips of the same graph (the Eq. 2 comparison axis);
``--decomposition 1ds`` runs the sparse-exchange variant (capped
frontier-id buckets broadcast per level, dense-bitmap fallback on
overflow — Buluc & Madduri's formulation):
    ... examples/graph500_bfs.py --grid 4x4 --decomposition 1ds

``--local-mode kernel --storage dcsc`` selects the Pallas local-
discovery path with compressed pointers in either decomposition (1D =
the strip-DCSC kernel; the §5.1 CSR/DCSC axis of Fig. 6):
    ... --decomposition 1d --local-mode kernel --storage dcsc

``--born`` generates + formats the graph ON DEVICE (graph/dist_build:
per-shard counter R-MAT stream, owner-routed all_to_all, shard-local
dedup) — the host never materializes the edge list, so scales beyond
host memory fit; tree validation needs the host edge list and is
skipped.  ``--store DIR`` persists graph + compiled executable to a
GraphStore (and reloads both on the next identical run — disk to first
traversal in seconds):
    ... --grid 16x1 --decomposition 1d --born --store /tmp/gstore --fast
"""
import argparse
import time

import numpy as np

from repro.configs.base import BFSConfig
from repro.core import comm_model
from repro.core.engine import plan_bfs
from repro.core.metrics import harmonic_mean, teps
from repro.core.ref import validate_parents
from repro.graph.formats import build_blocked, build_blocked_1d
from repro.graph.rmat import random_source, rmat_graph
from repro.launch.mesh import make_local_mesh, make_local_mesh_1d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--grid", default="1x1")
    ap.add_argument("--roots", type=int, default=16)
    ap.add_argument("--no-diropt", action="store_true")
    ap.add_argument("--decomposition", choices=("1d", "1ds", "2d"),
                    default="2d")
    ap.add_argument("--local-mode", choices=("dense", "kernel"),
                    default="dense")
    ap.add_argument("--storage", choices=("csr", "dcsc"), default="csr")
    ap.add_argument("--fast", action="store_true",
                    help="instrument=False: compile out counters/stats "
                         "for the latency-lean level pipeline (TEPS "
                         "runs; the comm-volume report is skipped)")
    ap.add_argument("--born", action="store_true",
                    help="device-side distributed build (graph/"
                         "dist_build): no host edge list, validation "
                         "skipped")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="GraphStore directory: persist graph + AOT "
                         "executable; identical reruns reload from disk")
    args = ap.parse_args()
    pr, pc = map(int, args.grid.split("x"))

    store = None
    if args.store:
        from repro.ckpt.graph_store import GraphStore
        store = GraphStore(args.store)

    edges = None
    if args.born:
        from repro.graph.dist_build import BuildSpec, dist_build
        spec = BuildSpec(scale=args.scale, edge_factor=16, seed=1)
        mesh = make_local_mesh_1d(pr * pc) \
            if args.decomposition in ("1d", "1ds") else make_local_mesh(pr, pc)
        name = f"s{args.scale}-{args.decomposition}"
        graph = None
        if store is not None:
            try:                       # identical rerun: reload from disk
                t0 = time.perf_counter()
                graph = store.load_graph(name, mesh=mesh, expect_spec=spec)
                print(f"store load: {time.perf_counter() - t0:.3f}s "
                      f"(graph shards from {args.store})")
            except FileNotFoundError:
                pass
        if graph is None:
            graph, info = dist_build(spec, args.decomposition, mesh,
                                     (pr, pc))
            print(f"born-sharded build: {info['build_s']:.3f}s "
                  f"({info['build_teps']:.3e} edges/s input rate; "
                  f"m={info['m']}, no host edge materialization)")
            if store is not None:
                t0 = time.perf_counter()
                store.save_graph(name, graph, spec=spec)
                print(f"store save: {time.perf_counter() - t0:.3f}s -> "
                      f"{args.store}")
    else:
        edges = rmat_graph(args.scale, 16, seed=1)
        if args.decomposition in ("1d", "1ds"):
            graph = build_blocked_1d(
                edges, pr * pc, align=32,
                with_col_ptr=(args.local_mode == "kernel"
                              and args.storage == "csr"))
            mesh = make_local_mesh_1d(pr * pc)
        else:
            graph = build_blocked(edges, pr, pc, align=32)
            mesh = make_local_mesh(pr, pc)
    cfg = BFSConfig(decomposition=args.decomposition, storage=args.storage,
                    direction_optimizing=not args.no_diropt,
                    instrument=not args.fast)
    rng = np.random.default_rng(0)

    # plan + compile once; every root below is pure traversal (the §7
    # methodology: harmonic-mean TEPS must not be smeared by compilation)
    engine = plan_bfs(graph, cfg, mesh,
                      local_mode=args.local_mode).compile(store=store)
    engine.search(0)[0].block_until_ready()    # untimed first-dispatch warmup
    src = "store (deserialized)" if engine.exec_from_store else "XLA"
    print(f"compile: {engine.compile_s:.3f}s ({src}; exec_load "
          f"{engine.exec_load_s:.3f}s), graph ship: "
          f"{engine.ship_s:.3f}s (paid once, reused for {args.roots} roots)")

    # born graphs have no host edge list: draw roots from the degree
    # vector instead of random_source(edges)
    deg_global = None
    if edges is None:
        deg_global = np.flatnonzero(np.asarray(graph.deg_A).ravel() > 0)
    rates, res = [], None
    for i in range(args.roots):
        root = int(rng.choice(deg_global)) if edges is None \
            else random_source(edges, rng)
        # time the device search only; host-side result conversion and
        # validation stay outside the timed region (worker.py methodology)
        t0 = time.perf_counter()
        out = engine.search(root)
        out[0].block_until_ready()
        dt = time.perf_counter() - t0
        res = engine.to_result(out)
        if edges is not None:
            ok, msg = validate_parents(edges.n, edges.src, edges.dst, root,
                                       res.parents)
            assert ok, msg
            valid = "valid"
        else:
            valid = "validation skipped (born-sharded: no host edges)"
        rates.append(teps(graph.m_input, dt))
        print(f"root {root:>8}: {res.n_levels} levels, {dt*1e3:8.2f} ms, "
              f"{rates[-1]:.3e} TEPS, {valid}")
    print(f"\nharmonic-mean TEPS over {args.roots} roots "
          f"(traversal only): {harmonic_mean(rates):.3e}")
    if args.fast:
        # counters are compiled out of the fast program — there is no
        # comm-volume accounting to report (run without --fast for it)
        return
    useful = sum(v for k, v in res.counters.items() if k.startswith('use_'))
    if args.decomposition in ("1d", "1ds"):
        wt = comm_model.topdown_1d_words(graph.m, pr * pc)
        we = comm_model.expand_1d_words(graph.part.n, pr * pc, res.n_levels)
        # "1d" must reproduce the dense closed form exactly; "1ds" ships
        # sparse ids, so the dense volume is its per-search upper bound
        rel = "vs model" if args.decomposition == "1d" \
            else "vs dense-bitmap bound"
        print(f"useful words (last search): {useful:.3e}  "
              f"({args.decomposition} top-down model w={wt:.3e}; "
              f"wire_expand measured {res.counters['wire_expand']:.3e} "
              f"{rel} {we:.3e})")
    else:
        wt = comm_model.topdown_words(graph.part.n, graph.m, pr, pc)
        print(f"useful words (last search): {useful:.3e}  "
              f"(pure top-down model w_t={wt:.3e})")


if __name__ == "__main__":
    main()
