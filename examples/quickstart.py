"""Quickstart: direction-optimizing distributed BFS on an R-MAT graph,
via the plan → compile → run session API (compile once, traverse many).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import BFSConfig
from repro.core.engine import plan_bfs
from repro.core.metrics import teps
from repro.core.ref import validate_parents
from repro.graph.formats import build_blocked
from repro.graph.rmat import random_source, rmat_graph
from repro.launch.mesh import make_local_mesh


def main():
    edges = rmat_graph(scale=12, edge_factor=16, seed=1)
    print(f"R-MAT scale 12: n={edges.n} m={edges.m} (Graph500 params)")
    graph = build_blocked(edges, pr=1, pc=1, align=32)
    mesh = make_local_mesh(1, 1)
    cfg = BFSConfig(direction_optimizing=True, storage="dcsc")
    root = random_source(edges, np.random.default_rng(0))

    import time
    engine = plan_bfs(graph, cfg, mesh).compile()   # ship + jit, once
    t0 = time.perf_counter()
    out = engine.search(root)                       # device search only
    out[0].block_until_ready()
    dt = time.perf_counter() - t0
    res = engine.to_result(out)
    ok, msg = validate_parents(edges.n, edges.src, edges.dst, root,
                               res.parents)
    print(f"BFS from {root}: {res.n_levels} levels, valid tree: {ok}")
    print(f"compile {engine.compile_s:.3f}s (once); "
          f"TEPS (traversal): {teps(edges.m_input, dt):.3e}")
    modes = res.level_stats[: res.n_levels, 2]
    print(f"direction schedule (0=top-down, 1=bottom-up): {modes}")
    useful = sum(v for k, v in res.counters.items() if k.startswith('use_'))
    print(f"useful communication words: {useful:.3e}")
    assert ok


if __name__ == "__main__":
    main()
