"""Full-graph GNN training with the paper's 2D-partitioned aggregation:
GIN on a synthetic citation graph; verifies the shard_map expand/fold
SpMM against segment_sum, then trains.

    PYTHONPATH=src python examples/gnn_full_graph.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNShape, get_config, reduced
from repro.core.spmm import spmm_2d
from repro.graph.datasets import build_gnn_batch
from repro.graph.formats import build_blocked
from repro.graph.rmat import preprocess
from repro.launch.mesh import make_local_mesh
from repro.models import gnn as gnn_mod
from repro.optim.adamw import AdamW


def main():
    cfg = reduced(get_config("gin-tu"), d_hidden=32)
    shape = GNNShape("cora_like", 1024, 8192, d_feat=64, kind="full")
    b = build_gnn_batch(cfg, shape, seed=0)

    # 1) the paper's 2D SpMM == segment_sum oracle (1x1 grid here;
    #    tests/_dist_spmm_main.py covers real multi-device grids)
    e = preprocess(b["senders"].astype(np.int64),
                   b["receivers"].astype(np.int64), shape.n_nodes,
                   symmetrize=False)
    g2d = build_blocked(e, 1, 1, align=32)
    mesh = make_local_mesh(1, 1)
    x = b["x"][:, :8].astype(np.float32)
    got = spmm_2d(g2d, x, mesh)
    want = np.zeros_like(x)
    np.add.at(want, e.dst, x[e.src])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    print("2D expand/fold SpMM matches segment_sum oracle")

    # 2) train GIN for a few epochs
    bj = {k: jnp.asarray(v) for k, v in b.items()}
    bj["node_mask"] = jnp.ones(shape.n_nodes)
    init, apply = gnn_mod.build_gnn_apply(cfg, 64, cfg.n_classes)
    p = init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3, schedule="constant")
    ost = opt.init(p)

    @jax.jit
    def step(p, ost):
        loss, g = jax.value_and_grad(lambda p_: gnn_mod.node_xent(
            apply(p_, bj), bj["labels"], bj["node_mask"]))(p)
        p, ost = opt.update(g, ost, p)
        return p, ost, loss

    losses = []
    for i in range(30):
        p, ost, loss = step(p, ost)
        losses.append(float(loss))
    print(f"GIN loss {losses[0]:.3f} -> {losses[-1]:.3f} over 30 steps")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
